#!/usr/bin/env python
"""Bit-identity + invariant gate for seeded CI smoke runs.

Replaces the copy-pasted "run the CLI twice, diff the JSON reports"
heredocs in the smoke jobs (serve-smoke / serve-chaos-smoke / trace-smoke /
spec-decode-smoke) with one tool:

    python tools/ci_bitcheck.py RUN1.json RUN2.json \
        --require stream_digest completed \
        --expect "completed==16" "preemptions>=1"

Checks, in order:

  1. RUN1 and RUN2 are BYTE-identical (the determinism gate — every seeded
     artifact in this repo, report/trace/metrics alike, serializes
     deterministically, so byte equality is the strongest and simplest
     check). With ``--match K ...`` the byte check is replaced by equality
     of just those dotted-path keys across the two files — for comparing
     DIFFERENT runs that must agree on specific fields (e.g. the
     speculative run's ``stream_digest`` vs the plain run's).
  2. ``--require`` keys exist in RUN1 (parsed as JSON; dotted paths
     descend into nested objects). JSONL artifacts (Watchtower alert
     logs) parse into a synthetic doc: header fields at the top level,
     ``counts.<rule>__<state>`` tallies, ``n_lines``/``n_events``.
  3. ``--expect`` invariants hold on RUN1: ``key OP value`` with OP one of
     ``== != >= <= > <`` (numeric when both sides parse as numbers,
     string equality otherwise).

Exit 0 when every check passes, 1 on any failure, 2 on usage errors.
Stdlib only (it must run before any dependency install step).
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Any, List

_EXPECT_RE = re.compile(r"^([A-Za-z0-9_.\-]+)\s*(==|!=|>=|<=|>|<)\s*(.+)$")
_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    ">=": lambda a, b: a >= b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    "<": lambda a, b: a < b,
}


def _lookup(doc: Any, path: str):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(path)
        cur = cur[part]
    return cur


def _coerce(text: str):
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    return text.strip("\"'")


def _load_jsonl(text: str, path: str):
    """Parse a JSONL artifact (e.g. Watchtower alert logs) into one
    queryable doc: header fields (``schema_version``/``kind``/...) are
    lifted to the top level, and event lines carrying ``rule``+``state``
    are tallied into ``counts.<rule>__<state>`` so smoke jobs can gate on
    e.g. ``--expect "counts.straggler-slowdown__firing>=1"``."""
    lines = []
    for n, raw in enumerate(text.splitlines(), 1):
        if not raw.strip():
            continue
        try:
            lines.append(json.loads(raw))
        except json.JSONDecodeError as e:
            print(f"ci_bitcheck: cannot parse {path} line {n}: {e}",
                  file=sys.stderr)
            sys.exit(2)
    doc: dict = {"jsonl": True, "n_lines": len(lines), "counts": {}}
    if lines and isinstance(lines[0], dict) and "schema_version" in lines[0]:
        doc.update(lines[0])
        lines = lines[1:]
    for ev in lines:
        if isinstance(ev, dict) and "rule" in ev and "state" in ev:
            key = f"{ev['rule']}__{ev['state']}"
            doc["counts"][key] = doc["counts"].get(key, 0) + 1
    doc["n_events"] = len(lines)
    return doc


def _load_json(path: str):
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        print(f"ci_bitcheck: cannot parse {path}: {e}", file=sys.stderr)
        sys.exit(2)
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        return _load_jsonl(text, path)


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="byte-identity + invariant gate for seeded CI runs")
    ap.add_argument("run1", help="first artifact (JSON for key checks)")
    ap.add_argument("run2", help="second artifact to compare against")
    ap.add_argument("--require", nargs="*", default=[], metavar="KEY",
                    help="dotted-path keys that must exist in RUN1")
    ap.add_argument("--expect", nargs="*", default=[], metavar="EXPR",
                    help="invariants on RUN1: 'key OP value' "
                         "(OP: == != >= <= > <)")
    ap.add_argument("--match", nargs="*", default=None, metavar="KEY",
                    help="compare only these dotted-path keys between the "
                         "two files instead of full byte identity")
    args = ap.parse_args(argv)

    failures: List[str] = []
    if args.match is None:
        try:
            with open(args.run1, "rb") as f1, open(args.run2, "rb") as f2:
                b1, b2 = f1.read(), f2.read()
        except OSError as e:
            print(f"ci_bitcheck: {e}", file=sys.stderr)
            return 2
        if b1 != b2:
            n = next((i for i, (x, y) in enumerate(zip(b1, b2)) if x != y),
                     min(len(b1), len(b2)))
            failures.append(
                f"{args.run1} and {args.run2} differ "
                f"(first difference at byte {n}; sizes {len(b1)}/{len(b2)})")

    doc1 = _load_json(args.run1)
    if args.match is not None:
        doc2 = _load_json(args.run2)
        for key in args.match:
            try:
                v1, v2 = _lookup(doc1, key), _lookup(doc2, key)
            except KeyError:
                failures.append(f"--match key {key!r} missing from a report")
                continue
            if v1 != v2:
                failures.append(f"{key}: {v1!r} ({args.run1}) != {v2!r} "
                                f"({args.run2})")

    for key in args.require:
        try:
            _lookup(doc1, key)
        except KeyError:
            failures.append(f"required key {key!r} missing from {args.run1}")

    for expr in args.expect:
        m = _EXPECT_RE.match(expr)
        if m is None:
            print(f"ci_bitcheck: cannot parse --expect {expr!r}",
                  file=sys.stderr)
            return 2
        key, op, raw = m.groups()
        want = _coerce(raw)
        try:
            got = _lookup(doc1, key)
        except KeyError:
            failures.append(f"--expect key {key!r} missing from {args.run1}")
            continue
        if isinstance(want, (int, float)) and isinstance(got, bool):
            got = int(got)
        if not _OPS[op](got, want):
            failures.append(f"expect failed: {key}={got!r}, wanted "
                            f"{op} {want!r}")

    if failures:
        for f in failures:
            print(f"ci_bitcheck FAIL: {f}", file=sys.stderr)
        return 1
    checked = (f"match={args.match}" if args.match is not None
               else "byte-identical")
    print(f"ci_bitcheck OK: {args.run1} vs {args.run2} ({checked}, "
          f"{len(args.require)} required, {len(args.expect)} expected)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
