"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
all in interpret=True mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distill_loss import fused_distill_loss
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_ce import fused_cross_entropy
from repro.kernels import ops


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


CE_SHAPES = [(128, 256), (256, 512), (384, 1024)]


@pytest.mark.parametrize("t,v", CE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ce_sweep(t, v, dtype):
    k = jax.random.key(t + v)
    logits = (jax.random.normal(k, (t, v)) * 4).astype(dtype)
    labels = jax.random.randint(jax.random.key(1), (t,), 0, v)
    out = fused_cross_entropy(logits, labels, block_t=128, block_v=128,
                              interpret=True)
    want = ref.cross_entropy_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("mode", ["mse", "kl"])
@pytest.mark.parametrize("t,v", [(128, 256), (256, 768)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_distill_sweep(mode, t, v, dtype):
    a = (jax.random.normal(jax.random.key(0), (t, v)) * 2).astype(dtype)
    b = (jax.random.normal(jax.random.key(1), (t, v)) * 2).astype(dtype)
    out = fused_distill_loss(a, b, mode=mode, block_t=128, block_v=128,
                             interpret=True)
    want = ref.distill_mse_ref(a, b) if mode == "mse" else ref.distill_kl_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **_tol(dtype))


ATTN_CASES = [
    # (B, S, H, KV, hd, causal, window)
    (1, 128, 4, 4, 64, True, 0),
    (2, 256, 4, 2, 64, True, 0),      # GQA 2:1
    (1, 128, 8, 2, 32, True, 0),      # GQA 4:1
    (1, 256, 4, 4, 64, True, 64),     # sliding window
    (2, 128, 4, 1, 64, True, 0),      # MQA
    (1, 128, 2, 2, 128, False, 0),    # encoder (non-causal)
]


@pytest.mark.parametrize("b,s,h,kv,hd,causal,window", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kv, hd, causal, window, dtype):
    keys = jax.random.split(jax.random.key(s + h), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd)).astype(dtype)
    k = jax.random.normal(keys[1], (b, s, kv, hd)).astype(dtype)
    v = jax.random.normal(keys[2], (b, s, kv, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_flash_attention_cross_lengths():
    """T != S (prefix cache reads)."""
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (1, 64, 4, 32))
    k = jax.random.normal(keys[1], (1, 256, 4, 32))
    v = jax.random.normal(keys[2], (1, 256, 4, 32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


class TestOpsWrappers:
    def test_ce_padding_paths(self):
        """Unaligned T and V get padded transparently."""
        t, v = 100, 300
        logits = jax.random.normal(jax.random.key(0), (2, 50, v)) * 3
        labels = jax.random.randint(jax.random.key(1), (2, 50), 0, v)
        out = ops.cross_entropy_tokens(logits, labels, block_t=64,
                                       block_v=128, interpret=True)
        want = ref.cross_entropy_ref(logits.reshape(t, v),
                                     labels.reshape(t)).reshape(2, 50)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_distill_padding_paths(self):
        t, v = 96, 200
        a = jax.random.normal(jax.random.key(0), (t, v))
        b = jax.random.normal(jax.random.key(1), (t, v))
        for mode in ("mse", "kl"):
            out = ops.distill_loss_tokens(a, b, mode=mode, block_t=64,
                                          block_v=128, interpret=True)
            want = (ref.distill_mse_ref if mode == "mse"
                    else ref.distill_kl_ref)(a, b)
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)

    def test_attention_padding(self):
        keys = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(keys[0], (1, 100, 4, 32))
        k = jax.random.normal(keys[1], (1, 100, 2, 32))
        v = jax.random.normal(keys[2], (1, 100, 2, 32))
        out = ops.attention(q, k, v, causal=True, block_q=64, block_k=64,
                            interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_distill_kernel_agrees_with_core_loss(self):
        """Kernel path == the core (model-level) distillation loss."""
        from repro.core.codistillation import distill_mse
        a = jax.random.normal(jax.random.key(0), (4, 16, 64))
        b = jax.random.normal(jax.random.key(1), (4, 16, 64))
        kern = float(jnp.mean(ops.distill_loss_tokens(a, b, mode="mse",
                                                      block_t=64, block_v=64,
                                                      interpret=True)))
        core = float(distill_mse(a, b))
        assert kern == pytest.approx(core, rel=1e-5)
