from repro.train.engine import (  # noqa: F401
    AllReduce,
    AsyncPrediction,
    CheckpointExchange,
    ExchangeStrategy,
    PipelinedPredictions,
    PredictionExchange,
    STRATEGIES,
    ShardMapCompressed,
    StepBundle,
    build_train_step,
    make_codist_eval_step,
    make_eval_step,
    make_schedules,
    refresh_stale,
    resolve_strategy,
)
from repro.train.loop import (  # noqa: F401
    History,
    stack_batches,
    train,
    train_allreduce,
    train_codist,
)
from repro.train.state import (  # noqa: F401
    CodistState,
    TrainState,
    init_codist_state,
    init_peer_state,
    init_train_state,
)
