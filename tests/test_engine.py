"""Unified step-engine tests: strategy parity, microbatch gradient
accumulation for every mechanism, the trainable mask, plan schedules, and
comm accounting — plus subprocess checks for the shard_map strategy (which
needs a multi-device "pod" axis)."""
import json
import os
import subprocess
import sys
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.core.codistillation import model_slice
from repro.data import MarkovLM, make_lm_batch
from repro.models import build_model
from repro.optim import make_optimizer
from repro.train import (AllReduce, CheckpointExchange, PipelinedPredictions,
                         PredictionExchange, TrainState, build_train_step,
                         init_codist_state, resolve_strategy, stack_batches,
                         train, train_allreduce, train_codist)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def tiny_cfg():
    return replace(get_reduced("qwen1.5-0.5b"), num_layers=1, d_model=32,
                   d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                   head_dim=16)


TASK = MarkovLM(vocab=64, seed=0)
N, B, S = 2, 8, 16


def coord_batches(n=N, b=B, s=S):
    def fn(step):
        return stack_batches([make_lm_batch(TASK, b, s, step, None, seed=0)
                              for _ in range(n)])
    return fn


def single_batches(b=B, s=S):
    return lambda step: make_lm_batch(TASK, b, s, step, None, seed=0)


def mb_batches(k, n=N, b=B, s=S):
    """Same data as coord_batches, reshaped to the (n, k, B/k, ...) layout."""
    base = coord_batches(n, b, s)

    def fn(step):
        return jax.tree.map(
            lambda x: x.reshape((n, k, b // k) + x.shape[2:]), base(step))
    return fn


# ----------------------------------------------------------------------------
# strategy parity: alpha=0 reduces every mechanism to independent training
# ----------------------------------------------------------------------------

class TestStrategyParity:
    """At alpha=0 the codist loss is mean_i task_i, so model i's gradient is
    (1/n) * d(task_i): with SGD-momentum, zero weight decay and the codist LR
    scaled by n, every codist strategy must reproduce the all-reduce
    trajectory of each model EXACTLY (AdamW would only match approximately —
    its normalizer absorbs the 1/n)."""

    STEPS = 6

    def _tc(self, lr_scale=1.0):
        return TrainConfig(lr=0.05 * lr_scale, lr_schedule="cosine",
                           warmup_steps=2, total_steps=self.STEPS,
                           weight_decay=0.0, optimizer="sgdm", seed=0)

    @pytest.fixture(scope="class")
    def reference(self):
        """Per-model all-reduce task-loss trajectories from a shared init."""
        model = build_model(tiny_cfg())
        opt_init, _ = make_optimizer("sgdm")
        stacked = init_codist_state(model, jax.random.key(0), N, opt_init)
        runs = []
        for i in range(N):
            st = TrainState(model_slice(stacked.params, i),
                            opt_init(model_slice(stacked.params, i)),
                            jnp.zeros((), jnp.int32))
            _, hist = train(model, self._tc(), single_batches(), AllReduce(),
                            state=st, log_every=1)
            runs.append(hist.series("task_loss"))
        return model, stacked, np.asarray(runs)  # (n, steps)

    def _run_codist(self, model, stacked, strategy_cls, **cfg_kw):
        codist = CodistConfig(n_models=N, alpha0=0.0, **cfg_kw)
        _, hist = train_codist(model, codist, self._tc(lr_scale=N),
                               coord_batches(), state=stacked, log_every=1,
                               strategy=strategy_cls(codist))
        return hist

    def test_prediction_matches_allreduce(self, reference):
        model, stacked, ref = reference
        hist = self._run_codist(model, stacked, PredictionExchange)
        for i in range(N):
            got = hist.series(f"task_loss_per_model_{i}")
            np.testing.assert_allclose(got, ref[i], rtol=1e-4, atol=1e-5)

    def test_checkpoint_matches_allreduce(self, reference):
        model, stacked, ref = reference
        # stale is absent on the supplied state: ensure_state must repair it
        hist = self._run_codist(model, stacked, CheckpointExchange,
                                mode="checkpoints", period=2)
        for i in range(N):
            got = hist.series(f"task_loss_per_model_{i}")
            np.testing.assert_allclose(got, ref[i], rtol=1e-4, atol=1e-5)

    def test_pipelined_matches_allreduce(self, reference):
        model, stacked, ref = reference
        hist = self._run_codist(model, stacked, PipelinedPredictions,
                                pipelined=True)
        got = hist.series("task_loss")
        np.testing.assert_allclose(got, ref.mean(axis=0), rtol=1e-4,
                                   atol=1e-5)


# ----------------------------------------------------------------------------
# microbatch gradient accumulation: parity between microbatch=1 and =4
# (pins the fix: checkpoint/pipelined used to silently skip accumulation)
# ----------------------------------------------------------------------------

class TestMicrobatchParity:
    K = 4
    STEPS = 2  # two steps so the pipelined peer buffer is exercised

    @pytest.fixture(scope="class")
    def model(self):
        return build_model(tiny_cfg())

    def _tc(self, k):
        return TrainConfig(lr=1e-2, total_steps=self.STEPS, warmup_steps=0,
                           optimizer="sgdm", microbatch=k, seed=0)

    def _final_params(self, model, strategy_cls, cfg_kw, k):
        codist = CodistConfig(n_models=N, alpha0=1.0, **cfg_kw)
        batches = mb_batches(self.K) if k > 1 else coord_batches()
        strategy = strategy_cls(codist)
        tc = self._tc(k)
        opt_init, _ = make_optimizer("sgdm")
        state = strategy.init_state(model, tc, jax.random.key(0), opt_init,
                                    batches(0))
        bundle = build_train_step(model, tc, codist, strategy)
        for s in range(self.STEPS):
            state, _, _ = bundle.apply(state, batches(s), s)
        return state.params

    @pytest.mark.parametrize("strategy_cls,cfg_kw", [
        (PredictionExchange, {}),
        (CheckpointExchange, {"mode": "checkpoints"}),
        (PipelinedPredictions, {"pipelined": True}),
    ], ids=["prediction", "checkpoint", "pipelined"])
    def test_grad_parity(self, model, strategy_cls, cfg_kw):
        p1 = self._final_params(model, strategy_cls, cfg_kw, k=0)
        p4 = self._final_params(model, strategy_cls, cfg_kw, k=self.K)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)

    def test_allreduce_grad_parity(self, model):
        tc1, tc4 = self._tc(0), self._tc(self.K)
        opt_init, _ = make_optimizer("sgdm")
        b1 = single_batches()(0)
        b4 = jax.tree.map(
            lambda x: x.reshape((self.K, B // self.K) + x.shape[1:]), b1)
        s0 = AllReduce().init_state(model, tc1, jax.random.key(0), opt_init)
        st1, _ = build_train_step(model, tc1, None,
                                  AllReduce()).variants["on"](s0, b1)
        st4, _ = build_train_step(model, tc4, None,
                                  AllReduce()).variants["on"](s0, b4)
        for a, b in zip(jax.tree.leaves(st1.params),
                        jax.tree.leaves(st4.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)


# ----------------------------------------------------------------------------
# trainable mask: frozen params stay frozen under EVERY strategy
# (pins the fix: the pipelined step used to drop the mask)
# ----------------------------------------------------------------------------

class TestTrainableMask:
    @pytest.mark.parametrize("cfg_kw", [
        {}, {"mode": "checkpoints"}, {"pipelined": True},
    ], ids=["prediction", "checkpoint", "pipelined"])
    def test_frozen_params_unchanged(self, cfg_kw):
        model = build_model(tiny_cfg())
        codist = CodistConfig(n_models=N, alpha0=1.0, **cfg_kw)
        tc = TrainConfig(lr=1e-2, total_steps=1, warmup_steps=0,
                         optimizer="sgdm", seed=0)
        strategy = resolve_strategy(codist)
        opt_init, _ = make_optimizer("sgdm")
        batch = coord_batches()(0)
        state = strategy.init_state(model, tc, jax.random.key(0), opt_init,
                                    batch)
        frozen = jax.tree.map(lambda p: jnp.zeros((), jnp.int32),
                              state.params)
        bundle = build_train_step(model, tc, codist, strategy,
                                  trainable=frozen)
        new_state, _, _ = bundle.apply(state, batch, 0)
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------------
# plan schedules + comm accounting
# ----------------------------------------------------------------------------

class TestPlansAndComm:
    def test_prediction_plan_period(self):
        s = PredictionExchange(CodistConfig(n_models=2, period=5))
        assert [s.plan(k).distill for k in range(10)] == \
            [True, False, False, False, False] * 2
        assert [s.variant_for(s.plan(k)) for k in range(3)] == \
            ["on", "off", "off"]

    def test_checkpoint_plan_distills_every_step(self):
        s = CheckpointExchange(CodistConfig(n_models=2, mode="checkpoints",
                                            period=5))
        plans = [s.plan(k) for k in range(10)]
        assert all(p.distill for p in plans)
        assert sum(p.exchange for p in plans) == 2

    def test_allreduce_plan_exchanges_every_step(self):
        s = AllReduce()
        assert all(s.plan(k).exchange for k in range(5))

    def test_comm_bytes_ordering(self):
        """Section-3 accounting through strategy.comm_bytes: small-vocab
        prediction exchange is cheaper per event than a parameter exchange,
        which is cheaper than the 2x-model all-reduce."""
        model = build_model(tiny_cfg())
        opt_init, _ = make_optimizer("sgdm")
        codist = CodistConfig(n_models=N)
        batch = coord_batches(b=2, s=8)(0)
        state = init_codist_state(model, jax.random.key(0), N, opt_init)
        pred = PredictionExchange(codist).comm_bytes(model, state, batch)
        ckpt = CheckpointExchange(
            replace(codist, mode="checkpoints")).comm_bytes(
                model, state, batch)
        ar_state = AllReduce().init_state(model, None, jax.random.key(0),
                                          opt_init)
        ar = AllReduce().comm_bytes(model, ar_state, batch)
        assert 0 < pred < ckpt < ar
        # prediction bits: (n-1) * B * S * padded_vocab * 32 / 8
        want = (N - 1) * 2 * 8 * model.cfg.padded_vocab * 32 / 8
        assert pred == pytest.approx(want)

    def test_resolve_strategy_dispatch(self):
        assert isinstance(resolve_strategy(None), AllReduce)
        assert isinstance(resolve_strategy(CodistConfig(n_models=2)),
                          PredictionExchange)
        assert isinstance(
            resolve_strategy(CodistConfig(n_models=2, mode="checkpoints")),
            CheckpointExchange)
        assert isinstance(
            resolve_strategy(CodistConfig(n_models=2, pipelined=True)),
            PipelinedPredictions)


# ----------------------------------------------------------------------------
# shard_map strategy: needs a multi-device "pod" axis -> subprocess
# ----------------------------------------------------------------------------

def run_sub(code: str, devices: int = 2) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=520)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_shardmap_matches_prediction_exchange():
    """Satellite parity claim: at period=1 and compression='none' the
    explicit shard_map exchange and the pjit prediction exchange produce
    identical losses (same math, pinned schedule)."""
    code = """
import json
import jax, jax.numpy as jnp
from dataclasses import replace
from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.models import build_model
from repro.data import MarkovLM, make_lm_batch
from repro.train import (ShardMapCompressed, stack_batches, train_codist)

cfg = replace(get_reduced('qwen1.5-0.5b'), num_layers=1, d_model=32,
              d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
              head_dim=16)
model = build_model(cfg)
task = MarkovLM(vocab=64, seed=0)
tc = TrainConfig(lr=1e-2, total_steps=4, warmup_steps=0, optimizer='sgdm',
                 seed=0)
codist = CodistConfig(n_models=2, period=1, alpha0=1.0, distill_loss='mse',
                      compression='none')
def batches(step):
    return stack_batches([make_lm_batch(task, 4, 16, step, None, seed=0)
                          for _ in range(2)])
_, h_pred = train_codist(model, codist, tc, batches, log_every=1)
mesh = jax.make_mesh((2,), ('pod',))
_, h_sm = train_codist(model, codist, tc, batches, log_every=1,
                       strategy=ShardMapCompressed(codist, mesh))
print('RESULT ' + json.dumps({
    'pred': h_pred.series('loss'), 'sm': h_sm.series('loss'),
    'pred_dist': h_pred.series('distill_loss'),
    'sm_dist': h_sm.series('distill_loss')}))
"""
    r = run_sub(code)
    np.testing.assert_allclose(r["sm"], r["pred"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(r["sm_dist"], r["pred_dist"], rtol=1e-4,
                               atol=1e-5)
    assert max(r["pred_dist"]) > 0  # the distillation term is actually live


def test_cli_codist_shardmap_smoke():
    """--mode codist-shardmap trains end-to-end from the CLI (the launcher
    forces the pod-axis host devices itself)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--mode",
         "codist-shardmap", "--steps", "3", "--batch", "2", "--seq", "16",
         "--log-every", "1", "--eval-every", "0"],
        capture_output=True, text=True, env=env, timeout=520)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    assert "done: 3 steps" in out.stdout
