"""wrn28x10 [paper's own multi-view workload] — Wide-ResNet 28x10 on CIFAR-10 as in
Section 5.1 (frozen first-bottleneck channel-split experiment, after Allen-Zhu & Li).
"""
from repro.models.conv import ConvConfig

CONFIG = ConvConfig(
    name="wrn28x10",
    kind="wideresnet",
    depths=(4, 4, 4),          # (28-4)/6 = 4 blocks per group
    widths=(160, 320, 640),
    bottleneck=False,
    num_classes=10,
    image_size=32,
    source="WRN-28-10 [arXiv:1605.07146]; multi-view setup [arXiv:2012.09816]",
)


def reduced():
    return ConvConfig(
        name="wrn28x10-reduced",
        kind="wideresnet",
        depths=(1, 1),
        widths=(32, 64),
        bottleneck=False,
        num_classes=10,
        image_size=32,
        source=CONFIG.source,
    )
