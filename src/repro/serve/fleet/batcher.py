"""Continuous-batching scheduler: per-step join/evict of ragged requests
into fixed decode slots over the paged KV pool.

One ``FleetEngine`` serves one codistilled peer. Every engine tick:

  1. requests whose (simulated) arrival time has passed move into the
     bounded waiting queue (admission control: overflow is REJECTED, load
     shedding at the edge rather than unbounded latency);
  2. up to ``max_prefills_per_step`` waiting requests are admitted into free
     decode slots — reservation-on-admit: the full worst-case context
     (prompt + max output) is block-reserved up front so an admitted request
     can never deadlock mid-decode. Each admission runs an exact-length
     single-request prefill (identical to ``Engine.generate``'s — the parity
     anchor) whose KV scatters into the slot's blocks and whose last-token
     argmax is the request's first generated token;
  3. one batched decode step advances EVERY live slot through the paged
     pool (prefill/decode interleaving: joins at step t decode in step t);
  4. finished requests evict, freeing their blocks for the next tick.

Time is simulated (a deterministic per-step cost model), so latency/SLO
reports are bit-reproducible across machines — wall-clock throughput is
measured separately by ``benchmarks/serving.py``. Greedy decoding only: the
fleet's testable invariant is temperature-0 token-identity with the dense
engine.

With a ``ChaosSchedule`` attached (``repro.serve.fleet.chaos``) each tick
additionally consults the seeded fault schedule: the tick cost is scaled by
the peer's slowdown, a scheduled preemption jumps the clock past the pause
(in-flight slots frozen, KV intact), and a scheduled failure kills the
engine at the start of the tick — a dead engine makes no progress until the
router ``revive``s it. The clean path (no schedule) is bit-identical to the
pre-chaos engine.
"""
from __future__ import annotations

import weakref
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_cache import is_quantized_dtype
from repro.serve.fleet.cache import PagedCachePool
from repro.serve.fleet.model_exec import build_decode_step
from repro.serve.fleet.workload import Request

PyTree = Any

# trace process-row convention (see docs/observability.md): the router is
# pid 0, peer engines are pid 1+peer_id, and the per-request span trees get
# their own process row so Perfetto doesn't split a migrated request's tree
# across the peers it visited
ROUTER_PID = 0
REQUEST_PID = 1000


@dataclass(frozen=True)
class FleetConfig:
    max_slots: int = 8
    block_size: int = 8
    num_blocks: int = 128            # incl. the reserved null block
    max_blocks_per_slot: int = 16
    max_queue: int = 256             # admission control: beyond this, shed
    max_prefills_per_step: int = 2   # prefill/decode interleaving knob
    defrag_every: int = 0            # engine steps; 0 = never
    # None/True: fused paged-attention decode kernel (Mosaic on TPU,
    # interpret on CPU); False: the jnp gather+dense-softmax oracle
    fused_attention: Optional[bool] = None
    # deterministic simulated cost model (ms)
    prefill_ms_per_token: float = 0.2
    decode_ms_per_step: float = 1.5
    step_overhead_ms: float = 0.3


@dataclass
class RequestRecord:
    """Per-request lifecycle + output stream (the determinism surface).

    ``origin`` is set on migrated continuations: the CLIENT's request, whose
    arrival anchors TTFT/E2E regardless of how many peers the work visited.
    ``migrations`` counts placements beyond the first (on the logical,
    client-facing record).
    """
    request: Request
    canary: bool = False
    admitted_ms: Optional[float] = None
    first_token_ms: Optional[float] = None
    finished_ms: Optional[float] = None
    rejected: bool = False
    cancelled: bool = False          # hedge loser / harvested off a peer
    origin: Optional[Request] = None
    migrations: int = 0
    tokens: List[int] = field(default_factory=list)
    prefill_logits: Optional[np.ndarray] = None   # kept for canary compares
    # observability bookkeeping (router-managed; see FleetRouter._trace_*):
    # only client-facing placements are traced, and each physical placement
    # emits its span tree exactly once
    traced: bool = False
    trace_emitted: bool = False

    @property
    def _arrival0_ms(self) -> float:
        return (self.origin or self.request).arrival_ms

    @property
    def ttft_ms(self) -> Optional[float]:
        if self.first_token_ms is None:
            return None
        return self.first_token_ms - self._arrival0_ms

    @property
    def e2e_ms(self) -> Optional[float]:
        if self.finished_ms is None:
            return None
        return self.finished_ms - self._arrival0_ms


@dataclass
class _Slot:
    record: RequestRecord
    remaining: int
    next_token: int                  # decode input (last generated token)


# compiled decode/prefill shared across engines: N peers of one fleet serve
# the SAME model object (params are call arguments), so compiling per engine
# would duplicate the decode program and every distinct prompt-length
# prefill trace N times. Weak-keyed on the model so entries (and their jit
# traces) die with the fleet instead of accumulating for process lifetime.
_EXEC_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _shared_exec(model, cache_dtype, fused_attention=None):
    per_model = _EXEC_CACHE.setdefault(model, {})
    key = (jnp.dtype(cache_dtype).name, fused_attention)
    if key not in per_model:
        # quantized pools are quantized at INSERT time (scatter-quant /
        # quantize_rows): prefill itself must run with a full-precision
        # cache so there are exact rows to quantize
        prefill_dtype = (jnp.float32 if is_quantized_dtype(cache_dtype)
                         else cache_dtype)
        per_model[key] = (
            build_decode_step(model, fused_attention=fused_attention),
            jax.jit(lambda p, b, cap: model.prefill(p, b, cap,
                                                    cache_dtype=prefill_dtype),
                    static_argnums=(2,)),
        )
    return per_model[key]


def _shared_verify(model, cache_dtype, fused_attention, k: int):
    """Compile-once k-token speculative verify, cached alongside the decode
    step (same weak-keyed registry: N peers of one fleet share one trace
    per (dtype, fused, k))."""
    from repro.serve.fleet.model_exec import build_verify_step
    per_model = _EXEC_CACHE.setdefault(model, {})
    key = (jnp.dtype(cache_dtype).name, fused_attention, "verify", k)
    if key not in per_model:
        per_model[key] = build_verify_step(model, k,
                                           fused_attention=fused_attention)
    return per_model[key]


class FleetEngine:
    """One peer's continuous batcher: paged pool + compile-once decode."""

    def __init__(self, model, params: PyTree, config: FleetConfig,
                 cache_dtype=jnp.float32, keep_logits: bool = False,
                 peer_id: int = 0, tracer=None, metrics=None):
        self.model = model
        self.params = params
        self.config = config
        self.cache_dtype = cache_dtype
        self.keep_logits = keep_logits
        self.peer_id = peer_id
        # observability (None = hooks compile to a single attribute check:
        # the default decode tick allocates nothing new — pinned by
        # tests/test_obs.py's digest-equality test)
        self.tracer = tracer
        self.metrics = metrics
        # optional Watchtower (obs/watch.py): evaluated once per tick on
        # this engine's simulated clock; None = no alerting, no overhead
        self.watch = None
        self._pid = peer_id + 1          # trace process row (0 = router)
        # chaos hooks (None/untouched on the clean path)
        self.chaos = None                # Optional[ChaosSchedule]
        self.health = None               # Optional[PeerHealth]
        self.dead = False
        self._fail_fired = False         # scheduled permanent failure spent
        self.died_at_ms: Optional[float] = None
        self.offline_until_ms = 0.0
        self.preemptions_hit = 0
        self.max_queue_live = config.max_queue   # tightened when degraded
        self.pool = PagedCachePool(
            model, max_slots=config.max_slots, block_size=config.block_size,
            num_blocks=config.num_blocks,
            max_blocks_per_slot=config.max_blocks_per_slot,
            cache_dtype=cache_dtype)
        self._decode, self._prefill = _shared_exec(
            model, cache_dtype, config.fused_attention)
        self.now_ms = 0.0
        self.steps = 0
        self.weights_version = -1        # bumped by router weight refresh
        self.pending: Deque[RequestRecord] = deque()  # future arrivals
        self.waiting: Deque[RequestRecord] = deque()  # admission queue
        self.slots: Dict[int, _Slot] = {}             # slot id -> live req
        self.records: List[RequestRecord] = []
        # deterministic accounting
        self.kv_bytes_written = 0
        self.decode_tokens = 0
        self.prefill_tokens = 0
        self.rejected = 0
        self.peak_utilization = 0.0
        cfg = model.cfg
        n_attn = len(self.pool.kv_subs) * self.pool.n_scan
        per_row = (cfg.num_kv_heads * cfg.resolved_head_dim
                   * jnp.dtype(cache_dtype).itemsize)
        if self.pool.quantized:
            per_row += 4             # one fp32 scale per stored row
        self._kv_bytes_per_token = int(n_attn * 2 * per_row)
        # analytic decode cost per attended context row: qk + av are each a
        # multiply-accumulate over num_heads * head_dim lanes per attention
        # sublayer (2 FLOPs per MAC -> factor 4); HBM traffic is the K and V
        # rows actually read, at the pool's stored precision
        self._flops_per_ctx_row = int(
            4 * n_attn * cfg.num_heads * cfg.resolved_head_dim)
        if self.tracer is not None:
            self.tracer.name_process(self._pid, f"peer{peer_id}")
            self.tracer.name_thread(self._pid, 0, "engine")

    # ---- intake ------------------------------------------------------------
    def set_params(self, params: PyTree) -> None:
        self.params = params         # args of the jitted fns: no recompile

    def enqueue(self, request: Request, canary: bool = False) -> RequestRecord:
        rec = RequestRecord(request, canary=canary)
        self.records.append(rec)
        self.pending.append(rec)     # router submits in arrival order
        return rec

    @property
    def load(self) -> int:
        # pending counts too: the router enqueues at arrival time, and ticks
        # may not run between closely-spaced arrivals — without it,
        # least_loaded would route a whole burst to one peer on stale load
        return len(self.slots) + len(self.waiting) + len(self.pending)

    def has_work(self) -> bool:
        return bool(self.slots or self.waiting or self.pending)

    def next_arrival_ms(self) -> Optional[float]:
        # min over the whole deque: migration can append a continuation with
        # an earlier arrival than harvested-in future requests, so the head
        # is not guaranteed earliest (it is on the clean path)
        if not self.pending:
            return None
        return min(r.request.arrival_ms for r in self.pending)

    # ---- the engine tick ---------------------------------------------------
    def _intake(self) -> None:
        # full rotation instead of head-only for the same reason as
        # ``next_arrival_ms``; order-preserving, identical on the clean path
        for _ in range(len(self.pending)):
            rec = self.pending.popleft()
            if rec.request.arrival_ms > self.now_ms:
                self.pending.append(rec)
                continue
            if len(self.waiting) >= self.max_queue_live:
                rec.rejected = True
                self.rejected += 1
                continue
            self.waiting.append(rec)

    def _admit(self) -> int:
        """Prefill + join up to ``max_prefills_per_step`` waiting requests.
        Returns prefilled token count (for the simulated cost model)."""
        admitted_tokens = 0
        n = 0
        while self.waiting and n < self.config.max_prefills_per_step:
            rec = self.waiting[0]
            req = rec.request
            total = req.prompt_len + req.max_new
            if self.pool.blocks_needed(total) > min(
                    self.pool.num_blocks - 1, self.pool.max_blocks_per_slot):
                # larger than the pool itself: shed instead of wedging the queue
                self.waiting.popleft()
                rec.rejected = True
                self.rejected += 1
                continue
            free_slots = [s for s in range(self.config.max_slots)
                          if s not in self.slots]
            if not free_slots or not self.pool.can_admit(total):
                break                # head-of-line: wait for evictions
            self.waiting.popleft()
            slot = free_slots[0]
            self.pool.allocate(slot, total)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache = self._prefill(self.params, {"tokens": tokens},
                                          req.prompt_len)
            self.pool.insert_prefill(slot, cache, req.prompt_len)
            first = int(jnp.argmax(logits[0, -1]))
            rec.admitted_ms = self.now_ms
            rec.tokens.append(first)
            if self.keep_logits or rec.canary:
                rec.prefill_logits = np.asarray(logits[0, -1], np.float32)
            self.slots[slot] = _Slot(rec, remaining=req.max_new - 1,
                                     next_token=first)
            admitted_tokens += req.prompt_len
            self.prefill_tokens += req.prompt_len
            self.kv_bytes_written += req.prompt_len * self._kv_bytes_per_token
            n += 1
        return admitted_tokens

    def _decode_tick(self) -> int:
        """One batched decode step over every live slot. Returns the total
        attended context rows (post-write lengths summed over live slots —
        the analytic HBM/FLOP unit); 0 means nothing decoded."""
        live = sorted(s for s, sl in self.slots.items() if sl.remaining > 0)
        if not live:
            return 0
        S = self.config.max_slots
        active = np.zeros((S,), bool)
        active[live] = True
        tokens = np.zeros((S, 1), np.int32)
        for s in live:
            tokens[s, 0] = self.slots[s].next_token
        wslot, woff = self.pool.write_maps(active)
        logits, kv, states = self._decode(
            self.params, self.pool.kv, self.pool.states,
            jnp.asarray(self.pool.table), jnp.asarray(self.pool.lengths),
            jnp.asarray(wslot), jnp.asarray(woff), jnp.asarray(tokens))
        self.pool.kv = kv
        self.pool.states = states
        new_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        ctx_rows = 0
        for s in live:
            self.pool.lengths[s] += 1
            ctx_rows += int(self.pool.lengths[s])
            sl = self.slots[s]
            tok = int(new_tokens[s])
            sl.record.tokens.append(tok)
            sl.next_token = tok
            sl.remaining -= 1
            self.decode_tokens += 1
            self.kv_bytes_written += self._kv_bytes_per_token
        return ctx_rows

    def _decode_cost_ms(self) -> float:
        """Simulated cost of the tick's decode work (hook: the speculative
        engine charges draft + verify instead of one plain step)."""
        return self.config.decode_ms_per_step

    def _defrag(self) -> None:
        self.pool.defrag()

    def _evict(self, finish_ms: float) -> None:
        for s in [s for s, sl in self.slots.items() if sl.remaining <= 0]:
            sl = self.slots.pop(s)
            sl.record.finished_ms = finish_ms
            self.pool.free_slot(s)

    def step(self) -> bool:
        """One engine tick; returns False when nothing could progress (the
        caller should jump the clock to the next arrival)."""
        if self.dead:
            return False
        tick = self.steps
        if self.chaos is not None and not self._fail_fired:
            fail_tick = self.chaos.fails_at(self.peer_id)
            if fail_tick is not None and tick >= fail_tick:
                # a permanent failure fires exactly once: the tick counter
                # keeps counting after a checkpoint-recovery rejoin, so the
                # schedule must not re-kill the revived peer
                self._fail_fired = True
                self.die()
                return False
        t0 = self.now_ms
        self._intake()
        admitted_tokens = self._admit()
        newly = {s for s, sl in self.slots.items()
                 if sl.record.admitted_ms == self.now_ms}
        ctx_rows = self._decode_tick()
        decoded = ctx_rows > 0
        if admitted_tokens == 0 and not decoded:
            # single-token requests can still finish on prefill alone
            self._evict(self.now_ms)
            return False
        cost = (self.config.step_overhead_ms
                + self.config.prefill_ms_per_token * admitted_tokens
                + (self._decode_cost_ms() if decoded else 0.0))
        slow_mult = 1.0
        if self.chaos is not None:
            slow_mult = self.chaos.slowdown(self.peer_id, tick)
            cost *= slow_mult
            if self.health is not None:
                # the health signal IS the observed/clean cost ratio — what
                # a real router would estimate from tick latencies
                self.health.observe(slow_mult)
        self.now_ms += cost
        # first-token latencies must be read off before _evict pops any
        # single-step slot out of the slot table
        new_ttfts: List[float] = []
        for s in newly:
            rec = self.slots[s].record
            rec.first_token_ms = self.now_ms
            if rec.ttft_ms is not None:
                new_ttfts.append(rec.ttft_ms)
        self._evict(self.now_ms)
        self.steps += 1
        self.peak_utilization = max(self.peak_utilization,
                                    self.pool.utilization())
        if self.config.defrag_every and \
                self.steps % self.config.defrag_every == 0:
            self._defrag()
        if self.tracer is not None:
            self.tracer.complete(
                "tick", t0, self.now_ms, pid=self._pid, cat="engine",
                args={"tick": tick, "admitted_tokens": admitted_tokens,
                      "live_slots": len(self.slots),
                      "queued": len(self.waiting)})
            self.tracer.counter(
                "kv_pool", self.now_ms,
                {"utilization": round(self.pool.utilization(), 6),
                 "kv_bytes_written": self.kv_bytes_written}, pid=self._pid)
            if decoded:
                self.tracer.counter(
                    "decode_analytic", self.now_ms,
                    {"hbm_bytes": ctx_rows * self._kv_bytes_per_token,
                     "flops": ctx_rows * self._flops_per_ctx_row},
                    pid=self._pid)
        if self.metrics is not None:
            self.metrics.histogram("fleet/tick_cost_ms").observe(cost)
            self.metrics.gauge("fleet/kv_utilization").set(
                round(self.pool.utilization(), 6))
            for ttft in new_ttfts:
                self.metrics.histogram("fleet/ttft_live_ms").observe(ttft)
            if self.chaos is not None:
                # the live straggler signal: observed/clean tick-cost ratio
                self.metrics.gauge("fleet/slowdown").set(slow_mult)
            if admitted_tokens:
                self.metrics.counter("fleet/prefill_tokens").inc(
                    admitted_tokens)
            if ctx_rows:
                self.metrics.counter("fleet/decode_ctx_rows").inc(ctx_rows)
                self.metrics.counter("fleet/analytic_hbm_bytes").inc(
                    ctx_rows * self._kv_bytes_per_token)
                self.metrics.counter("fleet/analytic_flops").inc(
                    ctx_rows * self._flops_per_ctx_row)
        if self.chaos is not None:
            pause = self.chaos.pause_ms(self.peer_id, tick)
            if pause > 0:
                # preemption: clock jumps past the pause; slots stay frozen
                # (no decode progress), the router sees offline_until_ms
                self.offline_until_ms = self.now_ms + pause
                if self.tracer is not None:
                    self.tracer.instant("preempt", self.now_ms, pid=self._pid,
                                        cat="chaos", args={"pause_ms": pause})
                    self.tracer.complete("preempted", self.now_ms,
                                         self.offline_until_ms,
                                         pid=self._pid, cat="chaos")
                if self.watch is not None:
                    self.watch.note_fault(
                        "preempt", self.now_ms,
                        {"peer": self.peer_id, "pause_ms": pause,
                         "live_rids": sorted(
                             sl.record.request.rid
                             for sl in self.slots.values())})
                self.now_ms = self.offline_until_ms
                self.preemptions_hit += 1
        if self.watch is not None:
            self.watch.evaluate(self.now_ms)
        return True

    def advance_to(self, t_ms: float) -> None:
        """Run ticks until the clock reaches ``t_ms`` (or work runs dry,
        in which case the clock jumps forward — idle time is free). A dead
        engine only follows the clock."""
        while self.now_ms < t_ms:
            if not self.step():
                if self.dead:
                    self.now_ms = t_ms
                    break
                nxt = self.next_arrival_ms()
                self.now_ms = t_ms if nxt is None else min(t_ms,
                                                           max(nxt, self.now_ms))
                if nxt is None or nxt > t_ms:
                    break

    def drain(self) -> None:
        while self.slots or self.waiting or self.pending:
            if not self.step():
                if self.dead:
                    break            # router harvests what's left
                nxt = self.next_arrival_ms()
                if nxt is None:
                    break
                self.now_ms = max(self.now_ms, nxt)

    # ---- chaos lifecycle (no-ops on the clean path) ------------------------
    def die(self) -> None:
        """Permanent failure: KV state is gone; records stay attached so the
        router can harvest in-flight work for migration."""
        self.dead = True
        self.died_at_ms = self.now_ms
        if self.tracer is not None:
            self.tracer.instant("die", self.now_ms, pid=self._pid,
                                cat="chaos")
        if self.watch is not None:
            self.watch.note_fault(
                "fail", self.now_ms,
                {"peer": self.peer_id,
                 "live_rids": sorted(sl.record.request.rid
                                     for sl in self.slots.values())})

    def revive(self, t_ms: float, params: Optional[PyTree] = None,
               version: Optional[int] = None) -> None:
        """Rejoin after a permanent failure, from recovered weights.

        The router must have harvested the dead engine first — reviving
        with live slots would silently resurrect stale KV state.
        """
        assert self.dead, "revive() on a live engine"
        assert not self.slots and not self.waiting, \
            "revive() before harvest(): in-flight work would be resurrected"
        self.dead = False
        self.died_at_ms = None
        self.offline_until_ms = 0.0
        self.now_ms = max(self.now_ms, t_ms)
        if params is not None:
            self.set_params(params)
            if version is not None:
                self.weights_version = version
        if self.tracer is not None:
            self.tracer.instant("revive", self.now_ms, pid=self._pid,
                                cat="chaos")

    def harvest(self) -> List[RequestRecord]:
        """Strip every unfinished request (live slots, queued, future) for
        re-routing, freeing their blocks. Deterministic order: slots by slot
        id, then the waiting queue, then pending arrivals."""
        out: List[RequestRecord] = []
        for s in sorted(self.slots):
            sl = self.slots.pop(s)
            self.pool.free_slot(s)
            out.append(sl.record)
        out.extend(self.waiting)
        self.waiting.clear()
        out.extend(self.pending)
        self.pending.clear()
        for rec in out:
            rec.cancelled = True
        return out

    def cancel(self, rec: RequestRecord) -> None:
        """Remove one request wherever it sits (hedge loser / migration);
        identity-based — records compare by value, two copies of one hedged
        request must not alias."""
        self.pending = deque(r for r in self.pending if r is not rec)
        self.waiting = deque(r for r in self.waiting if r is not rec)
        for s, sl in list(self.slots.items()):
            if sl.record is rec:
                del self.slots[s]
                self.pool.free_slot(s)
        rec.cancelled = True
