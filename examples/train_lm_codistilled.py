"""End-to-end driver: codistill two ~25M-parameter qwen-family LMs for a few
hundred steps on synthetic Markov data, with the paper's full recipe —
prediction exchange + coordinated sampling, alpha schedule, decayed weight
decay, warmup + cosine LR, periodic eval, checkpointing.

    PYTHONPATH=src python examples/train_lm_codistilled.py [--steps 300]
    PYTHONPATH=src python examples/train_lm_codistilled.py --preset 100m

(defaults sized for this CPU container; --preset 100m is the same driver at
~100M params for real hardware or patient CPUs)
"""
import argparse
import json
import os
import time

from dataclasses import replace

from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.data import MarkovLM, make_lm_batch
from repro.models import build_model
from repro.train import stack_batches, train_codist
from repro.checkpoint import save_pytree

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--preset", default="25m", choices=["25m", "100m"])
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--out", default="results/train_lm_codistilled")
args = ap.parse_args()

if args.preset == "100m":
    cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=12, d_model=768,
                  head_dim=64, num_heads=12, num_kv_heads=12, d_ff=2048,
                  vocab_size=8192)
else:
    cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=8, d_model=384,
                  head_dim=48, num_heads=8, num_kv_heads=8, d_ff=1024,
                  vocab_size=4096)
model = build_model(cfg)
n_params = cfg.param_count()
print(f"model: {cfg.name} reduced, {n_params / 1e6:.1f}M params, "
      f"{cfg.num_layers}L d={cfg.d_model}")

task = MarkovLM(vocab=cfg.vocab_size, seed=0, effective_vocab=512)
tc = TrainConfig(lr=6e-4, lr_schedule="cosine", warmup_steps=30,
                 total_steps=args.steps, weight_decay=5e-4,
                 weight_decay_schedule=(5e-4, 1e-5, 0.0),
                 optimizer="adamw", seed=0)
codist = CodistConfig(n_models=2, mode="predictions", period=1,
                      distill_loss="mse", alpha0=1.0, alpha_growth=1.05,
                      steps_per_epoch=max(1, args.steps // 20),
                      burn_in_steps=20)


def batches(step):
    return stack_batches([
        make_lm_batch(task, args.batch, args.seq, step, None, seed=0)
        for _ in range(2)])


def eval_batches(step):
    return stack_batches([
        make_lm_batch(task, args.batch, args.seq, 50_000 + step, None, seed=1)
        for _ in range(2)])


t0 = time.time()
state, hist = train_codist(model, codist, tc, batches,
                           eval_batches=eval_batches, eval_every=50,
                           log_every=20, track_param_distance=True)
dt = time.time() - t0

for r in hist.records:
    line = (f"step {r['step']:4d}  task {r['task_loss']:.4f}  "
            f"distill {r.get('distill_loss', 0):.5f}  "
            f"alpha {r.get('alpha', 0):.2f}  wd {r.get('wd', 0):.1e}")
    if "eval_loss" in r:
        line += f"  eval {r['eval_loss']:.4f}"
    print(line, flush=True)

print(f"\n{args.steps} steps in {dt:.0f}s ({dt / args.steps * 1e3:.0f} ms/step)"
      f" — final eval loss {hist.last('eval_loss'):.4f}")
os.makedirs(args.out, exist_ok=True)
with open(os.path.join(args.out, "history.json"), "w") as f:
    json.dump(hist.records, f, indent=1)
save_pytree(os.path.join(args.out, "final"), state.params)
print(f"history + stacked checkpoint -> {args.out}/")
assert hist.last("eval_loss") < hist.records[0]["task_loss"], "did not learn"
print("PASS")
