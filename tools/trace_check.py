#!/usr/bin/env python3
"""Validate ``repro.obs`` artifacts: traces, metrics, alerts, postmortems.

    python tools/trace_check.py out.json [more.json ...]

The artifact kind is detected from the document shape — Chrome/Perfetto
trace (``traceEvents``), metrics registry dump (``counters``/``gauges``/
``histograms``), Watchtower postmortem bundle (``kind: postmortem``), and
alert JSONL (newline-delimited records with an ``alerts`` header line) —
and each gets its own schema-version + invariant checks.

Trace checks (exit 0 = every file valid, 1 = a violation, 2 = unreadable):

  * top-level schema: a ``traceEvents`` array plus the ``otherData`` clock
    stamp written by :class:`repro.obs.trace.Tracer`;
  * every event has a known ``ph`` phase and ``name``/``pid``/``tid``,
    integer ``ts >= 0`` (metadata events are pinned at ts 0);
  * the array is sorted by ``ts`` (the tracer's canonical order — a
    simulated clock never runs backwards);
  * complete events (``X``) carry integer ``dur >= 0``;
  * ``B``/``E`` spans balance per ``(pid, tid)`` track with LIFO name
    matching (spans nest);
  * nestable async spans (``b``/``e``) balance per ``(cat, id)`` — the
    per-request trees close even when a request migrates across peers;
  * async events (``b``/``e``/``n``) carry an ``id``.

Used by the ``trace-smoke`` CI job next to the byte-identity diff: the
diff proves determinism, this proves the file is a well-formed trace.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

PHASES = {"X", "B", "E", "b", "e", "n", "i", "C", "M"}


def check_events(events: List[Dict], errors: List[str]) -> None:
    last_ts = None
    open_sync: Dict[tuple, List[tuple]] = {}
    open_async: Dict[tuple, List[str]] = {}
    for i, ev in enumerate(events):
        where = f"event[{i}] {ev.get('name', '?')!r}"
        ph = ev.get("ph")
        if ph not in PHASES:
            errors.append(f"{where}: unknown ph {ph!r}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                errors.append(f"{where}: missing {key!r}")
        ts = ev.get("ts")
        if not isinstance(ts, int) or ts < 0:
            errors.append(f"{where}: ts must be a non-negative integer, "
                          f"got {ts!r}")
            continue
        if ph == "M":
            if ts != 0:
                errors.append(f"{where}: metadata events are pinned at ts 0")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(f"{where}: ts {ts} < previous event ts {last_ts} "
                          "(traceEvents must be sorted: simulated clocks "
                          "are monotonic)")
        last_ts = ts
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, int) or dur < 0:
                errors.append(f"{where}: X event needs integer dur >= 0, "
                              f"got {dur!r}")
        elif ph == "B":
            open_sync.setdefault(track, []).append((ev.get("name"), ts))
        elif ph == "E":
            stack = open_sync.get(track)
            if not stack:
                errors.append(f"{where}: E with no open B on track {track}")
            else:
                name, ts0 = stack.pop()
                if name != ev.get("name"):
                    errors.append(f"{where}: E closes {ev.get('name')!r} "
                                  f"but innermost open span is {name!r}")
                if ts < ts0:
                    errors.append(f"{where}: E at ts {ts} precedes its B "
                                  f"at ts {ts0}")
        elif ph in ("b", "e", "n"):
            if "id" not in ev:
                errors.append(f"{where}: async event missing id")
                continue
            key = (ev.get("cat"), ev.get("id"))
            if ph == "b":
                open_async.setdefault(key, []).append(ev.get("name"))
            elif ph == "e":
                stack = open_async.get(key)
                if not stack:
                    errors.append(f"{where}: async e with no open b for "
                                  f"(cat, id)={key}")
                elif stack[-1] != ev.get("name"):
                    errors.append(f"{where}: async e closes "
                                  f"{ev.get('name')!r} but innermost open "
                                  f"async span is {stack[-1]!r}")
                else:
                    stack.pop()
    for track, stack in sorted(open_sync.items(), key=str):
        for name, ts0 in stack:
            errors.append(f"span {name!r} on track {track} opened at ts "
                          f"{ts0} never closed")
    for key, stack in sorted(open_async.items(), key=str):
        for name in stack:
            errors.append(f"async span {name!r} for (cat, id)={key} "
                          "never closed")


METRICS_SCHEMA_VERSION = 1   # repro.obs.metrics.METRICS_SCHEMA_VERSION
ALERTS_SCHEMA_VERSION = 1    # repro.obs.watch.ALERTS_SCHEMA_VERSION
POSTMORTEM_SCHEMA_VERSION = 1  # repro.obs.recorder.POSTMORTEM_SCHEMA_VERSION

ALERT_STATES = {"firing", "resolved"}


def _check_schema(doc: Dict, want: int, what: str, errors: List[str]) -> None:
    got = doc.get("schema_version")
    if got != want:
        errors.append(f"{what} schema_version must be {want}, got {got!r}")


def check_metrics(doc: Dict, errors: List[str]) -> int:
    """Metrics registry dump: three name->scalar/dict sections."""
    _check_schema(doc, METRICS_SCHEMA_VERSION, "metrics", errors)
    n = 0
    for section, leaf in (("counters", (int, float)),
                          ("gauges", (int, float)),
                          ("histograms", dict)):
        block = doc.get(section)
        if not isinstance(block, dict):
            errors.append(f"metrics section {section!r} missing or not "
                          "an object")
            continue
        n += len(block)
        for name, v in block.items():
            if not isinstance(v, leaf) or isinstance(v, bool):
                errors.append(f"metrics {section}[{name!r}]: expected "
                              f"{leaf}, got {type(v).__name__}")
            elif section == "histograms":
                for key in ("count", "p50", "p99"):
                    if key not in v:
                        errors.append(f"metrics histograms[{name!r}] "
                                      f"missing {key!r}")
    return n


def check_postmortem(doc: Dict, errors: List[str]) -> int:
    """Flight-recorder bundle: reason, int ts, sorted (ts, seq) events."""
    _check_schema(doc, POSTMORTEM_SCHEMA_VERSION, "postmortem", errors)
    for key in ("reason", "ts", "events", "n_events_seen"):
        if key not in doc:
            errors.append(f"postmortem missing {key!r}")
    if not isinstance(doc.get("ts", 0), int) or doc.get("ts", 0) < 0:
        errors.append(f"postmortem ts must be a non-negative integer, "
                      f"got {doc.get('ts')!r}")
    events = doc.get("events", [])
    if not isinstance(events, list):
        errors.append("postmortem events is not an array")
        return 0
    last = None
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ts" not in ev:
            errors.append(f"events[{i}]: expected a trace event with 'ts'")
            continue
        ts = ev["ts"]
        if not isinstance(ts, int) or ts < 0:
            errors.append(f"events[{i}]: ts must be a non-negative "
                          f"integer, got {ts!r}")
            continue
        if last is not None and ts < last:
            errors.append(f"events[{i}]: ts {ts} < previous {last} "
                          "(ring must dump sorted)")
        last = ts
    n_seen = doc.get("n_events_seen")
    if isinstance(n_seen, int) and n_seen < len(events):
        errors.append(f"n_events_seen {n_seen} < {len(events)} events in "
                      "the bundle (ring bound violated)")
    return len(events)


def check_alerts(lines: List[Dict], errors: List[str]) -> int:
    """Watchtower JSONL: an ``alerts`` header then (ts, seq)-sorted
    firing/resolved transitions."""
    if not lines:
        errors.append("empty alert log (expected at least a header line)")
        return 0
    head = lines[0]
    if not isinstance(head, dict) or head.get("kind") != "alerts":
        errors.append("first line is not an alerts header "
                      "(kind: 'alerts')")
    else:
        _check_schema(head, ALERTS_SCHEMA_VERSION, "alerts", errors)
        for key in ("clock", "unit_us", "n_rules"):
            if key not in head:
                errors.append(f"alerts header missing {key!r}")
    last = None
    for i, ev in enumerate(lines[1:], 1):
        where = f"line[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        for key in ("ts", "seq", "rule", "state", "metric"):
            if key not in ev:
                errors.append(f"{where}: missing {key!r}")
        ts = ev.get("ts")
        if not isinstance(ts, int) or ts < 0:
            errors.append(f"{where}: ts must be a non-negative integer, "
                          f"got {ts!r}")
            continue
        if ev.get("state") not in ALERT_STATES:
            errors.append(f"{where}: state must be one of "
                          f"{sorted(ALERT_STATES)}, got {ev.get('state')!r}")
        key = (ts, ev.get("seq", 0))
        if last is not None and key < last:
            errors.append(f"{where}: (ts, seq) {key} < previous {last} "
                          "(alert log must be sorted)")
        last = key
    return len(lines) - 1


def check_file(path: str) -> List[str]:
    errors: List[str] = []
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        # not one JSON document: alert JSONL (one record per line)
        lines = [json.loads(ln) for ln in text.splitlines() if ln.strip()]
        check_alerts(lines, errors)
        return [f"{path}: {e}" for e in errors]
    if isinstance(doc, dict) and "traceEvents" in doc:
        other = doc.get("otherData")
        if not isinstance(other, dict) or "clock" not in other \
                or "schema_version" not in other:
            errors.append("missing otherData clock/schema_version "
                          "stamp (not produced by repro.obs?)")
        events = doc["traceEvents"]
        if not isinstance(events, list):
            return [f"{path}: traceEvents is not an array"]
        check_events(events, errors)
    elif isinstance(doc, dict) and doc.get("kind") == "postmortem":
        check_postmortem(doc, errors)
    elif isinstance(doc, dict) and "counters" in doc and "gauges" in doc:
        check_metrics(doc, errors)
    elif isinstance(doc, dict) and doc.get("kind") == "alerts":
        # a single-line alert log still parses as one JSON value only if
        # it has no events; treat the header alone as a valid empty log
        check_alerts([doc], errors)
    else:
        return [f"{path}: unrecognized obs artifact (expected a trace, "
                "metrics dump, alert JSONL, or postmortem bundle)"]
    return [f"{path}: {e}" for e in errors]


def _describe(path: str) -> str:
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        n = sum(1 for ln in text.splitlines() if ln.strip()) - 1
        return f"alert log, {n} events"
    if isinstance(doc, dict) and "traceEvents" in doc:
        return f"trace, {len(doc['traceEvents'])} events"
    if isinstance(doc, dict) and doc.get("kind") == "postmortem":
        return (f"postmortem {doc.get('reason', '?')!r}, "
                f"{len(doc.get('events', []))} ring events")
    if isinstance(doc, dict) and "counters" in doc:
        n = sum(len(doc.get(s, {}))
                for s in ("counters", "gauges", "histograms"))
        return f"metrics, {n} streams"
    return "alert log, 0 events"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python tools/trace_check.py",
        description="Validate repro.obs artifacts (traces, metrics dumps, "
                    "alert JSONL, postmortem bundles).")
    ap.add_argument("traces", nargs="+", help="obs artifact files to check")
    args = ap.parse_args(argv)
    failed = False
    for path in args.traces:
        try:
            errors = check_file(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable ({type(e).__name__}: {e})",
                  file=sys.stderr)
            return 2
        if errors:
            failed = True
            for e in errors:
                print(e, file=sys.stderr)
        else:
            print(f"{path}: OK ({_describe(path)})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
