"""Hypothesis property tests for the observability layer: span nesting /
monotonic-clock invariants of ``repro.obs.trace.Tracer`` and the exact-
quantile guarantee of ``repro.obs.metrics.Histogram``.

Lives apart from ``tests/test_obs.py`` so the deterministic obs tests run
even where the optional ``hypothesis`` dev dependency isn't installed
(this module skips cleanly, same pattern as ``tests/test_property.py``).
"""
import os
import sys

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs import Histogram, TraceError, Tracer  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import trace_check  # noqa: E402

S = settings(max_examples=25, deadline=None)


class TestTracerProperties:
    @S
    @given(durs=st.lists(st.floats(0.0, 10.0), min_size=1, max_size=8),
           t0=st.floats(0.0, 100.0))
    def test_nested_spans_always_validate(self, durs, t0):
        """Any properly-nested LIFO span stack with non-decreasing times
        exports a validator-clean trace."""
        tr = Tracer(unit_us=1000.0)
        t = t0
        for i, d in enumerate(durs):
            tr.begin(f"s{i}", t, pid=0, tid=0)
            t += d
        for i in reversed(range(len(durs))):
            tr.end(f"s{i}", t, pid=0, tid=0)
            t += 0.5
        doc = tr.to_dict()
        assert trace_check.check_events(doc["traceEvents"]) == []
        assert not tr.open_spans()

    @S
    @given(ts=st.lists(st.floats(0.0, 1000.0), min_size=2, max_size=16))
    def test_export_order_is_time_sorted(self, ts):
        tr = Tracer(unit_us=1000.0)
        for i, t in enumerate(ts):
            tr.instant(f"e{i}", t, pid=0, tid=0)
        out = [e["ts"] for e in tr.to_dict()["traceEvents"]]
        assert out == sorted(out)

    @S
    @given(back=st.floats(0.001, 50.0), t=st.floats(1.0, 100.0))
    def test_backwards_clock_always_raises(self, back, t):
        tr = Tracer()
        tr.begin("a", t, pid=0, tid=0)
        with pytest.raises(TraceError):
            tr.end("a", t - back, pid=0, tid=0)


class TestHistogramProperties:
    @S
    @given(vals=st.lists(st.floats(0.0, 1e6), min_size=1, max_size=200),
           q=st.floats(0.0, 100.0))
    def test_percentile_matches_numpy_exactly(self, vals, q):
        h = Histogram()
        for v in vals:
            h.observe(v)
        assert h.percentile(q) == float(np.percentile(np.asarray(vals), q))

    @S
    @given(vals=st.lists(st.floats(0.0, 1e4), min_size=0, max_size=100))
    def test_bucket_counts_partition_the_samples(self, vals):
        h = Histogram()
        for v in vals:
            h.observe(v)
        d = h.to_dict()
        assert sum(d["buckets"].values()) == len(vals)
        assert d["count"] == len(vals)
        if vals:
            assert d["sum"] == pytest.approx(sum(vals))
