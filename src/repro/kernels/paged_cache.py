"""Paged KV-cache gather/scatter Pallas kernels for the serving fleet.

The continuous batcher (``repro.serve.fleet``) stores decode-time KV in a
shared block pool ``(num_blocks, block_size, KV, hd)`` instead of one dense
``(B, cap, ...)`` buffer per call: a request owns ``ceil(ctx/block_size)``
blocks named by a per-slot block table, so HBM holds only live context and
slots of wildly different lengths share one allocation. Block 0 is the
reserved NULL block — never allocated, all-zero — and every dead table entry
points at it, which keeps the BlockSpec index maps total.

Two kernels move data between the pool and the decode step:

  ``paged_gather``   (pool, table, n_live) -> (S, MB*BS, KV, hd)
      grid (S, MB); program (s, m) DMAs pool block ``table[s, m]`` into the
      slot's contiguous view, zeroing blocks past ``n_live[s]`` — decode
      reads only live blocks (dead entries all alias the one null block).
  ``paged_scatter``  (pool, new, write_slot, write_off) -> pool
      grid (num_blocks,); the inverse block->writer map (computed host-side
      by the allocator: ``write_slot[b]`` = slot appending into block b this
      step, -1 = untouched) makes every output block written exactly once,
      so the update needs no atomics and no partially-covered outputs.

Quantized pools (``cache_dtype`` int8 / fp8) store one fp32 scale per
token row alongside the pool in a ``(num_blocks, block_size)`` array:
``paged_scatter_quant`` is the fused scatter variant that computes the
row's absmax scale and quantizes INSIDE the kernel (one pass, nothing
dequantized in HBM), and ``quantize_rows`` is the jnp row quantizer the
pool uses at prefill-insert time. Scale 0 (the null block, never written)
dequantizes to exactly 0, so the null-block invariant extends to scales.

All kernels use ``PrefetchScalarGridSpec``: the table / write maps are
scalar-prefetched so the index maps can compute DMA sources before the body
runs. Interpret mode on CPU, Mosaic on TPU (``auto_interpret``), with jnp
oracles (``*_ref``) pinned against the kernels in tests/test_kernels.py and
tests/test_paged_attention.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ----------------------------------------------------------------------------
# gather: pool blocks -> per-slot contiguous KV
# ----------------------------------------------------------------------------

def _gather_kernel(table_ref, nlive_ref, pool_ref, out_ref):
    s, m = pl.program_id(0), pl.program_id(1)
    live = m < nlive_ref[s]
    blk = pool_ref[0]
    out_ref[0, 0] = jnp.where(live, blk, jnp.zeros_like(blk))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gather(pool: jax.Array, table: jax.Array, n_live: jax.Array,
                 interpret: Optional[bool] = None) -> jax.Array:
    """pool (NB, BS, KV, hd); table (S, MB) int32; n_live (S,) int32 live
    blocks per slot. Returns (S, MB*BS, KV, hd): slot s's context at
    positions [0, n_live[s]*BS), zeros beyond."""
    if interpret is None:
        from repro.kernels.ops import auto_interpret
        interpret = auto_interpret()
    nb, bs, kv, hd = pool.shape
    s, mb = table.shape
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(s, mb),
            in_specs=[pl.BlockSpec((1, bs, kv, hd),
                                   lambda si, mi, t, nl: (t[si, mi], 0, 0, 0))],
            out_specs=pl.BlockSpec((1, 1, bs, kv, hd),
                                   lambda si, mi, t, nl: (si, mi, 0, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((s, mb, bs, kv, hd), pool.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), n_live.astype(jnp.int32), pool)
    return out.reshape(s, mb * bs, kv, hd)


def paged_gather_ref(pool: jax.Array, table: jax.Array,
                     n_live: jax.Array) -> jax.Array:
    """jnp oracle for ``paged_gather``."""
    s, mb = table.shape
    _, bs, kv, hd = pool.shape
    g = pool[table]                                     # (S, MB, BS, KV, hd)
    live = jnp.arange(mb)[None, :] < n_live[:, None]    # (S, MB)
    g = jnp.where(live[..., None, None, None], g, 0.0)
    return g.reshape(s, mb * bs, kv, hd)


# ----------------------------------------------------------------------------
# scatter: one new KV row per appending slot -> its (block, offset)
# ----------------------------------------------------------------------------

def _scatter_kernel(wslot_ref, woff_ref, new_ref, pool_ref, out_ref, *,
                    block_size: int):
    b = pl.program_id(0)
    w = wslot_ref[b]
    off = woff_ref[b]
    src = pl.load(new_ref, (pl.dslice(jnp.maximum(w, 0), 1),
                            slice(None), slice(None)))      # (1, KV, hd)
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_size, 1, 1), 0)
    mask = (rows == off) & (w >= 0)
    out_ref[0] = jnp.where(mask, src, pool_ref[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_scatter(pool: jax.Array, new: jax.Array, write_slot: jax.Array,
                  write_off: jax.Array,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Append one KV row per active slot into its owned block.

    pool (NB, BS, KV, hd); new (S, KV, hd); write_slot (NB,) int32 = the
    slot appending into block b this step (-1: block untouched); write_off
    (NB,) int32 = row within the block. The block->writer inversion is the
    allocator's (slots own disjoint blocks, so at most one writer per block)
    and makes each output block written exactly once.
    """
    if interpret is None:
        from repro.kernels.ops import auto_interpret
        interpret = auto_interpret()
    nb, bs, kv, hd = pool.shape
    s = new.shape[0]
    return pl.pallas_call(
        functools.partial(_scatter_kernel, block_size=bs),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nb,),
            in_specs=[
                pl.BlockSpec((s, kv, hd), lambda b, ws, wo: (0, 0, 0)),
                pl.BlockSpec((1, bs, kv, hd), lambda b, ws, wo: (b, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bs, kv, hd),
                                   lambda b, ws, wo: (b, 0, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        interpret=interpret,
    )(write_slot.astype(jnp.int32), write_off.astype(jnp.int32),
      new.astype(pool.dtype), pool)


def paged_scatter_ref(pool: jax.Array, new: jax.Array, write_slot: jax.Array,
                      write_off: jax.Array) -> jax.Array:
    """jnp oracle for ``paged_scatter``."""
    nb, bs, _, _ = pool.shape
    rows = jnp.arange(bs)[None, :]
    mask = (write_slot >= 0)[:, None] & (rows == write_off[:, None])  # (NB,BS)
    src = new.astype(pool.dtype)[jnp.clip(write_slot, 0)]             # (NB,KV,hd)
    return jnp.where(mask[..., None, None], src[:, None], pool)


# ----------------------------------------------------------------------------
# quantized pools: per-row fp32 scales, fused quantize-at-scatter
# ----------------------------------------------------------------------------

# absmax of the representable range per quantized cache dtype
_QMAX = {"int8": 127.0, "float8_e4m3fn": 448.0}


def is_quantized_dtype(dtype) -> bool:
    """True for the quantized KV-pool dtypes (int8 / fp8)."""
    return jnp.dtype(dtype).name in _QMAX


def quantized_dtype_names():
    return tuple(sorted(_QMAX))


def _quantize(x: jax.Array, inv_scale: jax.Array, dtype) -> jax.Array:
    """fp32 -> quantized storage given the reciprocal row scale (already
    broadcast against x). int8 rounds-to-even then clips; fp8 is a plain
    dtype conversion (values are in range by construction of the scale)."""
    y = x.astype(jnp.float32) * inv_scale
    if jnp.dtype(dtype).name == "int8":
        return jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    return y.astype(dtype)


def quantize_rows(x: jax.Array, dtype):
    """Quantize ``x (..., KV, hd)`` with one fp32 absmax scale per leading
    index (a "row" = one stored token position across all KV heads).
    Returns ``(q, scales)`` with ``scales = x.shape[:-2]``; all-zero rows
    get scale 0 (and dequantize to exactly 0)."""
    qmax = _QMAX[jnp.dtype(dtype).name]
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1))
    scales = absmax / qmax
    inv = jnp.where(scales > 0, 1.0 / jnp.maximum(scales, 1e-30), 0.0)
    return _quantize(x, inv[..., None, None], dtype), scales


def _scatter_quant_kernel(wslot_ref, woff_ref, new_ref, pool_ref, sc_ref,
                          out_ref, osc_ref, *, block_size: int, qmax: float,
                          out_dtype):
    b = pl.program_id(0)
    w = wslot_ref[b]
    off = woff_ref[b]
    src = pl.load(new_ref, (pl.dslice(jnp.maximum(w, 0), 1),
                            slice(None), slice(None)))      # (1, KV, hd)
    src = src.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(src))
    scale = absmax / qmax
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    qrow = _quantize(src, inv, out_dtype)
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_size, 1, 1), 0)
    mask = (rows == off) & (w >= 0)
    out_ref[0] = jnp.where(mask, qrow, pool_ref[0])
    rows2 = jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    mask2 = (rows2 == off) & (w >= 0)
    osc_ref[...] = jnp.where(mask2, scale, sc_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_scatter_quant(pool: jax.Array, scales: jax.Array, new: jax.Array,
                        write_slot: jax.Array, write_off: jax.Array,
                        interpret: Optional[bool] = None):
    """``paged_scatter`` fused with row quantization: the appended fp32 KV
    row is absmax-scaled and stored quantized, its scale written into the
    ``(NB, BS)`` per-row scale array. Returns ``(pool, scales)``.
    Same writer-map contract as ``paged_scatter``."""
    if interpret is None:
        from repro.kernels.ops import auto_interpret
        interpret = auto_interpret()
    nb, bs, kv, hd = pool.shape
    s = new.shape[0]
    qmax = _QMAX[jnp.dtype(pool.dtype).name]
    return pl.pallas_call(
        functools.partial(_scatter_quant_kernel, block_size=bs, qmax=qmax,
                          out_dtype=pool.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nb,),
            in_specs=[
                pl.BlockSpec((s, kv, hd), lambda b, ws, wo: (0, 0, 0)),
                pl.BlockSpec((1, bs, kv, hd), lambda b, ws, wo: (b, 0, 0, 0)),
                pl.BlockSpec((1, bs), lambda b, ws, wo: (b, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, bs, kv, hd), lambda b, ws, wo: (b, 0, 0, 0)),
                pl.BlockSpec((1, bs), lambda b, ws, wo: (b, 0)),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct(pool.shape, pool.dtype),
                   jax.ShapeDtypeStruct(scales.shape, jnp.float32)],
        interpret=interpret,
    )(write_slot.astype(jnp.int32), write_off.astype(jnp.int32),
      new.astype(jnp.float32), pool, scales.astype(jnp.float32))


def paged_scatter_quant_ref(pool: jax.Array, scales: jax.Array,
                            new: jax.Array, write_slot: jax.Array,
                            write_off: jax.Array):
    """jnp oracle for ``paged_scatter_quant``."""
    nb, bs, _, _ = pool.shape
    rows = jnp.arange(bs)[None, :]
    mask = (write_slot >= 0)[:, None] & (rows == write_off[:, None])  # (NB,BS)
    src = new[jnp.clip(write_slot, 0)]                                # (NB,KV,hd)
    qrow, sc = quantize_rows(src, pool.dtype)                         # (NB,), ...
    out = jnp.where(mask[..., None, None], qrow[:, None], pool)
    return out, jnp.where(mask, sc[:, None], scales.astype(jnp.float32))
