"""Paper-grid experiment harness + CI benchmark regression gate.

Covers the ISSUE-4 acceptance surface: spec expansion count / dedup /
canonicalization, the YAML/JSON loader and the two committed specs, seeded
cell determinism (same spec+seed => bit-identical final losses), crash-safe
resume (valid results skipped, corrupt ones re-run), aggregate math pinned
on a synthetic fixture, and the ``tools/bench_compare.py`` gate fed a
doctored regressed row.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro.experiments import (AlphaPoint, LRPoint, SweepSpec,
                               TINY_OVERRIDES, aggregate, cell_paths,
                               load_spec, run_cell, run_sweep,
                               spec_from_dict, summary_is_valid,
                               sweep_dir_for, write_outputs)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def tiny_spec(**kw) -> SweepSpec:
    base = dict(name="t", seq_len=8, steps=3, batch_sizes=(2,),
                modes=("allreduce", "codist"),
                alpha_schedules=(AlphaPoint("const"),), peers=(2,),
                model_overrides=TINY_OVERRIDES)
    base.update(kw)
    return SweepSpec(**base)


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


# ----------------------------------------------------------------------------
# spec expansion
# ----------------------------------------------------------------------------

class TestSpec:
    def test_expansion_count_and_dedup(self):
        spec = tiny_spec(
            batch_sizes=(2, 4), seeds=(0, 1),
            alpha_schedules=(AlphaPoint("const"),
                             AlphaPoint("burnin", burn_in_frac=0.25)),
            peers=(2, 4))
        cells = spec.cells()
        # raw cross-product is 2*2*2*2*2 = 32 per-mode... but allreduce
        # collapses alpha x peers: per batch = 2 seeds (allreduce)
        # + 2 alpha * 2 peers * 2 seeds (codist) = 10; two batches => 20
        assert len(cells) == 20
        ids = [c.cell_id for c in cells]
        assert len(ids) == len(set(ids))

    def test_allreduce_canonicalization(self):
        cells = tiny_spec().cells()
        base = [c for c in cells if c.mode == "allreduce"]
        assert base and all(c.peers == 1 and c.alpha.name == "none"
                            for c in base)

    def test_baseline_first_ordering(self):
        cells = tiny_spec(batch_sizes=(2, 4)).cells()
        for batch in (2, 4):
            group = [c for c in cells if c.batch == batch]
            assert group[0].mode == "allreduce"

    def test_lr_linear_scaling(self):
        lr = LRPoint("scaled", lr=1e-3, scale_with_batch=True,
                     base_batch=256)
        assert lr.resolve_lr(256) == pytest.approx(1e-3)
        assert lr.resolve_lr(64) == pytest.approx(2.5e-4)
        assert LRPoint("flat", lr=1e-3).resolve_lr(64) == pytest.approx(1e-3)

    def test_validation_errors(self):
        with pytest.raises(ValueError, match="unknown mode"):
            tiny_spec(modes=("codist", "nope"))
        with pytest.raises(ValueError, match="duplicate"):
            tiny_spec(alpha_schedules=(AlphaPoint("a"), AlphaPoint("a")))
        # distinct names that SLUG identically would silently merge cells
        with pytest.raises(ValueError, match="after slugging"):
            tiny_spec(alpha_schedules=(AlphaPoint("run-1"),
                                       AlphaPoint("run_1", alpha0=0.5)))
        with pytest.raises(ValueError, match="after slugging"):
            tiny_spec(lr_schedules=(LRPoint("a b"), LRPoint("a_b", lr=1e-4)))
        with pytest.raises(ValueError, match="unknown spec field"):
            spec_from_dict({"name": "x", "not_a_field": 1})
        # an empty axis must not silently expand to a zero-cell sweep
        with pytest.raises(ValueError, match="non-empty"):
            tiny_spec(peers=())
        with pytest.raises(ValueError, match="non-empty"):
            tiny_spec(batch_sizes=())

    def test_loader_json_and_yaml_roundtrip(self, tmp_path):
        doc = {"name": "rt", "steps": 7, "batch_sizes": [2, 4],
               "modes": ["allreduce", "codist"],
               "lr_schedules": [{"name": "c", "kind": "cosine", "lr": 2e-3}],
               "alpha_schedules": [{"name": "ramp", "alpha0": 0.3,
                                    "growth": 1.1}],
               "peers": [2], "model_overrides": {"d_model": 64}}
        jpath = tmp_path / "s.json"
        jpath.write_text(json.dumps(doc))
        spec_j = load_spec(str(jpath))
        assert spec_j.steps == 7
        assert spec_j.lr_schedules[0].lr == pytest.approx(2e-3)
        assert spec_j.alpha_schedules[0].growth == pytest.approx(1.1)
        assert dict(spec_j.model_overrides) == {"d_model": 64}

        yaml = pytest.importorskip("yaml")
        ypath = tmp_path / "s.yaml"
        ypath.write_text(yaml.safe_dump(doc))
        assert load_spec(str(ypath)) == spec_j

    def test_committed_specs_expand(self):
        small = load_spec(os.path.join(
            REPO, "experiments", "specs", "paper_grid_small.yaml"))
        cells = small.cells()
        assert len(cells) == 6  # pinned: the CI spec's documented size
        modes = {c.mode for c in cells}
        assert modes == {"allreduce", "codist"}
        full = load_spec(os.path.join(
            REPO, "experiments", "specs", "paper_grid.yaml"))
        assert len(full.cells()) == 888  # pinned: documented expansion


# ----------------------------------------------------------------------------
# runner: determinism + crash-safe resume
# ----------------------------------------------------------------------------

class TestRunner:
    def test_seeded_cell_determinism(self):
        spec = tiny_spec(modes=("codist",))
        (cell,) = spec.cells()
        s1, h1 = run_cell(cell)
        s2, h2 = run_cell(cell)
        # same spec + seed => bit-identical trajectory, not just close
        assert s1["final"]["task_loss"] == s2["final"]["task_loss"]
        assert h1.series("loss") == h2.series("loss")
        (other,) = tiny_spec(modes=("codist",), seeds=(1,)).cells()
        s3, _ = run_cell(other)
        assert s3["final"]["task_loss"] != s1["final"]["task_loss"]

    def test_resume_skips_completed_and_reruns_corrupt(self, tmp_path):
        spec = tiny_spec()
        out = str(tmp_path)
        first = run_sweep(spec, out, log=lambda _m: None)
        assert [r.status for r in first] == ["ran", "ran"]

        again = run_sweep(spec, out, resume=True, log=lambda _m: None)
        assert [r.status for r in again] == ["skipped", "skipped"]
        assert all(r.summary is not None for r in again)

        # a corrupt summary invalidates exactly that cell
        sweep_dir = sweep_dir_for(spec.name, out)
        victim = again[1].cell
        summary_path, _ = cell_paths(sweep_dir, victim)
        with open(summary_path, "w") as f:
            f.write("{not json")
        assert not summary_is_valid(sweep_dir, victim, victim.steps)
        third = run_sweep(spec, out, resume=True, log=lambda _m: None)
        assert sorted(r.status for r in third) == ["ran", "skipped"]

        # a different step count invalidates persisted results too
        assert not summary_is_valid(sweep_dir, again[0].cell, 99)

        # so does a spec edit that keeps every axis NAME but changes a
        # value (same cell_id, different experiment)
        relr = tiny_spec(lr_schedules=(LRPoint("cos", lr=5e-4),))
        for cell in relr.cells():
            assert not summary_is_valid(sweep_dir, cell, cell.steps)

        # end-to-end aggregate over the run directory
        doc = aggregate(sweep_dir, spec.name)
        assert doc["n_cells"] == 2
        by_mode = {r["mode"]: r for r in doc["grid"]}
        assert by_mode["allreduce"]["gap_vs_allreduce"] is None
        assert by_mode["codist"]["gap_vs_allreduce"] == pytest.approx(
            by_mode["codist"]["final_loss_mean"]
            - by_mode["allreduce"]["final_loss_mean"])
        assert by_mode["codist"]["comm_bytes_mean"] > 0
        json_path, md_path = write_outputs(doc, sweep_dir)
        assert os.path.exists(json_path) and os.path.exists(md_path)
        assert "gap vs all-reduce" in open(md_path).read()


# ----------------------------------------------------------------------------
# aggregate math on a synthetic fixture (no jax, exact numbers)
# ----------------------------------------------------------------------------

def _write_cell(sweep_dir, cell_id, mode, batch, lr, alpha, peers, seed,
                final_loss, records):
    os.makedirs(sweep_dir, exist_ok=True)
    with open(os.path.join(sweep_dir, f"{cell_id}.jsonl"), "w") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")
    summary = {
        "schema": 1, "status": "complete", "cell_id": cell_id,
        "cell": {"seed": seed},
        "grid_key": [mode, batch, lr, alpha, peers],
        "baseline_key": [batch, lr],
        "steps": records[-1]["step"] + 1,
        "final": {"task_loss": final_loss, "loss": final_loss,
                  "comm_bytes": records[-1].get("comm_bytes", 0),
                  "comm_events": len(records)},
    }
    with open(os.path.join(sweep_dir, f"{cell_id}.json"), "w") as f:
        json.dump(summary, f)


class TestAggregate:
    def test_aggregate_math(self, tmp_path):
        d = str(tmp_path / "synthetic")
        # baseline: two seeds, finals 1.0 and 2.0 => L* = 1.5; quality
        # levels 2.25 / 1.8 / 1.575 all crossed at step 1 (comm=20 resp 40)
        _write_cell(d, "ar-s0", "allreduce", 2, "cos", "none", 1, 0, 1.0,
                    [{"step": 0, "task_loss": 3.0, "comm_bytes": 10},
                     {"step": 1, "task_loss": 1.0, "comm_bytes": 20}])
        _write_cell(d, "ar-s1", "allreduce", 2, "cos", "none", 1, 1, 2.0,
                    [{"step": 0, "task_loss": 3.0, "comm_bytes": 20},
                     {"step": 1, "task_loss": 2.0, "comm_bytes": 40}])
        # codist: finals 2.0 and 2.5 => mean 2.25, range 0.5, gap +0.75;
        # seed 0 crosses 2.25 at step 1 (comm=8), seed 1 never does
        _write_cell(d, "co-s0", "codist", 2, "cos", "const", 2, 0, 2.0,
                    [{"step": 0, "task_loss": 3.0, "comm_bytes": 4},
                     {"step": 1, "task_loss": 2.0, "comm_bytes": 8}])
        _write_cell(d, "co-s1", "codist", 2, "cos", "const", 2, 1, 2.5,
                    [{"step": 0, "task_loss": 3.0, "comm_bytes": 4},
                     {"step": 1, "task_loss": 2.5, "comm_bytes": 8}])

        doc = aggregate(d, "synthetic")
        assert doc["n_cells"] == 4
        by_mode = {r["mode"]: r for r in doc["grid"]}
        ar, co = by_mode["allreduce"], by_mode["codist"]
        assert ar["final_loss_mean"] == pytest.approx(1.5)
        assert ar["final_loss_range"] == pytest.approx(1.0)
        assert ar["gap_vs_allreduce"] is None
        assert co["final_loss_mean"] == pytest.approx(2.25)
        assert co["final_loss_min"] == pytest.approx(2.0)
        assert co["final_loss_max"] == pytest.approx(2.5)
        assert co["final_loss_range"] == pytest.approx(0.5)
        assert co["gap_vs_allreduce"] == pytest.approx(0.75)
        assert co["seeds"] == [0, 1]
        # quality levels come off the baseline: factor * 1.5
        levels = doc["quality_levels"]["b2-cos@2steps"]
        assert levels["1.5x"] == pytest.approx(2.25)
        assert levels["1.05x"] == pytest.approx(1.575)
        # baseline crossings: mean(20, 40) = 30 at every level
        assert ar["bytes_to_quality"]["1.5x"] == pytest.approx(30.0)
        # codist: only seed 0 reaches 2.25 (at comm=8); seed 1 never does,
        # so the mean is over the cells that reached the level
        assert co["bytes_to_quality"]["1.5x"] == pytest.approx(8.0)
        assert co["bytes_to_quality"]["1.05x"] is None

    def test_aggregate_never_mixes_step_counts(self, tmp_path):
        # same cell ids re-run at a different --steps: rows must stay
        # separate and gaps only compare within equal training lengths
        d = str(tmp_path / "mixed")
        _write_cell(d, "ar-s0", "allreduce", 2, "cos", "none", 1, 0, 1.0,
                    [{"step": 0, "task_loss": 2.0, "comm_bytes": 10},
                     {"step": 1, "task_loss": 1.0, "comm_bytes": 20}])
        _write_cell(d, "co-s0", "codist", 2, "cos", "const", 2, 0, 1.5,
                    [{"step": 0, "task_loss": 2.0, "comm_bytes": 4},
                     {"step": 1, "task_loss": 1.5, "comm_bytes": 8},
                     {"step": 2, "task_loss": 1.5, "comm_bytes": 12}])
        doc = aggregate(d, "mixed")
        assert {r["steps"] for r in doc["grid"]} == {2, 3}
        co = next(r for r in doc["grid"] if r["mode"] == "codist")
        # no 2-step baseline exists for the 3-step codist run
        assert co["gap_vs_allreduce"] is None

    def test_aggregate_empty_dir(self, tmp_path):
        doc = aggregate(str(tmp_path), "empty")
        assert doc["n_cells"] == 0 and doc["grid"] == []
        # a sweep that never ran (no directory) aggregates empty, not a crash
        doc = aggregate(str(tmp_path / "never_ran"), "fresh")
        assert doc["n_cells"] == 0 and doc["grid"] == []

    def test_aggregate_filters_stale_cells(self, tmp_path):
        d = str(tmp_path / "s")
        _write_cell(d, "ar-s0", "allreduce", 2, "cos", "none", 1, 0, 1.0,
                    [{"step": 0, "task_loss": 1.0, "comm_bytes": 10}])
        # a leftover from a previous spec revision of the same name
        _write_cell(d, "stale-s0", "codist", 9, "old", "gone", 2, 0, 9.0,
                    [{"step": 0, "task_loss": 9.0, "comm_bytes": 1}])
        unfiltered = aggregate(d, "s")
        assert unfiltered["n_cells"] == 2
        doc = aggregate(d, "s", cell_ids={"ar-s0"})
        assert doc["n_cells"] == 1
        assert [r["mode"] for r in doc["grid"]] == ["allreduce"]


# ----------------------------------------------------------------------------
# CI benchmark regression gate (tools/bench_compare.py)
# ----------------------------------------------------------------------------

def _bench_doc(rows):
    return {"backend": "cpu", "quick": True, "rows": rows}


def _run_compare(tmp_path, base_rows, new_rows, *extra):
    bp, np_ = tmp_path / "base.json", tmp_path / "new.json"
    bp.write_text(json.dumps(_bench_doc(base_rows)))
    np_.write_text(json.dumps(_bench_doc(new_rows)))
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "bench_compare.py"),
         "--baseline", str(bp), "--new", str(np_), *extra],
        capture_output=True, text=True, cwd=REPO)


BASE_ROWS = [
    {"name": "throughput/strategy_prediction", "us_per_call": 100.0,
     "derived": "comm_bytes=524288"},
    {"name": "throughput/grad_ce_fused_vs_jnp", "us_per_call": 400.0,
     "derived": "1.0x_ref"},
]


class TestBenchCompare:
    def test_clean_run_passes(self, tmp_path):
        r = _run_compare(tmp_path, BASE_ROWS, BASE_ROWS)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "OK" in r.stdout

    def test_doctored_throughput_regression_fails(self, tmp_path):
        doctored = json.loads(json.dumps(BASE_ROWS))
        doctored[0]["us_per_call"] = 200.0  # 2x slower > 25% tolerance
        r = _run_compare(tmp_path, BASE_ROWS, doctored)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "REGRESSED" in r.stdout and "2.00x" in r.stdout
        # ...but a wide-enough tolerance waves the same rows through
        r2 = _run_compare(tmp_path, BASE_ROWS, doctored, "--tolerance", "1.5")
        assert r2.returncode == 0, r2.stdout + r2.stderr

    def test_min_us_floor_skips_micro_rows(self, tmp_path):
        doctored = json.loads(json.dumps(BASE_ROWS))
        doctored[1]["us_per_call"] = 4000.0  # 10x slower, but a micro row
        r = _run_compare(tmp_path, BASE_ROWS, doctored,
                         "--min-us", "10000")
        assert r.returncode == 0, r.stdout + r.stderr
        assert "min-us" in r.stdout
        # comm_bytes stays gated regardless of the floor
        doctored[0]["derived"] = "comm_bytes=1"
        r2 = _run_compare(tmp_path, BASE_ROWS, doctored,
                          "--min-us", "10000")
        assert r2.returncode == 1

    def test_comm_bytes_change_fails_exactly(self, tmp_path):
        doctored = json.loads(json.dumps(BASE_ROWS))
        doctored[0]["derived"] = "comm_bytes=524290"  # tiny but nonzero
        r = _run_compare(tmp_path, BASE_ROWS, doctored)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "comm_bytes" in r.stdout

    def test_comm_bytes_lost_on_one_side_fails(self, tmp_path):
        # a crashed sweep cell emits '-' instead of comm_bytes=N: the row
        # must regress, not fall through as "nothing to compare"
        doctored = json.loads(json.dumps(BASE_ROWS))
        doctored[0]["derived"] = "-"
        doctored[0]["us_per_call"] = 0.0
        r = _run_compare(tmp_path, BASE_ROWS, doctored)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "missing on the new side" in r.stdout

    def test_vanished_row_of_executed_benchmark_fails(self, tmp_path):
        # the throughput benchmark ran (one row present) but a variant
        # disappeared: its gates must not silently vacate
        r = _run_compare(tmp_path, BASE_ROWS, BASE_ROWS[:1])
        assert r.returncode == 1, r.stdout + r.stderr
        assert "missing from the new run" in r.stdout
        # ...whereas rows of a benchmark that did NOT run are not gated
        fault_extra = BASE_ROWS + [{"name": "fault/loss", "us_per_call": 0.0,
                                    "derived": "1.0"}]
        r2 = _run_compare(tmp_path, fault_extra, BASE_ROWS)
        assert r2.returncode == 0, r2.stdout + r2.stderr

    def test_disjoint_rows_is_usage_error(self, tmp_path):
        other = [{"name": "zzz/other", "us_per_call": 1.0, "derived": "x"}]
        r = _run_compare(tmp_path, BASE_ROWS, other)
        assert r.returncode == 2


# ----------------------------------------------------------------------------
# benchmarks.run --only validation (the registry bugfix)
# ----------------------------------------------------------------------------

def test_benchmarks_run_unknown_only_exits_2():
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only",
         "definitely_not_a_benchmark"],
        capture_output=True, text=True, cwd=REPO, env=_env(), timeout=120)
    assert r.returncode == 2, r.stdout + r.stderr
    assert "unknown benchmark" in r.stderr
    assert "registered:" in r.stderr and "sweep_smoke" in r.stderr


def test_benchmarks_run_misspelled_only_suggests_nearest():
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "servng"],
        capture_output=True, text=True, cwd=REPO, env=_env(), timeout=120)
    assert r.returncode == 2, r.stdout + r.stderr
    assert "unknown benchmark" in r.stderr
    assert "did you mean: serving" in r.stderr, r.stderr


# ----------------------------------------------------------------------------
# tools/ci_bitcheck.py — the shared smoke-job determinism gate
# ----------------------------------------------------------------------------

def _bitcheck(*argv):
    return subprocess.run(
        [sys.executable, "tools/ci_bitcheck.py", *argv],
        capture_output=True, text=True, cwd=REPO, env=_env(), timeout=60)


def test_ci_bitcheck_identical_reports_pass(tmp_path):
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text('{"completed": 16, "nested": {"digest": "abc"}}')
    b.write_text(a.read_text())
    r = _bitcheck(str(a), str(b), "--require", "nested.digest",
                  "--expect", "completed==16", "completed>=1")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_ci_bitcheck_divergent_reports_fail(tmp_path):
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text('{"completed": 16}')
    b.write_text('{"completed": 15}')
    r = _bitcheck(str(a), str(b))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "differ" in r.stderr


def test_ci_bitcheck_match_mode_compares_only_listed_keys(tmp_path):
    # --match: two DIFFERENT runs (spec vs plain) that must agree on the
    # stream digest but nothing else
    a = tmp_path / "spec.json"
    b = tmp_path / "plain.json"
    a.write_text('{"stream_digest": "abc", "spec_rounds": 7}')
    b.write_text('{"stream_digest": "abc", "spec_rounds": 0}')
    assert _bitcheck(str(a), str(b), "--match", "stream_digest").returncode == 0
    assert _bitcheck(str(a), str(b), "--match", "spec_rounds").returncode == 1


def test_ci_bitcheck_expect_failures_and_usage_errors(tmp_path):
    a = tmp_path / "a.json"
    a.write_text('{"rate": 0.4}')
    b = tmp_path / "b.json"
    b.write_text(a.read_text())
    r = _bitcheck(str(a), str(b), "--expect", "rate>0.5")
    assert r.returncode == 1 and "expect failed" in r.stderr
    r = _bitcheck(str(a), str(b), "--expect", "not an expression")
    assert r.returncode == 2
    r = _bitcheck(str(a), str(tmp_path / "missing.json"))
    assert r.returncode == 2
