"""One virtual peer: its train state, local step clock, burn-in gate, and
checkpoint-based recovery.

Every peer drives the SAME compiled :class:`~repro.train.engine.StepBundle`
(built once from the ``AsyncPrediction`` strategy — peers differ only in
their ``TrainState``), which is what lets a cluster of N peers cost N states
but a single compilation per variant.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.checkpoint.io import (has_snapshot, load_snapshot, save_snapshot)
from repro.train.loop import History


class PeerRuntime:
    """Host-side bookkeeping for one codistilling peer on its own clock."""

    def __init__(self, pid: int, state, *, burn_in: int = 0,
                 joined_at: float = 0.0):
        self.pid = pid
        self.state = state
        self.step = int(state.step)          # local step (mirrors state.step)
        self.alive = True
        self.finished = False
        self.burn_in = burn_in               # local steps before distilling
        self.joined_at = joined_at
        self.completed_at: Optional[float] = None
        self.hist = History()

    @property
    def distill_ready(self) -> bool:
        """Burn-in gate (the paper / Anil et al.): a freshly joined peer
        neither distills nor publishes until it has trained ``burn_in``
        local steps — random predictions would poison the cluster."""
        return self.step >= self.burn_in

    def advance(self, new_state) -> None:
        self.state = new_state
        self.step += 1

    def die(self) -> None:
        self.alive = False

    # ---- checkpoint-based recovery -----------------------------------------
    def snapshot(self, directory: str) -> None:
        # step metadata lets snapshot consumers (the serving fleet's
        # keep-last weight refresh) order snapshots without loading payloads
        save_snapshot(directory, self.pid, self.state,
                      meta={"step": self.step})

    def can_recover(self, directory: Optional[str]) -> bool:
        return directory is not None and has_snapshot(directory, self.pid)

    def restore(self, directory: str, rejoined_at: float) -> None:
        """Rejoin from the last snapshot: params/opt/step all rewind to the
        snapshot, so the peer replays the lost steps (and its mailbox
        payloads resume from there)."""
        self.state = load_snapshot(directory, self.pid, self.state)
        self.step = int(jax.device_get(self.state.step))
        self.alive = True
        self.joined_at = rejoined_at
