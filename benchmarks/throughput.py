"""Step-time microbenchmarks (CPU, tiny model): relative cost of the exchange
modes and the kernels vs their jnp references. Wall-clock on this container is
NOT TPU-predictive — roofline terms in the dry-run are — but relative step
structure (distill on/off, checkpoint n-forwards, pipelined replay) is."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import CodistConfig, TrainConfig
from repro.data import make_lm_batch
from repro.optim import make_optimizer
from repro.train import init_codist_state, stack_batches
from repro.train import steps as steps_mod

from benchmarks.common import lm_setup, timed


def run(quick: bool = False) -> List[Dict]:
    model, task = lm_setup()
    tc = TrainConfig(lr=1e-3, total_steps=100, optimizer="adamw")
    opt_init, _ = make_optimizer("adamw")
    state = init_codist_state(model, jax.random.key(0), 2, opt_init,
                              with_stale=True)
    batch = stack_batches([make_lm_batch(task, 8, 64, 0, None, seed=0)
                           for _ in range(2)])
    rows: List[Dict] = []
    variants = {
        "step_codist_distill": jax.jit(steps_mod.make_codist_step(
            model, CodistConfig(n_models=2), tc, True)),
        "step_codist_plain": jax.jit(steps_mod.make_codist_step(
            model, CodistConfig(n_models=2), tc, False)),
        "step_codist_topk": jax.jit(steps_mod.make_codist_step(
            model, CodistConfig(n_models=2, compression="topk", topk=16),
            tc, True)),
        "step_checkpoint_mode": jax.jit(steps_mod.make_codist_checkpoint_step(
            model, CodistConfig(n_models=2, mode="checkpoints"), tc)),
    }
    base_us = None
    for name, fn in variants.items():
        (_, m), us = timed(lambda f=fn: f(state, batch), warmup=1,
                           iters=2 if quick else 5)
        if name == "step_codist_plain":
            base_us = us
        rows.append({"name": f"throughput/{name}", "us_per_call": us,
                     "derived": round(float(m["loss"]), 4)})
    # relative overheads vs the no-distill step
    if base_us:
        for r in rows:
            if r["name"] != "throughput/step_codist_plain":
                r["derived"] = f"{r['us_per_call'] / base_us:.2f}x_plain"

    # kernels vs jnp references (interpret mode: correctness-path timing only)
    from repro.core import codistillation as cd
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    t, v = (256, 512) if quick else (512, 2048)
    lg = jax.random.normal(jax.random.key(0), (t, v))
    lb = jax.random.randint(jax.random.key(1), (t,), 0, v)
    tgt = jax.random.normal(jax.random.key(2), (t, v))
    _, us_k = timed(lambda: kops.cross_entropy_tokens(lg, lb, interpret=True),
                    iters=2)
    _, us_r = timed(lambda: kref.cross_entropy_ref(lg, lb), iters=2)
    rows.append({"name": "throughput/fused_ce_interp_vs_ref",
                 "us_per_call": us_k, "derived": f"{us_k / us_r:.1f}x_ref"})
    # both paper loss variants: mse (A.3) and kl (Anil-style)
    for mode in ("mse", "kl"):
        _, us_k = timed(lambda m=mode: kops.distill_loss_tokens(
            lg, tgt, mode=m, interpret=True), iters=2)
        ref_fn = kref.distill_mse_ref if mode == "mse" else kref.distill_kl_ref
        _, us_r = timed(lambda f=ref_fn: f(lg, tgt), iters=2)
        rows.append({"name": f"throughput/fused_distill_{mode}_interp_vs_ref",
                     "us_per_call": us_k,
                     "derived": f"{us_k / us_r:.1f}x_ref"})

    # GRADIENT timings: jax.grad through the custom-VJP kernels vs the jnp
    # losses (the training path the fused_losses flag switches)
    grad_pairs = {
        "ce": (
            jax.jit(jax.grad(lambda x: kops.fused_cross_entropy_loss(
                x, lb, 0.1, interpret=True))),
            jax.jit(jax.grad(lambda x: cd.cross_entropy(x, lb, 0.1,
                                                        fused=False))),
        ),
    }
    for mode in ("mse", "kl"):
        ref_loss = cd.distill_mse if mode == "mse" else cd.distill_kl
        grad_pairs[f"distill_{mode}"] = (
            jax.jit(jax.grad(lambda x, m=mode: kops.fused_distill_mean(
                x, tgt, m, interpret=True))),
            jax.jit(jax.grad(lambda x, f=ref_loss: f(x, tgt, fused=False))),
        )
    for name, (fused_g, ref_g) in grad_pairs.items():
        _, us_k = timed(lambda f=fused_g: f(lg), iters=2)
        _, us_r = timed(lambda f=ref_g: f(lg), iters=2)
        rows.append({"name": f"throughput/grad_{name}_fused_vs_jnp",
                     "us_per_call": us_k,
                     "derived": f"{us_k / us_r:.1f}x_ref"})
    return rows
