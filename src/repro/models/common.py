"""Shared layer primitives: inits, norms, embeddings, RoPE, dtype policy.

Parameters are plain nested dicts of jnp arrays (no flax) — this keeps the
stacked-model codistillation transform (leading ``n`` axis over the ``"pod"``
mesh axis) and scan-over-layers stacking (leading ``L`` axis) trivial pytree
operations.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


# ----------------------------------------------------------------------------
# initializers
# ----------------------------------------------------------------------------

def dense_init(key: jax.Array, in_dim: int, out_shape: Tuple[int, ...],
               dtype=jnp.float32, scale: float = 1.0) -> jax.Array:
    """Truncated-normal fan-in init for a (in_dim, *out_shape) matrix."""
    std = scale / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, *out_shape))
            * std).astype(dtype)


def embed_init(key: jax.Array, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def zeros(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.ones(shape, dtype)


class KeyGen:
    """Splitting helper: kg = KeyGen(key); w = init(kg(), ...)."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_rms_norm(d: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {"scale": ones((d,), dtype)}


def init_layer_norm(d: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {"scale": ones((d,), dtype), "bias": zeros((d,), dtype)}


def apply_norm(params: Dict[str, jax.Array], x: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    if "bias" in params:
        return layer_norm(x, params["scale"], params["bias"], eps)
    return rms_norm(x, params["scale"], eps)


# ----------------------------------------------------------------------------
# rotary position embeddings
# ----------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                     # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads: (..., S, 1, hd/2)
    cos = cos[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# embeddings / output head
# ----------------------------------------------------------------------------

def init_embedding(key: jax.Array, cfg: ModelConfig,
                   dtype=jnp.float32) -> Dict[str, jax.Array]:
    kg = KeyGen(key)
    p = {"tokens": embed_init(kg(), cfg.padded_vocab, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(kg(), cfg.d_model, (cfg.padded_vocab,), dtype)
    return p


def embed_tokens(params: Dict[str, jax.Array], tokens: jax.Array,
                 dtype) -> jax.Array:
    return params["tokens"].astype(dtype)[tokens]


def lm_head(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """Logits in the activation dtype (losses upcast per-shard to fp32 —
    keeping the (B,S,V) tensor in bf16 on TPU halves HBM and collective
    traffic for the dominant tensor of LM training)."""
    from repro.models.sharding_hints import hint
    if "head" in params:
        w = params["head"]
    else:
        w = params["tokens"].T
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    return hint(logits, "btv")


# ----------------------------------------------------------------------------
# activations
# ----------------------------------------------------------------------------

def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name in ("gelu", "geglu"):
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def count_params(tree: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))
