"""Timestamped peer-to-peer payload store with measured staleness.

In the synchronous engine the prediction exchange is a collective inside one
compiled step; in the async runtime peers run on independent step clocks, so
predictions (and checkpoint announcements) flow through this host-side
``Mailbox`` instead. Every payload carries the sender's local step and the
simulated post time; the consumer side applies the **staleness-bound
policy** from the paper's tolerance discussion:

  * ``bound=None``   keep-last: always distill against the newest payload,
                     however old (pipelined exchange taken to its limit);
  * ``bound=S``      drop: a payload older than ``S`` receiver-steps
                     contributes nothing (weight 0) — ``S=0`` accepts only
                     same-step payloads, reproducing the synchronous
                     prediction exchange exactly.

The mailbox also meters the bytes that would cross the slow links: a posted
payload costs its leaf bytes once per consumer that actually receives it
(re-reading a cached keep-last payload on later steps is free — the
receiver already holds it), which
``core.comm_model.bits_per_exchange_event`` must agree with
(``tests/test_comm_model.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def payload_bytes(payload: PyTree) -> int:
    return int(sum(np.asarray(x).nbytes for x in jax.tree.leaves(payload)))


@dataclass
class Payload:
    sender: int
    step: int          # sender's local step when posted
    time: float        # simulated post time
    data: PyTree


@dataclass
class StalenessStats:
    """Measured receiver_step - sender_step over accepted / offered payloads."""
    accepted: int = 0
    dropped: int = 0
    total: float = 0.0
    max: float = 0.0

    def record(self, staleness: float, ok: bool) -> None:
        if ok:
            self.accepted += 1
            self.total += staleness
            self.max = max(self.max, staleness)
        else:
            self.dropped += 1

    @property
    def mean(self) -> float:
        return self.total / self.accepted if self.accepted else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"staleness_mean": self.mean, "staleness_max": self.max,
                "payloads_accepted": self.accepted,
                "payloads_dropped": self.dropped}


class Mailbox:
    """Keep-last store of per-sender payloads, one slot per (sender, kind)."""

    def __init__(self, staleness_bound: Optional[int] = None):
        self.staleness_bound = staleness_bound
        self._slots: Dict[Tuple[int, str], Payload] = {}
        # (receiver, sender, kind) -> sender step last transferred, so a
        # keep-last payload re-read across several receiver steps is only
        # billed for the one transfer that physically happened
        self._delivered: Dict[Tuple[int, int, str], int] = {}
        self.stats = StalenessStats()
        self.bytes_posted = 0
        self.bytes_delivered = 0

    def post(self, sender: int, step: int, time: float, data: PyTree,
             kind: str = "predictions") -> None:
        self._slots[(sender, kind)] = Payload(sender, step, time, data)
        self.bytes_posted += payload_bytes(data)

    def peek(self, sender: int, kind: str = "predictions"
             ) -> Optional[Payload]:
        return self._slots.get((sender, kind))

    def drop_peer(self, sender: int) -> None:
        """Forget a failed peer's payloads (its predictions must not keep
        feeding the cluster after it is gone)."""
        for key in [k for k in self._slots if k[0] == sender]:
            del self._slots[key]

    def collect(self, receiver: int, receiver_step: int,
                senders: List[int], kind: str = "predictions"
                ) -> List[Tuple[int, Optional[Payload], float]]:
        """For each sender, the freshest payload and its acceptance weight.

        Returns ``[(sender, payload_or_None, weight)]``; weight is 0.0 when
        no payload exists or the drop policy rejects it (older than the
        bound in receiver steps). Accepted deliveries are metered as bytes
        crossing the slow links and their staleness recorded.
        """
        out: List[Tuple[int, Optional[Payload], float]] = []
        for s in senders:
            if s == receiver:
                continue
            p = self._slots.get((s, kind))
            if p is None:
                out.append((s, None, 0.0))
                continue
            staleness = float(receiver_step - p.step)
            ok = (self.staleness_bound is None
                  or staleness <= self.staleness_bound)
            self.stats.record(max(staleness, 0.0), ok)
            if ok and self._delivered.get((receiver, s, kind)) != p.step:
                self._delivered[(receiver, s, kind)] = p.step
                self.bytes_delivered += payload_bytes(p.data)
            out.append((s, p if ok else None, 1.0 if ok else 0.0))
        return out
