"""Quickstart: 2-way codistillation vs all_reduce on a tiny LM (CPU, ~2 min).

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's headline at smoke scale: two codistilling models
(batch B each, exchanging only predictions) track the loss of one all_reduce
model at batch 2B, while the Section-3 communication model shows the bits
saved on the cross-group links.
"""
import sys

from dataclasses import replace

from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.core import comm_model as cm
from repro.data import MarkovLM, make_lm_batch
from repro.models import build_model
from repro.train import stack_batches, train_allreduce, train_codist

STEPS = 60
B, S = 8, 64

cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=2, d_model=64,
              d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=2,
              head_dim=32)
model = build_model(cfg)
task = MarkovLM(vocab=64, seed=0)
tc = TrainConfig(lr=3e-3, total_steps=STEPS, warmup_steps=5,
                 optimizer="adamw", lr_schedule="cosine", seed=0)

print("== 2-way codistillation (prediction exchange, coordinated sampling) ==")
codist = CodistConfig(n_models=2, distill_loss="mse", alpha0=1.0)


def batches(step):
    return stack_batches([make_lm_batch(task, B, S, step, None, seed=0)
                          for _ in range(2)])


state_c, hist_c = train_codist(model, codist, tc, batches, log_every=10)
for r in hist_c.records:
    print(f"  step {r['step']:3d}  task {r['task_loss']:.4f}  "
          f"distill {r['distill_loss']:.5f}")
print(f"  observed exchange traffic: {hist_c.records[-1]['comm_events']:.0f} "
      f"events, {hist_c.records[-1]['comm_bytes']:.3e} bytes "
      f"(strategy.comm_bytes accounting)")

print("== all_reduce baseline (one model, batch 2B) ==")


def it():
    s = 0
    while True:
        yield make_lm_batch(task, 2 * B, S, s, None, seed=0)
        s += 1


state_a, hist_a = train_allreduce(model, tc, it(), log_every=10)
for r in hist_a.records:
    print(f"  step {r['step']:3d}  task {r['task_loss']:.4f}")

lc = hist_c.records[-1]["task_loss"]
la = hist_a.records[-1]["task_loss"]
print(f"\nfinal loss: codist {lc:.4f} vs all_reduce {la:.4f} "
      f"(gap {abs(lc - la) / la * 100:.1f}%)")

print("\n== Section-3 communication accounting (cross-group bits/iter) ==")
ar = cm.allreduce_bits(cm.model_bits(cfg))
pred = cm.codist_cost(cfg, codist, per_device_batch=B, seq_len=S)
pred5 = cm.codist_cost(cfg, replace(codist, period=5), per_device_batch=B,
                       seq_len=S)
ck = cm.codist_cost(cfg, replace(codist, mode="checkpoints", period=50),
                    per_device_batch=B, seq_len=S)
for c in (ar, pred, pred5, ck):
    print(f"  {c.scheme:18s} {c.bits_per_iter_per_device:12.3e} bits/iter "
          f"({c.ratio_vs(ar):8.1f}x fewer than all_reduce)")

ok = abs(lc - la) / la < 0.15
print("\nPASS" if ok else "\nWARN: loss gap larger than expected")
sys.exit(0 if ok else 1)
