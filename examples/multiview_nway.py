"""Section-5.1 multi-view study: when does n-way (n>2) codistillation help?

    PYTHONPATH=src python examples/multiview_nway.py

Reproduces the Figure-6 pattern on the controlled synthetic multi-view task:
models restricted to DIFFERENT views gain monotonically with n; models
sharing ONE view do not (beyond the small n=2 bump).
"""
from repro.configs import CodistConfig, TrainConfig
from repro.models.mlp import MLP, MLPConfig
from repro.train import train_codist

import sys
sys.path.insert(0, ".")
from benchmarks.fig6_multiview import TASK, _batches, _eval_acc  # noqa: E402

STEPS = 400
model = MLP(MLPConfig(in_dim=TASK.dim, hidden=(128, 128),
                      num_classes=TASK.num_classes))
tc = TrainConfig(lr=3e-3, total_steps=STEPS, warmup_steps=5,
                 optimizer="adamw", lr_schedule="cosine", seed=0)

results = {}
for scenario in ("enforced", "shared"):
    print(f"== scenario: {scenario} "
          f"({'models see different views' if scenario == 'enforced' else 'all models share one view'}) ==")
    for n in (1, 2, 4, 8):
        codist = CodistConfig(n_models=n, alpha0=2.0 if n > 1 else 0.0,
                              distill_loss="kl")
        state, _ = train_codist(model, codist, tc, _batches(n, scenario),
                                log_every=STEPS - 1)
        acc = _eval_acc(model, state, n, scenario)
        results[(scenario, n)] = acc
        print(f"  n={n}: held-out accuracy {acc:.4f}")

gain_e = results[("enforced", 8)] - results[("enforced", 1)]
gain_s = results[("shared", 8)] - results[("shared", 1)]
print(f"\nenforced-views gain (n=8 vs n=1): {gain_e:+.4f}")
print(f"shared-view   gain (n=8 vs n=1): {gain_s:+.4f}")
print("multi-view hypothesis confirmed" if gain_e > gain_s + 0.02
      else "WARN: expected larger enforced-view gain")
