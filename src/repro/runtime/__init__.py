"""Asynchronous fault-tolerant peer runtime (deterministic virtual cluster).

See docs/runtime.md: N codistilling peers on independent step clocks over a
seeded simulated timeline — speed heterogeneity, straggler episodes,
preemption, permanent failure with checkpoint recovery, and elastic
membership — with predictions flowing through a timestamped mailbox under a
staleness-bound policy.
"""
from repro.runtime.clock import (  # noqa: F401
    FaultConfig,
    FaultSchedule,
    VirtualClock,
    parse_faults,
)
from repro.runtime.mailbox import Mailbox, Payload, StalenessStats  # noqa: F401
from repro.runtime.peer import PeerRuntime  # noqa: F401
from repro.runtime.scheduler import (  # noqa: F401
    AsyncScheduler,
    RunReport,
    simulate_allreduce,
)
