"""internvl2-76b [vlm] — InternViT + (Llama3-70B-style) language backbone [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The vision frontend
(InternViT-6B + MLP projector) is a STUB per the assignment carve-out:
``input_specs()`` provides precomputed patch embeddings (B, num_patches, d_model).
"""
from repro.configs.base import ModelConfig, reduced as _reduced

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    qkv_bias=False,
    act="silu",
    rope_theta=5e5,
    num_patches=256,
    source="InternVL2-Llama3-76B [arXiv:2404.16821]",
)


def reduced():
    return _reduced(CONFIG)
