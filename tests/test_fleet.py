"""Serving-fleet tests: continuous batching vs the dense engine (the parity
invariant), seeded determinism, admission control, defrag, routing policies,
staleness-bounded weight refresh, and the checkpoint->serve round trip."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from dataclasses import replace

from repro.checkpoint.io import save_snapshot
from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.models import build_model
from repro.runtime import FaultConfig
from repro.serve import Engine
from repro.serve.fleet import (ChaosConfig, FleetConfig, FleetDefense,
                               FleetRouter, Request, SCENARIOS,
                               generate_workload)


def _tiny_cfg():
    return replace(get_reduced("qwen1.5-0.5b"), num_layers=2, d_model=64,
                   d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=2,
                   head_dim=32)


def _requests(cfg, lens, max_new=5, gap_ms=1.0, seed=0):
    """Handcrafted request list (few unique lengths keeps prefill traces
    cheap); arrivals ``gap_ms`` apart force mid-stream join/evict churn."""
    rng = np.random.default_rng(seed)
    return [Request(i, i * gap_ms,
                    tuple(int(x) for x in rng.integers(0, cfg.padded_vocab,
                                                       size=l)),
                    max_new)
            for i, l in enumerate(lens)]


class _ListWorkload:
    def __init__(self, requests, scenario="custom", seed=0):
        self.requests = requests
        self.scenario = scenario
        self.seed = seed


# ----------------------------------------------------------------------------
# the acceptance invariant: fleet == per-request Engine.generate, with churn
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-1.6b"])
def test_fleet_parity_with_churn(arch):
    """Continuous-batched decode through the paged pool is token-identical
    (temperature 0) to sequential Engine.generate — with 2 decode slots and
    8 staggered requests, so joins/evictions happen mid-stream."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _requests(cfg, [5, 9, 12, 7, 5, 9, 12, 7], max_new=5)
    fc = FleetConfig(max_slots=2, block_size=4, num_blocks=32,
                     max_blocks_per_slot=8, max_prefills_per_step=1)
    router = FleetRouter(model, [params], config=fc)
    rep = router.run(_ListWorkload(reqs), slo_ms=50.0)
    assert rep.completed == len(reqs)

    # churn actually happened: some request was admitted while another was
    # mid-stream (admitted after it but before it finished)
    recs = router._primaries
    assert any(a.admitted_ms is not None and b.admitted_ms is not None
               and b.admitted_ms > a.admitted_ms
               and b.admitted_ms < a.finished_ms
               for a in recs for b in recs if a is not b), \
        "no mid-stream join observed — churn not exercised"

    eng = Engine(model, params)
    for rec in recs:
        req = rec.request
        ref = eng.generate(
            {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}, req.max_new)
        want = np.asarray(ref.tokens[0, req.prompt_len:]).tolist()
        assert rec.tokens == want, \
            f"{arch} rid {req.rid}: fleet {rec.tokens} != engine {want}"


def test_fleet_parity_hybrid_and_moe():
    """The paged decode handles attn+ssm (jamba) and moe-ffn (grok) scans."""
    for arch in ["jamba-v0.1-52b", "grok-1-314b"]:
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        reqs = _requests(cfg, [6, 10, 6], max_new=4)
        fc = FleetConfig(max_slots=2, block_size=4, num_blocks=32,
                         max_blocks_per_slot=8)
        router = FleetRouter(model, [params], config=fc)
        rep = router.run(_ListWorkload(reqs))
        assert rep.completed == 3
        eng = Engine(model, params)
        for rec in router._primaries:
            req = rec.request
            ref = eng.generate(
                {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]},
                req.max_new)
            assert rec.tokens == np.asarray(
                ref.tokens[0, req.prompt_len:]).tolist(), (arch, req.rid)


# ----------------------------------------------------------------------------
# determinism / workload / scheduler behavior (tiny model)
# ----------------------------------------------------------------------------

def test_workload_seeded_and_bounded():
    wl1 = generate_workload("bursty", 32, 64, seed=9, max_prompt=16,
                            max_new=8)
    wl2 = generate_workload("bursty", 32, 64, seed=9, max_prompt=16,
                            max_new=8)
    assert wl1.requests == wl2.requests, "same seed must replay exactly"
    wl3 = generate_workload("bursty", 32, 64, seed=10, max_prompt=16,
                            max_new=8)
    assert wl1.requests != wl3.requests
    times = [r.arrival_ms for r in wl1.requests]
    assert times == sorted(times) and times[0] > 0
    assert all(1 <= r.prompt_len <= 16 and 1 <= r.max_new <= 8
               for r in wl1.requests)
    assert all(0 <= t < 64 for r in wl1.requests for t in r.prompt)
    for name in SCENARIOS:
        assert generate_workload(name, 4, 64, seed=0).requests


def test_fleet_seeded_determinism_and_report():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    peers = [model.init(jax.random.key(i)) for i in range(2)]

    def run(seed):
        wl = generate_workload("diurnal", 16, cfg.padded_vocab, seed=seed,
                               max_prompt=12, max_new=5)
        fc = FleetConfig(max_slots=3, block_size=4, num_blocks=48,
                         max_blocks_per_slot=8)
        r = FleetRouter(model, peers, config=fc, policy="round_robin",
                        canary_every=5)
        return r.run(wl, slo_ms=40.0)

    a, b, c = run(3), run(3), run(4)
    assert a.to_json() == b.to_json(), "same seed -> same SLO report"
    assert a.stream_digest != c.stream_digest
    doc = json.loads(a.to_json())
    for key in ("p50_ttft_ms", "p99_ttft_ms", "slo_attainment",
                "sim_tokens_per_s", "kv_bytes_written", "stream_digest"):
        assert key in doc
    assert a.completed == 16 and a.generated_tokens > 0
    assert a.kv_bytes_written > 0


def test_admission_control_sheds_load():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    # queue bound 1 + simultaneous arrivals: overflow must be REJECTED
    reqs = _requests(cfg, [8] * 6, max_new=4, gap_ms=0.0)
    fc = FleetConfig(max_slots=1, block_size=4, num_blocks=16,
                     max_blocks_per_slot=4, max_queue=1)
    router = FleetRouter(model, [params], config=fc)
    rep = router.run(_ListWorkload(reqs))
    assert rep.rejected > 0
    assert rep.completed + rep.rejected == 6
    # a request larger than the pool itself is shed, not wedged
    big = _requests(cfg, [8], max_new=200)
    router2 = FleetRouter(model, [params], config=fc)
    rep2 = router2.run(_ListWorkload(big))
    assert rep2.rejected == 1 and rep2.completed == 0


def test_defrag_preserves_streams():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def run(defrag_every):
        reqs = _requests(cfg, [5, 9, 7, 5, 9, 7], max_new=5)
        fc = FleetConfig(max_slots=2, block_size=4, num_blocks=24,
                         max_blocks_per_slot=8, defrag_every=defrag_every)
        r = FleetRouter(model, [params], config=fc)
        rep = r.run(_ListWorkload(reqs))
        return rep.stream_digest, r.engines[0].pool

    d0, _ = run(0)
    d1, pool = run(1)
    assert d0 == d1, "defrag changed decoded streams"
    # after full drain + compaction the free list is contiguous from 1
    assert pool.live_blocks() == 0
    assert pool.free == list(range(1, pool.num_blocks))


def test_router_policies():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    peers = [model.init(jax.random.key(i)) for i in range(3)]
    wl = lambda: _ListWorkload(_requests(cfg, [6, 6, 6, 6, 6, 6],  # noqa: E731
                                         max_new=4))
    fc = FleetConfig(max_slots=2, block_size=4, num_blocks=32,
                     max_blocks_per_slot=4)
    rr = FleetRouter(model, peers, config=fc, policy="round_robin")
    rep_rr = rr.run(wl())
    assert rep_rr.completed == 6
    assert all(len(e.records) == 2 for e in rr.engines)  # cyclic spread

    ll = FleetRouter(model, peers, config=fc, policy="least_loaded")
    assert ll.run(wl()).completed == 6

    en = FleetRouter(model, peers, config=fc, policy="ensemble")
    rep_en = en.run(wl())
    assert rep_en.completed == 6
    # every peer saw every request; shadows feed the agreement signal
    assert all(len(e.records) == 6 for e in en.engines)
    assert rep_en.canary["count"] == 12                 # 2 shadows x 6
    assert rep_en.canary["mean_mse"] > 0                # independent inits
    assert 0.0 <= rep_en.canary["token_agreement"] <= 1.0


def test_canary_divergence_zero_for_identical_peers():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    p = model.init(jax.random.key(0))
    fc = FleetConfig(max_slots=2, block_size=4, num_blocks=32,
                     max_blocks_per_slot=4)
    router = FleetRouter(model, [p, p], config=fc, policy="round_robin",
                         canary_every=2)
    rep = router.run(_ListWorkload(_requests(cfg, [6, 6, 6, 6], max_new=4)))
    assert rep.canary["count"] == 2
    assert rep.canary["mean_mse"] == 0.0
    assert rep.canary["token_agreement"] == 1.0


# ----------------------------------------------------------------------------
# weight refresh: keep-last + staleness bound (the mailbox policy, serving-side)
# ----------------------------------------------------------------------------

def test_weight_refresh_keep_last_and_staleness(tmp_path):
    cfg = _tiny_cfg()
    model = build_model(cfg)
    p_old = model.init(jax.random.key(0))
    p_new0 = model.init(jax.random.key(1))
    p_new1 = model.init(jax.random.key(2))
    snap = str(tmp_path / "snaps")
    # peer0 publishes step 10, peer1 only step 1: with bound 5, peer1's
    # snapshot is 9 steps behind the newest available -> dropped
    save_snapshot(snap, 0, {"params": p_new0}, meta={"step": 10})
    save_snapshot(snap, 1, {"params": p_new1}, meta={"step": 1})
    fc = FleetConfig(max_slots=1, block_size=4, num_blocks=16,
                     max_blocks_per_slot=4)
    router = FleetRouter(model, [p_old, p_old], config=fc,
                         snapshot_dir=snap, staleness_bound=5)
    assert router.refresh_now() == 1
    assert router.engines[0].weights_version == 10
    assert router.engines[1].weights_version == -1
    assert router.refreshes_dropped_stale == 1
    got = jax.tree.leaves(router.engines[0].params)[0]
    want = jax.tree.leaves(p_new0)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # keep-last: republishing an OLDER step must not roll peer0 back
    # (and peer1's step-1 snapshot stays 6 behind the newest -> still dropped)
    save_snapshot(snap, 0, {"params": p_old}, meta={"step": 7})
    assert router.refresh_now() == 0
    assert router.engines[0].weights_version == 10
    assert router.engines[1].weights_version == -1
    # a newer snapshot for peer1 (within the bound) is adopted
    save_snapshot(snap, 1, {"params": p_new1}, meta={"step": 9})
    assert router.refresh_now() == 1
    assert router.engines[1].weights_version == 9
    assert router.refresh_bytes > 0


# ----------------------------------------------------------------------------
# checkpoint -> serve round trip (trained codist peers into the fleet)
# ----------------------------------------------------------------------------

def test_checkpoint_roundtrip_codist_to_fleet(tmp_path):
    """save_snapshot from a short codist run -> router weight refresh ->
    the refreshed peer's prefill logits match the training-side forward of
    bundle.apply's final params."""
    from repro.data import MarkovLM, make_lm_batch
    from repro.optim import make_optimizer
    from repro.train import stack_batches
    from repro.train.engine import PredictionExchange, build_train_step

    cfg = _tiny_cfg()
    model = build_model(cfg)
    task = MarkovLM(vocab=cfg.vocab_size, seed=0)
    tc = TrainConfig(lr=1e-2, total_steps=6, warmup_steps=0,
                     optimizer="sgdm")
    codist = CodistConfig(n_models=2)
    strategy = PredictionExchange(codist)
    opt_init, _ = make_optimizer("sgdm")
    bundle = build_train_step(model, tc, codist, strategy)
    state = strategy.init_state(model, tc, jax.random.key(0), opt_init)
    for step in range(4):
        batch = stack_batches([make_lm_batch(task, 2, 12, step, None, seed=0)
                               for _ in range(2)])
        state, _metrics, _plan = bundle.apply(state, batch, step)

    snap = str(tmp_path / "snaps")
    for i in range(2):
        peer_params = jax.tree.map(lambda x: x[i], state.params)
        save_snapshot(snap, i, {"params": peer_params},
                      meta={"step": int(state.step)})

    stale = [model.init(jax.random.key(99)), model.init(jax.random.key(98))]
    fc = FleetConfig(max_slots=2, block_size=4, num_blocks=16,
                     max_blocks_per_slot=4)
    router = FleetRouter(model, stale, config=fc, snapshot_dir=snap)
    assert router.refresh_now() == 2

    tokens = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab_size, size=(1, 10)),
        jnp.int32)
    for i, eng in enumerate(router.engines):
        # training-side reference: forward through the trained peer params
        train_params = jax.tree.map(lambda x: x[i], state.params)
        full, _aux = model.forward(train_params, {"tokens": tokens})
        logits, _cache = eng._prefill(eng.params, {"tokens": tokens},
                                      tokens.shape[1])
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"peer {i} logits diverge from "
                                           "the trained replica")


# ----------------------------------------------------------------------------
# removal satellite: the deprecated step-factory modules are gone for good
# ----------------------------------------------------------------------------

def _run_py(code):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env)


def test_deprecated_step_modules_are_gone():
    """PR 5 migrated every caller to ``build_train_step``; the alias modules
    and the lazy ``repro.train.__getattr__`` shim are now deleted. Importing
    them must fail cleanly, and the package must not resurrect the names."""
    for mod in ("repro.train.steps", "repro.train.shardmap_step"):
        r = _run_py(f"import {mod}\n")
        assert r.returncode != 0 and "ModuleNotFoundError" in r.stderr, \
            f"{mod} should no longer exist:\n{r.stderr}"


def test_train_package_import_stays_warning_free():
    """Importing repro.train (and touching a removed legacy name) raises a
    plain AttributeError under ``-W error::DeprecationWarning`` — the tier-1
    posture CI runs with."""
    r = _run_py(
        "import warnings\n"
        "warnings.simplefilter('error', DeprecationWarning)\n"
        "import repro.train\n"
        "try:\n"
        "    repro.train.make_codist_step\n"
        "except AttributeError:\n"
        "    pass\n"
        "else:\n"
        "    raise SystemExit('legacy name still resolves')\n")
    assert r.returncode == 0, r.stderr


# ----------------------------------------------------------------------------
# chaos: seeded faults on the decode-tick clock + the router defenses
# (docs/chaos.md) — the acceptance pins: at-most-once token emission under
# preemption + migration, failover + snapshot recovery, bit-determinism
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_fleet():
    """One tiny model + params shared by the chaos tests (the compiled
    decode/prefill cache is weak-keyed on the model, so sharing it keeps
    these from recompiling per test)."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def _chaos_fc(max_queue=32):
    return FleetConfig(max_slots=2, block_size=4, num_blocks=32,
                       max_blocks_per_slot=8, max_queue=max_queue)


def test_preemption_migration_at_most_once(chaos_fleet):
    """Peer 1 is preempted mid-run; the defended router migrates its
    in-flight work to peer 0 by re-prefilling prompt+emitted. The client
    streams must be IDENTICAL to a clean run (identical peers): zero lost,
    zero duplicated tokens — and the whole thing bit-deterministic."""
    cfg, model, p = chaos_fleet
    reqs = _requests(cfg, [5, 9, 12, 7] * 4, max_new=5, gap_ms=4.0)
    wl = _ListWorkload(reqs)
    chaos = ChaosConfig(FaultConfig(n_peers=2, seed=0,
                                    preemptions=((1, 6, 150.0),)))
    clean = FleetRouter(model, [p, p], config=_chaos_fc()).run(wl)
    assert clean.completed == len(reqs) and clean.rejected == 0

    reps = [FleetRouter(model, [p, p], config=_chaos_fc(), chaos=chaos,
                        defense=FleetDefense()).run(wl) for _ in range(2)]
    rep = reps[0]
    assert rep.completed == len(reqs) and rep.rejected == 0
    assert rep.preemptions == 1
    assert rep.migrations >= 1
    assert rep.lost_tokens == 0 and rep.duplicated_tokens == 0
    # at-most-once emission, pinned at token level: continuation prefill
    # reproduces exactly the stream the preempted decode would have made
    assert rep.stream_digest == clean.stream_digest
    # bit-deterministic across two seeded runs (the CI chaos-smoke gate)
    assert reps[0].to_json() == reps[1].to_json()


def test_peer_failure_migration_and_snapshot_recovery(tmp_path, chaos_fleet):
    """Peer 1 dies permanently: defended routing migrates its work (nothing
    lost) and, with recover_after_ms + a snapshot, revives it from
    checkpoint. The undefended fleet strands the dead peer's requests."""
    cfg, model, p = chaos_fleet
    reqs = _requests(cfg, [5, 9, 12, 7] * 5, max_new=5, gap_ms=4.0)
    wl = _ListWorkload(reqs)
    snap = str(tmp_path / "snaps")
    save_snapshot(snap, 1, {"params": p}, meta={"step": 7})
    faults = FaultConfig(n_peers=2, seed=0, failures=((1, 8),))

    rep = FleetRouter(
        model, [p, p], config=_chaos_fc(), snapshot_dir=snap,
        chaos=ChaosConfig(faults, recover_after_ms=30.0),
        defense=FleetDefense()).run(wl)
    assert rep.peers_died == 1 and rep.peers_recovered == 1
    assert rep.migrations >= 1
    assert rep.completed == len(reqs)
    assert rep.lost_tokens == 0 and rep.duplicated_tokens == 0

    router_u = FleetRouter(model, [p, p], config=_chaos_fc(),
                           chaos=ChaosConfig(faults))
    rep_u = router_u.run(wl)
    assert rep_u.peers_died == 1 and rep_u.migrations == 0
    assert rep_u.completed < len(reqs)      # stranded on the dead peer
    # weights version proves recovery came from the step-7 snapshot
    assert rep.completed - rep_u.completed >= 1


def test_recovered_peer_adopts_snapshot_weights(tmp_path, chaos_fleet):
    cfg, model, p = chaos_fleet
    p1 = model.init(jax.random.key(9))
    snap = str(tmp_path / "snaps")
    save_snapshot(snap, 1, {"params": p1}, meta={"step": 7})
    router = FleetRouter(
        model, [p, p], config=_chaos_fc(), snapshot_dir=snap,
        chaos=ChaosConfig(FaultConfig(n_peers=2, seed=0, failures=((1, 4),)),
                          recover_after_ms=20.0),
        defense=FleetDefense())
    reqs = _requests(cfg, [5, 7] * 8, max_new=4, gap_ms=5.0)
    router.run(_ListWorkload(reqs))
    assert router.engines[1].weights_version == 7
    got = jax.tree.leaves(router.engines[1].params)[0]
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jax.tree.leaves(p1)[0]))


def test_degraded_admission_tightens_queue_bounds(chaos_fleet):
    """With half the peers gone, per-peer queue bounds halve (shed at the
    edge instead of queueing unservable work) and recover with capacity."""
    cfg, model, p = chaos_fleet
    fc = _chaos_fc(max_queue=8)
    router = FleetRouter(model, [p, p], config=fc, defense=FleetDefense())
    router._chaos_maintenance(0.0)
    assert all(e.max_queue_live == 8 for e in router.engines)
    router.engines[1].die()
    router._chaos_maintenance(1.0)
    assert all(e.max_queue_live == 4 for e in router.engines)
    router.engines[1].harvest()
    router.engines[1].revive(2.0)
    router._chaos_maintenance(3.0)
    assert all(e.max_queue_live == 8 for e in router.engines)


def test_hedged_dispatch_first_winner_cancels(chaos_fleet):
    """Slowest-decile requests run on two peers; the winner answers the
    client and the loser is cancelled — streams stay identical to the
    unhedged run (identical peers) with nothing lost or duplicated."""
    cfg, model, p = chaos_fleet
    reqs = _requests(cfg, [5, 5, 5, 5, 12, 5, 5, 12, 5, 5], max_new=5,
                     gap_ms=4.0)
    wl = _ListWorkload(reqs)
    clean = FleetRouter(model, [p, p], config=_chaos_fc()).run(wl)
    defense = FleetDefense(hedging=True, hedge_quantile=0.7,
                           hedge_min_samples=3)
    router = FleetRouter(model, [p, p], config=_chaos_fc(), defense=defense)
    rep = router.run(wl)
    assert rep.hedges >= 1
    assert rep.completed == len(reqs) and rep.rejected == 0
    assert rep.lost_tokens == 0 and rep.duplicated_tokens == 0
    assert rep.stream_digest == clean.stream_digest
    assert not router._hedge_pairs           # every pair resolved
    assert all(not e.slots and not e.waiting for e in router.engines)


def test_straggler_health_routing_beats_undefended(chaos_fleet):
    """PR 3's straggler schedule on the fleet clock: EWMA health routing
    steers arrivals off the slow peer, so the defended tail latency must
    beat the undefended round_robin tail. Both bit-deterministic."""
    cfg, model, p = chaos_fleet
    reqs = _requests(cfg, [5, 9, 12, 7] * 6, max_new=5, gap_ms=2.0)
    wl = _ListWorkload(reqs)
    chaos = ChaosConfig(FaultConfig(n_peers=2, seed=0, straggler_peers=(1,),
                                    straggler_factor=4.0,
                                    straggler_frac=0.2))
    rep_u = [FleetRouter(model, [p, p], config=_chaos_fc(),
                         chaos=chaos).run(wl) for _ in range(2)]
    rep_d = FleetRouter(model, [p, p], config=_chaos_fc(), chaos=chaos,
                        defense=FleetDefense(migration=False)).run(wl)
    assert rep_u[0].to_json() == rep_u[1].to_json()   # replayable chaos
    assert rep_d.completed == len(reqs)
    assert rep_d.p99_ttft_ms <= rep_u[0].p99_ttft_ms
    assert rep_d.slo_attainment >= rep_u[0].slo_attainment
