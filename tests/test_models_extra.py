"""Deeper model-layer tests: MoE routing invariants, attention masks, RoPE,
mamba decode-vs-prefill state handoff, VLM engine generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_reduced
from repro.configs.base import MoEConfig, ModelConfig, SSMConfig
from repro.models import build_model
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models.moe import _capacity, moe_forward, router_decisions
from repro.models.common import apply_rope


def _cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64,
                num_heads=2, num_kv_heads=2, d_ff=128, vocab_size=64,
                head_dim=32, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


class TestMoE:
    def test_router_combine_weights_sum_to_one_without_drops(self):
        m = MoEConfig(num_experts=4, top_k=2)
        logits = jax.random.normal(jax.random.key(0), (16, 4))
        dispatch, combine, aux = router_decisions(m, logits, capacity=16)
        total = jnp.sum(combine, axis=(1, 2))  # per-token combine mass
        np.testing.assert_allclose(np.asarray(total), 1.0, rtol=1e-5)

    def test_capacity_drops_reduce_combine_mass(self):
        m = MoEConfig(num_experts=2, top_k=2)
        # all tokens forced to the same experts -> tiny capacity drops most
        logits = jnp.tile(jnp.array([[5.0, 4.0]]), (16, 1))
        _, combine_full, _ = router_decisions(m, logits, capacity=16)
        _, combine_tiny, _ = router_decisions(m, logits, capacity=2)
        assert float(jnp.sum(combine_tiny)) < float(jnp.sum(combine_full))

    def test_nodrop_capacity(self):
        m = MoEConfig(num_experts=4, top_k=2)
        assert _capacity(m, tokens=100, capacity_factor=0.0) == 100
        assert _capacity(m, tokens=100, capacity_factor=1.25) < 100

    def test_load_balance_loss_minimized_by_uniform_router(self):
        m = MoEConfig(num_experts=4, top_k=1)
        uniform = jnp.zeros((64, 4))
        skewed = jnp.tile(jnp.array([[10.0, 0, 0, 0]]), (64, 1))
        _, _, aux_u = router_decisions(m, uniform, 32)
        _, _, aux_s = router_decisions(m, skewed, 32)
        assert float(aux_u) < float(aux_s)

    def test_moe_forward_nodrop_equals_manual_mixture(self):
        """With no drops, MoE output == sum_k gate_k * expert_k(x)."""
        cfg = _cfg(family="moe", act="silu",
                   moe=MoEConfig(num_experts=2, top_k=2))
        from repro.models.moe import init_moe
        p = init_moe(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 4, 64))
        y, _ = moe_forward(p, x, cfg, capacity_factor=0.0)
        # top-2 of 2 experts = all experts, renormalized gates = softmax probs
        logits = jnp.einsum("bsd,de->bse", x, p["router"])
        gates = jax.nn.softmax(logits, axis=-1)
        def expert(e, xx):
            h = jax.nn.silu(xx @ p["w_gate"][e]) * (xx @ p["w_up"][e])
            return h @ p["w_down"][e]
        want = sum(gates[..., e:e + 1] * expert(e, x) for e in range(2))
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


class TestAttention:
    def test_causal_mask_no_future_leak(self):
        cfg = _cfg()
        p = attn.init_attention(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 8, 64))
        out1, _ = attn.attention_forward(p, x, cfg)
        x2 = x.at[:, 5:].set(999.0)  # corrupt the future
        out2, _ = attn.attention_forward(p, x2, cfg)
        np.testing.assert_allclose(np.asarray(out1[:, :5]),
                                   np.asarray(out2[:, :5]), rtol=1e-5)

    def test_sliding_window_limits_receptive_field(self):
        cfg = _cfg(sliding_window=2)
        p = attn.init_attention(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 8, 64))
        out1, _ = attn.attention_forward(p, x, cfg)
        x2 = x.at[:, 0].set(999.0)  # position 0 outside window of position 7
        out2, _ = attn.attention_forward(p, x2, cfg)
        np.testing.assert_allclose(np.asarray(out1[:, 7]),
                                   np.asarray(out2[:, 7]), rtol=1e-5)
        assert not np.allclose(np.asarray(out1[:, 1]), np.asarray(out2[:, 1]))

    def test_gqa_equals_repeated_kv_mha(self):
        """GQA with kv groups == MHA with kv heads repeated."""
        from repro.kernels.ref import flash_attention_ref
        b, s, h, kv, hd = 1, 16, 4, 2, 8
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kv, hd))
        v = jax.random.normal(ks[2], (b, s, kv, hd))
        out_gqa = flash_attention_ref(q, k, v)
        k_full = jnp.repeat(k, h // kv, axis=2)
        v_full = jnp.repeat(v, h // kv, axis=2)
        out_mha = flash_attention_ref(q, k_full, v_full)
        np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                                   rtol=1e-5, atol=1e-6)

    def test_rope_preserves_norm_and_relativity(self):
        x = jax.random.normal(jax.random.key(0), (1, 6, 2, 16))
        pos = jnp.arange(6)[None]
        y = apply_rope(x, pos)
        np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                                   np.asarray(jnp.linalg.norm(x, axis=-1)),
                                   rtol=1e-5)
        # relative property: <rope(q,i), rope(k,j)> depends only on i-j
        q = jax.random.normal(jax.random.key(1), (1, 1, 1, 16))
        k = jax.random.normal(jax.random.key(2), (1, 1, 1, 16))
        def dot(i, j):
            qr = apply_rope(q, jnp.array([[i]]))
            kr = apply_rope(k, jnp.array([[j]]))
            return float(jnp.sum(qr * kr))
        assert dot(3, 1) == pytest.approx(dot(7, 5), rel=1e-4)


class TestMambaState:
    def test_prefill_state_matches_stepwise(self):
        cfg = _cfg(family="hybrid", ssm=SSMConfig(), attn_layer_period=2)
        p = mb.init_mamba(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 8, 64)) * 0.5
        _, state_pre = mb.mamba_prefill(p, x, cfg)
        state = mb.init_mamba_state(cfg, 1, jnp.float32)
        for i in range(8):
            _, state = mb.mamba_decode(p, x[:, i:i + 1], state, cfg)
        np.testing.assert_allclose(np.asarray(state_pre["h"]),
                                   np.asarray(state["h"]), rtol=1e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(state_pre["conv"]),
                                   np.asarray(state["conv"]), rtol=1e-4,
                                   atol=1e-5)


class TestEngineVLM:
    def test_vlm_generation_uses_patch_prefix(self):
        from repro.serve import Engine
        cfg = get_reduced("internvl2-76b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = Engine(model, params)
        k = jax.random.key(1)
        batch = {
            "tokens": jax.random.randint(k, (2, 6), 0, cfg.padded_vocab),
            "patches": 0.1 * jax.random.normal(k, (2, cfg.num_patches,
                                               cfg.d_model)),
        }
        r1 = eng.generate(batch, max_new_tokens=4)
        # different patches must influence generation
        batch2 = dict(batch, patches=batch["patches"] + 1.0)
        r2 = eng.generate(batch2, max_new_tokens=4)
        assert r1.tokens.shape == (2, 10)
        assert not np.array_equal(np.asarray(r1.tokens[:, 6:]),
                                  np.asarray(r2.tokens[:, 6:]))
