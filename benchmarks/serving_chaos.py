"""Chaos-serving benchmark: SLO attainment and goodput for the fleet under
seeded fault injection — defended (health routing + migration + degraded
admission) vs undefended vs a clean run.

Cells share one workload + fleet config so the deltas isolate the fault
schedule and the defenses:

  clean_2p              no faults — the SLO ceiling
  straggler_undefended  PR 3's straggler schedule (peer 1 runs 4x slow for
                        20% of ticks), blind round_robin routing
  straggler_defended    same schedule, EWMA health routing steers load off
                        the slow peer
  preempt_defended      mid-run preemption; admitted work migrates to the
                        healthy peer by re-prefilling prompt+emitted
  fail_recover          permanent peer death + checkpoint-recovery rejoin

Everything in ``derived`` runs on the SIMULATED clock and is
bit-deterministic for the committed seed; ``comm_bytes`` (KV bytes written +
refresh bytes) and the stream digests are matched exactly by
``tools/bench_compare.py``, so a chaos/defense behavior change fails CI the
same way a train-side comm change does. The summary rows pin the paper-style
robustness claim: defended SLO within 10% of clean while undefended degrades.
"""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List

import jax

from repro.checkpoint.io import save_snapshot
from repro.runtime import FaultConfig
from repro.serve.fleet import (ChaosConfig, FleetConfig, FleetDefense,
                               FleetRouter, generate_workload)

from benchmarks.common import tiny_lm_cfg

SEED = 17

# PR 3's straggler schedule (benchmarks/fault_tolerance.py), re-read on the
# serving fleet's decode-tick clock
STRAGGLER = dict(straggler_peers=(1,), straggler_factor=4.0,
                 straggler_frac=0.2)


def _row(name: str, rep, wall_s: float) -> Dict:
    # FleetReport.to_dict() is the shared serialization path (CLI --report,
    # obs metrics export, bench rows): a field drift breaks all consumers
    d = rep.to_dict()
    comm = d["kv_bytes_written"] + d["refresh_bytes"]
    return {
        "name": f"chaos/{name}",
        "us_per_call": wall_s * 1e6 / max(1, d["generated_tokens"]),
        "derived": (f"slo={d['slo_attainment']:.3f},"
                    f"goodput={d['goodput_tokens_per_s']:.1f},"
                    f"completed={d['completed']},"
                    f"migr={d['migrations']},"
                    f"lost={d['lost_tokens']},dup={d['duplicated_tokens']},"
                    f"digest={d['stream_digest'][:12]},"
                    f"comm_bytes={comm}"),
    }


def run(quick: bool = False) -> List[Dict]:
    from repro.models import build_model
    cfg = tiny_lm_cfg()
    model = build_model(cfg)
    peer_params = [model.init(jax.random.key(SEED + i)) for i in range(2)]
    n_requests = 12 if quick else 48
    # bursty arrivals + a 30 ms SLO: tight enough that 4x straggler episodes
    # blow the deadline on the blind router, loose enough that health routing
    # keeps every request inside it
    wl = generate_workload("bursty", n_requests, cfg.padded_vocab, seed=SEED,
                           max_prompt=16, max_new=6)
    fc = FleetConfig(max_slots=4, block_size=4, num_blocks=64,
                     max_blocks_per_slot=8)
    slo_ms = 30.0

    def cell(chaos=None, defense=None, snapshot_dir=None):
        router = FleetRouter(model, peer_params, config=fc,
                             snapshot_dir=snapshot_dir, chaos=chaos,
                             defense=defense)
        t0 = time.perf_counter()
        rep = router.run(wl, slo_ms=slo_ms)
        return rep, time.perf_counter() - t0

    straggler = ChaosConfig(FaultConfig(n_peers=2, seed=SEED, **STRAGGLER))
    preempt = ChaosConfig(FaultConfig(
        n_peers=2, seed=SEED, preemptions=((1, 6, 120.0),)))
    fail = ChaosConfig(FaultConfig(n_peers=2, seed=SEED, failures=((1, 8),)),
                       recover_after_ms=40.0)

    rows: List[Dict] = []
    reps = {}
    for name, chaos, defense, snap in [
            ("clean_2p", None, None, False),
            ("straggler_undefended", straggler, None, False),
            ("straggler_defended", straggler, FleetDefense(), False),
            ("preempt_defended", preempt, FleetDefense(), False),
            ("fail_recover", fail, FleetDefense(), True)]:
        if snap:
            with tempfile.TemporaryDirectory() as d:
                save_snapshot(d, 1, {"params": peer_params[1]},
                              meta={"step": 7})
                rep, wall = cell(chaos, defense, snapshot_dir=d)
        else:
            rep, wall = cell(chaos, defense)
        reps[name] = rep
        rows.append(_row(name, rep, wall))

    # the robustness claim, pinned as gated derived values: defended SLO
    # within 10% of clean while the undefended fleet degrades materially
    clean = reps["clean_2p"].slo_attainment
    defended = reps["straggler_defended"].slo_attainment
    undefended = reps["straggler_undefended"].slo_attainment
    rows.append({"name": "chaos/defended_within_10pct_of_clean",
                 "derived": int(defended >= clean * 0.9)})
    rows.append({"name": "chaos/undefended_slo_gap_frac",
                 "derived": round((clean - undefended) / max(clean, 1e-9), 4)})
    # at-most-once token emission across every defended cell
    lost_dup = sum(reps[n].lost_tokens + reps[n].duplicated_tokens
                   for n in ("straggler_defended", "preempt_defended",
                             "fail_recover"))
    rows.append({"name": "chaos/defended_lost_plus_dup_tokens",
                 "derived": lost_dup})
    return rows
