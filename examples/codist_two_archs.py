"""Section-5.2: codistilling ACROSS architectures — a small model codistilled
with a larger partner improves over training alone (the paper's ResNet50 <-
ResNeXt101 observation), using two different-capacity LMs on the same FINITE
data pool (the effect lives in the overfitting regime — A.7: codistillation
increasingly beats all_reduce as training data shrinks).

Codistillation only couples models through logits on a shared vocabulary, so
heterogeneous partners need the manual (per-model forward) path rather than
the stacked-vmap fast path — this example exercises exactly that API.

    PYTHONPATH=src python examples/codist_two_archs.py
"""
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_reduced
from repro.core.codistillation import cross_entropy, distill_mse
from repro.data import MarkovLM, make_lm_batch
from repro.models import build_model
from repro.optim import make_optimizer
from repro.train import make_schedules

STEPS, B, S, VOCAB, POOL = 400, 8, 64, 64, 6

small_cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=2, d_model=48,
                    d_ff=96, vocab_size=VOCAB, num_heads=2, num_kv_heads=2,
                    head_dim=24)
big_cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=4, d_model=192,
                  d_ff=512, vocab_size=VOCAB, num_heads=4, num_kv_heads=4,
                  head_dim=48)
small, big = build_model(small_cfg), build_model(big_cfg)
task = MarkovLM(vocab=VOCAB, seed=0)
tc = TrainConfig(lr=3e-3, total_steps=STEPS, warmup_steps=5,
                 optimizer="adamw", lr_schedule="cosine")
lr_fn, wd_fn, _, _ = make_schedules(tc)
opt_init, opt_update = make_optimizer("adamw")


def run(alpha: float, seed: int = 0):
    ps = small.init(jax.random.key(seed))
    pb = big.init(jax.random.key(seed + 100))
    os_, ob = opt_init(ps), opt_init(pb)

    @jax.jit
    def step(ps, pb, os_, ob, batch, k):
        def loss(params):
            p_s, p_b = params
            lg_s, _ = small.forward(p_s, batch)
            lg_b, _ = big.forward(p_b, batch)
            ce_s = cross_entropy(lg_s, batch["labels"])
            ce_b = cross_entropy(lg_b, batch["labels"])
            d_s = distill_mse(lg_s, jax.lax.stop_gradient(lg_b))
            d_b = distill_mse(lg_b, jax.lax.stop_gradient(lg_s))
            return ce_s + ce_b + alpha * (d_s + d_b), (ce_s, ce_b)

        (l, (ce_s, ce_b)), g = jax.value_and_grad(loss, has_aux=True)(
            (ps, pb))
        ps, os_ = opt_update(ps, g[0], os_, lr_fn(k), wd_fn(k))
        pb, ob = opt_update(pb, g[1], ob, lr_fn(k), wd_fn(k))
        return ps, pb, os_, ob, ce_s, ce_b

    for k in range(STEPS):
        batch = make_lm_batch(task, B, S, k % POOL, None, seed=0)
        ps, pb, os_, ob, ce_s, ce_b = step(ps, pb, os_, ob, batch,
                                           jnp.int32(k))

    # held-out eval of the SMALL model (the paper keeps one model at inference)
    losses = []
    for k in range(20_000, 20_008):
        batch = make_lm_batch(task, B, S, k, None, seed=1)
        lg, _ = small.forward(ps, batch)
        losses.append(float(cross_entropy(lg, batch["labels"])))
    return sum(losses) / len(losses)


solo = run(alpha=0.0)
with_big = run(alpha=1.0)
print(f"small model held-out loss, trained alone:        {solo:.4f}")
print(f"small model held-out loss, codistilled with big: {with_big:.4f}")
print("larger partner helps" if with_big < solo
      else "WARN: expected the larger partner to help")
