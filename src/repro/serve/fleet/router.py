"""Peer-aware routing across N codistilled replicas + the fleet driver.

Codistillation's deployment story (Anil et al. 2018; PAPER.md Section 6.6)
is that training yields N independently-serveable, equally-good models. The
router turns that into capacity and safety:

  * ``round_robin``   — cyclic assignment (equal-quality peers need no
                        affinity);
  * ``least_loaded``  — assign to the peer with the fewest queued+live
                        requests at arrival (ties -> lowest peer id);
  * ``ensemble``      — every request runs on ALL peers; the rotating
                        primary answers the client, the shadows feed the
                        agreement signal (the expensive, fully-covered
                        variant of the canary).

Because the peers trained against each other's predictions, their logits
agree far more than independently-trained models' — so DISAGREEMENT is a
cheap health signal. Every ``canary_every``-th request is duplicated to the
next peer and the pair's prefill logits are compared with
``distill_pair("mse", ...)`` (the training-side agreement metric, reused
verbatim): a peer whose canary divergence spikes has drifted (bad refresh,
corrupt weights) and is flagged, mirroring how codistillation monitors
peer agreement during training.

Weight refresh mirrors the async runtime mailbox's keep-last policy
(docs/runtime.md): ``checkpoint/io.py`` snapshots are polled every
``refresh_every_ms`` of simulated time; only a snapshot STRICTLY NEWER than
the peer's current weights is adopted (keep-last — never roll back), and a
snapshot more than ``staleness_bound`` steps behind the newest available is
dropped rather than adopted, exactly the mailbox's drop-vs-keep decision.
Refreshes happen at tick boundaries (serving never blocks on a load), and
the bytes are billed once per ADOPTED snapshot through
``core/comm_model.py``'s checkpoint-exchange event — the same ledger the
training mailbox meters, so serving and training comm costs are directly
comparable.

Chaos (docs/chaos.md): with a :class:`ChaosConfig` the engines consult the
runtime's seeded fault schedule on every tick, and with a
:class:`FleetDefense` the router fights back — health-aware peer selection,
migration of in-flight work off dead/preempted peers with at-most-once
token emission, optional hedged dispatch of the slowest-decile requests,
and degraded-mode admission control. Equal peers are what make every one
of these defenses SOUND: any replica can continue any request. Without
either config the run path is bit-identical to the pre-chaos router.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import (has_snapshot, load_snapshot_params,
                                 snapshot_meta)
from repro.core.codistillation import distill_pair
from repro.core.comm_model import bits_per_exchange_event, param_bits_of
from repro.obs.metrics import Histogram
from repro.serve.fleet.batcher import (REQUEST_PID, ROUTER_PID, FleetConfig,
                                       FleetEngine, RequestRecord)
from repro.serve.fleet.chaos import (ChaosConfig, ChaosSchedule, ChaosStats,
                                     FleetDefense, PeerHealth, _HedgePair,
                                     _Orphan)
from repro.serve.fleet.spec import SpecConfig, SpecEngine
from repro.serve.fleet.workload import Workload

PyTree = Any

POLICIES = ("round_robin", "least_loaded", "ensemble", "speculative")


@dataclass
class CanaryStats:
    count: int = 0
    mse_sum: float = 0.0
    mse_max: float = 0.0
    token_agree: int = 0
    token_total: int = 0

    def observe(self, primary: RequestRecord, shadow: RequestRecord) -> None:
        if primary.prefill_logits is None or shadow.prefill_logits is None:
            return
        a = jnp.asarray(primary.prefill_logits)[None, :]
        b = jnp.asarray(shadow.prefill_logits)[None, :]
        mse = float(distill_pair("mse", a, b))
        self.count += 1
        self.mse_sum += mse
        self.mse_max = max(self.mse_max, mse)
        n = min(len(primary.tokens), len(shadow.tokens))
        self.token_total += n
        self.token_agree += sum(1 for x, y in zip(primary.tokens[:n],
                                                  shadow.tokens[:n]) if x == y)

    def summary(self) -> Dict:
        return {
            "count": self.count,
            "mean_mse": self.mse_sum / self.count if self.count else 0.0,
            "max_mse": self.mse_max,
            "token_agreement": (self.token_agree / self.token_total
                                if self.token_total else 1.0),
        }


@dataclass
class FleetReport:
    """SLO + accounting summary of one fleet run (all times simulated ms)."""
    scenario: str
    router: str
    peers: int
    seed: int
    completed: int
    rejected: int
    p50_ttft_ms: float
    p99_ttft_ms: float
    p50_e2e_ms: float
    p99_e2e_ms: float
    slo_ms: float
    slo_attainment: float            # fraction with TTFT <= slo_ms
    sim_tokens_per_s: float
    generated_tokens: int
    kv_bytes_written: int
    refresh_bytes: int
    refreshes: int
    refreshes_dropped_stale: int
    peak_pool_utilization: float
    canary: Dict = field(default_factory=dict)
    stream_digest: str = ""          # sha256 over client token streams
    # chaos accounting (zero on clean runs)
    goodput_tokens_per_s: float = 0.0   # tokens of SLO-met completions
    lost_tokens: int = 0             # completed streams short of max_new
    duplicated_tokens: int = 0       # completed streams over max_new
    migrations: int = 0
    migration_failures: int = 0
    hedges: int = 0
    hedge_wins: int = 0
    preemptions: int = 0
    peers_died: int = 0
    peers_recovered: int = 0
    # speculative decoding (zero on plain runs); the accept rate is the
    # fleet's live codistillation-quality signal — how often the draft
    # peer's argmax agrees with the target's, measured on client traffic
    spec_rounds: int = 0
    spec_drafted_tokens: int = 0
    spec_accepted_tokens: int = 0
    spec_accept_rate: float = 0.0
    spec_fallback_ticks: int = 0

    def to_dict(self) -> Dict:
        """THE serialization path: ``launch/serve.py --report``, the bench
        rows, and the metrics export all read this dict (field names are the
        dataclass's — one schema everywhere)."""
        return dict(self.__dict__)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)


class FleetRouter:
    def __init__(self, model, peer_params: List[PyTree],
                 config: Optional[FleetConfig] = None,
                 policy: str = "round_robin",
                 cache_dtype=jnp.float32,
                 canary_every: int = 0,
                 snapshot_dir: Optional[str] = None,
                 refresh_every_ms: float = 0.0,
                 staleness_bound: int = 0,
                 chaos: Optional[ChaosConfig] = None,
                 defense: Optional[FleetDefense] = None,
                 tracer=None, metrics=None, watch=None,
                 spec: Optional[SpecConfig] = None,
                 draft_model=None, draft_params: PyTree = None):
        assert policy in POLICIES, (policy, POLICIES)
        assert len(peer_params) >= 1
        self.policy = policy
        self.config = config or FleetConfig()
        # observability (repro.obs): None = every hook is a no-op and the
        # run path is bit-identical to the uninstrumented router
        self.tracer = tracer
        self.metrics = metrics
        # optional Watchtower (obs/watch.py): engines evaluate it per tick,
        # the router once more after the end-of-run report gauges land
        self.watch = watch
        if tracer is not None:
            tracer.name_process(ROUTER_PID, "router")
            tracer.name_process(REQUEST_PID, "requests")
        # speculative pairing: every serving peer is a SpecEngine; the
        # draft is its ring neighbor, a dedicated peer (spec.draft_peer,
        # excluded from the serving rotation), or a static student model
        # (draft_model/draft_params). _spec_serving is None on every other
        # policy — all routing paths stay untouched.
        self.spec = spec
        self._spec_serving: Optional[List[int]] = None
        if policy == "speculative":
            sc = spec or SpecConfig()
            self.spec = sc
            student = draft_params is not None
            dedicated = None if student else sc.draft_peer
            if dedicated is not None:
                assert 0 <= dedicated < len(peer_params), \
                    (dedicated, len(peer_params))
            if not student and len(peer_params) < 2:
                raise ValueError(
                    "speculative ring pairing needs >= 2 peers "
                    "(or pass draft_model/draft_params for a student draft)")
            self.engines = [
                FleetEngine(model, p, self.config, cache_dtype=cache_dtype,
                            peer_id=i, tracer=tracer, metrics=metrics)
                if i == dedicated else
                SpecEngine(model, p, self.config, sc,
                           cache_dtype=cache_dtype, peer_id=i,
                           tracer=tracer, metrics=metrics,
                           draft_model=draft_model,
                           draft_params=draft_params)
                for i, p in enumerate(peer_params)]
            serving = [i for i, e in enumerate(self.engines)
                       if isinstance(e, SpecEngine)]
            if not student:
                for pos, i in enumerate(serving):
                    self.engines[i].set_partner(
                        self.engines[dedicated] if dedicated is not None
                        else self.engines[serving[(pos + 1) % len(serving)]])
            self._spec_serving = serving
        else:
            self.engines = [FleetEngine(model, p, self.config,
                                        cache_dtype=cache_dtype,
                                        keep_logits=(policy == "ensemble"),
                                        peer_id=i, tracer=tracer,
                                        metrics=metrics)
                            for i, p in enumerate(peer_params)]
        if watch is not None:
            for eng in self.engines:
                eng.watch = watch
        self.canary_every = canary_every
        self.snapshot_dir = snapshot_dir
        self.refresh_every_ms = refresh_every_ms
        self.staleness_bound = staleness_bound
        self._next_refresh_ms = refresh_every_ms
        self._rr = 0
        self._since_canary = 0
        # one weight refresh moves one replica across the slow links — the
        # n=2 checkpoint-exchange event of the Section-3 model (sender +
        # this peer), billed ONCE per adopted snapshot by the keep-last
        # guard below; tests/test_comm_model.py pins the ledger identity
        self._param_bytes = int(bits_per_exchange_event(
            "checkpoints", 2, b_model=param_bits_of(peer_params[0])) // 8)
        self.refresh_bytes = 0
        self.refreshes = 0
        self.refreshes_dropped_stale = 0
        self.canary_stats = CanaryStats()
        # (primary record, shadow record) pairs compared after the run
        self._pairs: List[tuple] = []
        self._primaries: List[RequestRecord] = []
        # ---- chaos state ----
        self.chaos = chaos
        self.defense = defense
        self.chaos_stats = ChaosStats()
        if chaos is not None:
            sched = ChaosSchedule(chaos)
            for eng in self.engines:
                eng.chaos = sched
        if defense is not None:
            for eng in self.engines:
                eng.health = PeerHealth(alpha=defense.health_alpha)
        self._death_seen = [False] * len(self.engines)
        self._orphans: List[_Orphan] = []          # awaiting (re)placement
        self._continuations: List[RequestRecord] = []   # live migrated copies
        self._phys2logical: Dict[int, RequestRecord] = {}
        self._hedge_pairs: List[_HedgePair] = []
        self._hedge_by_id: Dict[int, _HedgePair] = {}
        # hedging threshold over request sizes: an obs.Histogram, which
        # reproduces the np.quantile math of the ad-hoc sample list it
        # replaced bit-for-bit
        self._size_hist = (metrics.histogram("router/hedge_size_tokens")
                           if metrics is not None
                           else Histogram(name="router/hedge_size_tokens"))
        self._trace_close: Dict[int, float] = {}   # rid -> last child end

    # ---- peer selection ----------------------------------------------------
    def _serving(self, peers: List[int]) -> List[int]:
        """Restrict to the serving rotation (drops a dedicated draft peer
        under the speculative policy; identity everywhere else)."""
        if self._spec_serving is None:
            return peers
        return [i for i in peers if i in self._spec_serving]

    def _available(self, t_ms: float) -> List[int]:
        return [i for i, e in enumerate(self.engines)
                if not e.dead and e.offline_until_ms <= t_ms]

    def _healthy(self, t_ms: float) -> List[int]:
        """Available peers whose tick-cost EWMA looks nominal; falls back to
        any available peer when every one of them looks sick (serving from a
        straggler beats not serving)."""
        avail = self._serving(self._available(t_ms))
        if self.defense is None:
            return avail
        ok = [i for i in avail
              if self.engines[i].health is None
              or self.engines[i].health.healthy(self.defense.unhealthy_factor)]
        return ok or avail

    def _pick(self, t_ms: float) -> Optional[int]:
        n = len(self.engines)
        if self.defense is None:
            # undefended: route blindly, dead peers included — this is the
            # baseline the chaos benchmark measures the defenses against
            cands = self._serving(list(range(n)))
        else:
            cands = self._healthy(t_ms)
        if not cands:
            return None
        if self.policy == "least_loaded":
            return min(cands, key=lambda i: (self.engines[i].load, i))
        for _ in range(n):
            peer = self._rr % n
            self._rr += 1
            if peer in cands:
                return peer
        return cands[0]

    def _route(self, request) -> None:
        n = len(self.engines)
        t = request.arrival_ms
        if self.tracer is not None:
            # one async wrapper per client request, opened at arrival and
            # closed at report time; the id is the request id, so the tree
            # survives migration across peers. Children land on the
            # request's own thread row (tid = rid).
            self.tracer.name_thread(REQUEST_PID, request.rid,
                                    f"req{request.rid}")
            self.tracer.async_begin(
                "request", request.rid, "request", t, pid=REQUEST_PID,
                tid=request.rid,
                args={"prompt_len": request.prompt_len,
                      "max_new": request.max_new})
        if self.policy == "ensemble":
            if self.defense is None:
                avail = list(range(n))
            else:
                avail = self._available(t)
                if not avail:
                    self._no_capacity(request, t)
                    return
            for _ in range(n):
                primary = self._rr % n
                self._rr += 1
                if primary in avail:
                    break
            prec = self.engines[primary].enqueue(request)
            prec.traced = True
            self._primaries.append(prec)
            for off in range(1, n):
                peer = (primary + off) % n
                if peer not in avail:
                    continue
                srec = self.engines[peer].enqueue(request, canary=True)
                self._pairs.append((prec, srec))
            return
        peer = self._pick(t)
        if peer is None:
            self._no_capacity(request, t)
            return
        prec = self.engines[peer].enqueue(request)
        prec.traced = True
        self._primaries.append(prec)
        self._since_canary += 1
        if (self.canary_every and n > 1
                and self._since_canary >= self.canary_every):
            self._since_canary = 0
            shadow = self._shadow_of(peer)
            if shadow != peer:
                prec.canary = True   # keep the primary's prefill logits too
                srec = self.engines[shadow].enqueue(request, canary=True)
                self._pairs.append((prec, srec))
        self._maybe_hedge(request, prec, peer)

    def _shadow_of(self, peer: int) -> int:
        """Canary shadow: the next peer in the SERVING rotation (a dedicated
        draft peer never serves, not even shadows). Returns ``peer`` itself
        when there is no distinct serving peer to shadow on."""
        n = len(self.engines)
        if self._spec_serving is None:
            return (peer + 1) % n
        if len(self._spec_serving) < 2 or peer not in self._spec_serving:
            return peer
        pos = self._spec_serving.index(peer)
        return self._spec_serving[(pos + 1) % len(self._spec_serving)]

    def _no_capacity(self, request, t_ms: float) -> None:
        """Every peer is dead or offline at arrival."""
        alive = self._serving([i for i, e in enumerate(self.engines)
                               if not e.dead])
        rec = RequestRecord(request)
        rec.traced = True
        if self.defense is not None and alive:
            # park: the orphan machinery places it when a peer returns
            self._primaries.append(rec)
            self._orphans.append(_Orphan(rec, t_ms))
            return
        if alive:
            # undefended: queue on whichever peer comes back soonest
            peer = min(alive, key=lambda i: (self.engines[i].offline_until_ms,
                                             i))
            prec = self.engines[peer].enqueue(request)
            prec.traced = True
            self._primaries.append(prec)
            return
        rec.rejected = True
        self._primaries.append(rec)

    def _maybe_hedge(self, request, prec: RequestRecord, ppeer: int) -> None:
        d = self.defense
        if not (d and d.hedging and len(self.engines) > 1):
            return
        h = self._size_hist
        if h.count < d.hedge_min_samples:
            h.observe(request.total_tokens)
            return
        # threshold over previously-seen sizes only — this request is
        # observed AFTER the quantile, preserving the exact semantics of the
        # ad-hoc sample list this histogram replaced
        thr = h.quantile(d.hedge_quantile)
        h.observe(request.total_tokens)
        if request.total_tokens < thr:
            return
        cands = [i for i in self._healthy(request.arrival_ms) if i != ppeer]
        if not cands:
            return
        hpeer = min(cands, key=lambda i: (self.engines[i].load, i))
        hrec = self.engines[hpeer].enqueue(request)
        pair = _HedgePair(prec, hrec, ppeer, hpeer)
        self._hedge_pairs.append(pair)
        self._hedge_by_id[id(prec)] = pair
        self._hedge_by_id[id(hrec)] = pair
        self.chaos_stats.hedges += 1
        if self.tracer is not None:
            self.tracer.instant("hedge", request.arrival_ms, pid=REQUEST_PID,
                                tid=request.rid, cat="request",
                                args={"to_peer": hpeer})

    # ---- weight refresh (keep-last, staleness-bounded) ---------------------
    def refresh_now(self) -> int:
        """One poll of the snapshot directory; returns peers refreshed."""
        if not self.snapshot_dir:
            return 0
        n0 = self.refreshes
        metas = [snapshot_meta(self.snapshot_dir, i)
                 for i in range(len(self.engines))]
        steps = [m.get("step", -1) if m else -1 for m in metas]
        newest = max(steps) if steps else -1
        for i, eng in enumerate(self.engines):
            step = steps[i]
            if step < 0 or step <= eng.weights_version:
                continue             # keep-last: never adopt older weights
            if self.staleness_bound and newest - step > self.staleness_bound:
                self.refreshes_dropped_stale += 1
                continue             # too stale vs the fleet's newest: drop
            params = load_snapshot_params(self.snapshot_dir, i, eng.params)
            eng.set_params(params)
            eng.weights_version = step
            self.refreshes += 1
            self.refresh_bytes += self._param_bytes
        return self.refreshes - n0

    def _maybe_refresh(self, t_ms: float) -> None:
        if not self.snapshot_dir or self.refresh_every_ms <= 0:
            return
        if t_ms >= self._next_refresh_ms:
            # one poll per catch-up, however long the simulated gap: the
            # intermediate polls would all observe the same directory state
            periods = int((t_ms - self._next_refresh_ms)
                          // self.refresh_every_ms) + 1
            self._next_refresh_ms += periods * self.refresh_every_ms
            self.refresh_now()

    # ---- request-tree tracing ----------------------------------------------
    def _bump_close(self, rid: int, t: float) -> None:
        cur = self._trace_close.get(rid)
        if cur is None or t > cur:
            self._trace_close[rid] = t

    def _trace_placement(self, rec: RequestRecord, end_t: float, *,
                         cancelled: bool = False,
                         note: Optional[str] = None) -> None:
        """Emit the lifecycle spans of ONE physical placement of a traced
        request — queue → admit → prefill (or re-prefill for a migrated
        continuation) → decode — onto the request's own trace row. Called
        exactly once per placement, at the moment it concludes (finish,
        harvest, or end of run) when every timestamp is known; the tracer's
        export-time (ts, seq) ordering interleaves the spans correctly."""
        tr = self.tracer
        if tr is None or not rec.traced or rec.trace_emitted:
            return
        rec.trace_emitted = True
        rid = rec.request.rid
        base: Dict = {}
        if note:
            base["note"] = note
        if cancelled:
            base["cancelled"] = True
        args = base or None
        arr = rec.request.arrival_ms
        if rec.admitted_ms is None:
            if not rec.rejected:
                # still queued/pending when the placement was torn down
                t1 = max(arr, end_t)
                tr.complete("queue", arr, t1, pid=REQUEST_PID, tid=rid,
                            cat="request", args=args)
                self._bump_close(rid, t1)
            return
        adm = max(arr, rec.admitted_ms)
        tr.complete("queue", arr, adm, pid=REQUEST_PID, tid=rid,
                    cat="request")
        tr.instant("admit", adm, pid=REQUEST_PID, tid=rid, cat="request")
        name = "re-prefill" if rec.origin is not None else "prefill"
        first = (rec.first_token_ms if rec.first_token_ms is not None
                 else max(adm, end_t))
        first = max(adm, first)
        tr.complete(name, adm, first, pid=REQUEST_PID, tid=rid,
                    cat="request", args=args)
        last = first
        if rec.first_token_ms is not None:
            dend = (rec.finished_ms if rec.finished_ms is not None
                    else max(first, end_t))
            dargs = dict(base)
            dargs["tokens"] = len(rec.tokens)
            tr.complete("decode", first, dend, pid=REQUEST_PID, tid=rid,
                        cat="request", args=dargs)
            last = dend
        self._bump_close(rid, last)

    # ---- migration / hedging / recovery maintenance ------------------------
    def _logical_of(self, rec: RequestRecord) -> RequestRecord:
        """Resolve a harvested physical record to its client-facing record,
        folding any partial progress into it first."""
        logical = self._phys2logical.pop(id(rec), None)
        if logical is None:
            return rec               # the original placement
        if rec in self._continuations:
            self._continuations.remove(rec)
        self._fold(logical, rec)
        return logical

    @staticmethod
    def _fold(logical: RequestRecord, phys: RequestRecord) -> None:
        """Merge a continuation's progress into the client-facing record.
        Tokens already on ``logical`` were emitted BEFORE this placement —
        extending preserves at-most-once emission."""
        logical.tokens.extend(phys.tokens)
        if logical.admitted_ms is None:
            logical.admitted_ms = phys.admitted_ms
        if logical.first_token_ms is None:
            logical.first_token_ms = phys.first_token_ms
        if phys.finished_ms is not None:
            logical.finished_ms = phys.finished_ms
            logical.cancelled = False

    def _queue_migration(self, logical: RequestRecord, t_ms: float) -> None:
        if len(logical.tokens) >= logical.request.max_new:
            # every output token was already emitted: effectively complete
            logical.finished_ms = logical.finished_ms or t_ms
            logical.cancelled = False
            return
        backoff = (0.0 if logical.migrations == 0 else
                   self.defense.retry_backoff_ms
                   * (2 ** (logical.migrations - 1)))
        self._orphans.append(_Orphan(logical, t_ms + backoff))

    def _absorb_harvested(self, recs: List[RequestRecord],
                          t_ms: float) -> None:
        for rec in recs:
            # this placement is dead — emit its partial span tree now, while
            # its timestamps still describe what actually ran on the peer
            self._trace_placement(rec, t_ms, cancelled=True, note="harvest")
            pair = self._hedge_by_id.get(id(rec))
            if pair is not None:
                if rec is pair.rec:
                    pair.palive = False
                else:
                    pair.halive = False
                if pair.palive or pair.halive:
                    continue         # the surviving copy carries the request
                # both copies gone: hedging delivered nothing (whole-response
                # semantics), so restart the client record from scratch
                self._unhedge(pair)
                logical = pair.rec
                logical.tokens.clear()
                logical.admitted_ms = None
                logical.first_token_ms = None
            else:
                logical = self._logical_of(rec)
            self._queue_migration(logical, t_ms)

    def _unhedge(self, pair: _HedgePair) -> None:
        self._hedge_pairs.remove(pair)
        self._hedge_by_id.pop(id(pair.rec), None)
        self._hedge_by_id.pop(id(pair.hrec), None)

    def _sweep_continuations(self, t_ms: float) -> None:
        for prec in list(self._continuations):
            logical = self._phys2logical[id(prec)]
            if prec.rejected:
                # target queue shed the continuation: back off, try again
                self._continuations.remove(prec)
                del self._phys2logical[id(prec)]
                self._queue_migration(logical, t_ms)
            elif prec.finished_ms is not None:
                self._trace_placement(prec, t_ms)
                self._continuations.remove(prec)
                del self._phys2logical[id(prec)]
                self._fold(logical, prec)

    def _resolve_hedges(self, t_ms: float) -> None:
        for pair in list(self._hedge_pairs):
            prec, hrec = pair.rec, pair.hrec
            if pair.palive and prec.rejected:
                pair.palive = False  # admission shed == copy death
            if pair.halive and hrec.rejected:
                pair.halive = False
            pwin = pair.palive and prec.finished_ms is not None
            hwin = pair.halive and hrec.finished_ms is not None
            if pwin and (not hwin or prec.finished_ms <= hrec.finished_ms):
                if pair.halive and hrec.finished_ms is None:
                    self.engines[pair.hpeer].cancel(hrec)
                self._unhedge(pair)
            elif hwin:
                if pair.palive and prec.finished_ms is None:
                    self.engines[pair.ppeer].cancel(prec)
                # first winner answers the client: substitute wholesale
                # (nothing was delivered from the loser — whole-response
                # hedging never rewinds the client stream)
                prec.tokens[:] = hrec.tokens
                prec.admitted_ms = hrec.admitted_ms
                prec.first_token_ms = hrec.first_token_ms
                prec.finished_ms = hrec.finished_ms
                prec.rejected = False
                prec.cancelled = False
                self.chaos_stats.hedge_wins += 1
                if self.tracer is not None and prec.traced:
                    self.tracer.instant("hedge_win", hrec.finished_ms,
                                        pid=REQUEST_PID, tid=prec.request.rid,
                                        cat="request",
                                        args={"peer": pair.hpeer})
                self._unhedge(pair)
            elif not pair.palive and not pair.halive:
                # both copies rejected at admission: the shed stands
                self._unhedge(pair)

    def _sweep_peers(self, t_ms: float) -> None:
        migrate = self.defense is not None and self.defense.migration
        for i, eng in enumerate(self.engines):
            if eng.dead and not self._death_seen[i]:
                self._death_seen[i] = True
                self.chaos_stats.peers_died += 1
                if migrate:
                    self._absorb_harvested(eng.harvest(), t_ms)
            elif (migrate and not eng.dead and eng.has_work()
                  and eng.offline_until_ms - t_ms
                  > self.defense.migrate_pause_over_ms):
                # preempted for longer than the timeout: treat like a death
                # for the work's sake (the peer itself will return)
                self._absorb_harvested(eng.harvest(), t_ms)

    def _revive_due(self, t_ms: float) -> None:
        cz = self.chaos
        if cz is None or cz.recover_after_ms <= 0:
            return
        for i, eng in enumerate(self.engines):
            if not eng.dead or t_ms < eng.died_at_ms + cz.recover_after_ms:
                continue
            if not (self.defense is not None and self.defense.migration):
                eng.harvest()        # undefended: the doomed work is dropped
            params = version = None
            if self.snapshot_dir and has_snapshot(self.snapshot_dir, i):
                params = load_snapshot_params(self.snapshot_dir, i,
                                              eng.params)
                meta = snapshot_meta(self.snapshot_dir, i) or {}
                version = meta.get("step")
                # recovery pulls one replica across the slow links: bill it
                # to the same checkpoint-exchange ledger as a refresh
                self.refresh_bytes += self._param_bytes
            eng.revive(t_ms, params, version)
            if eng.health is not None:
                eng.health.ewma = 1.0    # fresh machine, fresh prior
            self._death_seen[i] = False
            self.chaos_stats.peers_recovered += 1

    def _retry_orphans(self, t_ms: float) -> None:
        for orph in list(self._orphans):
            if orph.next_attempt_ms > t_ms:
                continue
            logical: RequestRecord = orph.rec
            if logical.migrations >= self.defense.max_migrations:
                self._orphans.remove(orph)
                self.chaos_stats.migration_failures += 1
                logical.rejected = True
                continue
            cands = self._healthy(t_ms)
            if not cands:
                orph.next_attempt_ms = t_ms + self.defense.retry_backoff_ms
                continue
            peer = min(cands, key=lambda i: (self.engines[i].load, i))
            req0 = logical.request
            cont = req0.continuation(tuple(logical.tokens),
                                     max(req0.arrival_ms, t_ms))
            new_rec = self.engines[peer].enqueue(cont)
            new_rec.origin = req0
            new_rec.traced = logical.traced
            self._phys2logical[id(new_rec)] = logical
            self._continuations.append(new_rec)
            logical.migrations += 1
            self.chaos_stats.migrations += 1
            if self.tracer is not None and logical.traced:
                self.tracer.instant(
                    "migrate", t_ms, pid=REQUEST_PID, tid=req0.rid,
                    cat="request",
                    args={"attempt": logical.migrations, "to_peer": peer})
            self._orphans.remove(orph)

    def _update_admission(self, t_ms: float) -> None:
        if not (self.defense is not None and self.defense.degraded_admission):
            return
        n = len(self.engines)
        up = len(self._available(t_ms))
        q = max(1, int(self.config.max_queue * up / n)) if up else 1
        for eng in self.engines:
            eng.max_queue_live = q

    def _chaos_maintenance(self, t_ms: float) -> None:
        self._sweep_continuations(t_ms)
        self._resolve_hedges(t_ms)
        self._sweep_peers(t_ms)
        self._revive_due(t_ms)
        if self.defense is not None:
            self._retry_orphans(t_ms)
        self._update_admission(t_ms)

    def _drain_chaos(self) -> None:
        """Drain in bounded time quanta so deaths, revivals, migrations and
        hedge resolutions keep happening after the last arrival."""
        quantum = (self.defense.maintenance_quantum_ms
                   if self.defense is not None else 20.0)
        guard = 0
        while guard < 200_000:
            guard += 1
            alive = [e for e in self.engines if not e.dead]
            recovering = (self.chaos is not None
                          and self.chaos.recover_after_ms > 0
                          and any(e.dead for e in self.engines))
            work = any(e.has_work() for e in alive)
            placing = bool(self._orphans or self._continuations
                           or self._hedge_pairs)
            if not work and not placing and not (recovering and self._orphans):
                break
            if not alive and not recovering:
                break                # nothing can ever progress again
            t = max(e.now_ms for e in self.engines) + quantum
            for e in self.engines:
                e.advance_to(t)
            self._chaos_maintenance(t)
        # stragglers that finished on the final quantum
        end = max(e.now_ms for e in self.engines)
        self._chaos_maintenance(end)

    # ---- the run loop ------------------------------------------------------
    def run(self, workload: Workload, slo_ms: float = 50.0) -> FleetReport:
        chaosy = self.chaos is not None or self.defense is not None
        for req in sorted(workload.requests, key=lambda r: r.arrival_ms):
            self._maybe_refresh(req.arrival_ms)
            for eng in self.engines:
                eng.advance_to(req.arrival_ms)
            if chaosy:
                self._chaos_maintenance(req.arrival_ms)
            self._route(req)
        if chaosy:
            self._drain_chaos()
        else:
            for eng in self.engines:
                eng.drain()
        end_ms = max((eng.now_ms for eng in self.engines), default=0.0)
        self._maybe_refresh(end_ms)
        for prec, srec in self._pairs:
            self.canary_stats.observe(prec, srec)
        rep = self._report(workload, slo_ms, end_ms)
        if self.watch is not None:
            # one final evaluation after the report/canary gauges land, so
            # end-of-run rules (canary divergence) see their signals
            self.watch.evaluate(end_ms)
        return rep

    def _finalize_trace(self, end_ms: float) -> None:
        """Flush any placement whose spans were never emitted (clean
        finishes, strandings on undefended dead peers) and close every
        request's async wrapper — the export requires balanced trees even
        for rejected and unfinished requests."""
        if self.tracer is None:
            return
        for r in sorted(self._primaries, key=lambda r: r.request.rid):
            if not r.traced:
                continue
            rid = r.request.rid
            finished = r.finished_ms is not None
            if not r.trace_emitted:
                self._trace_placement(
                    r, end_ms, cancelled=not finished and not r.rejected)
            if finished:
                self.tracer.instant("emit", r.finished_ms, pid=REQUEST_PID,
                                    tid=rid, cat="request",
                                    args={"tokens": len(r.tokens)})
            close = self._trace_close.get(rid, r.request.arrival_ms)
            if finished:
                close = max(close, r.finished_ms)
            status = ("completed" if finished
                      else "rejected" if r.rejected else "unfinished")
            self.tracer.async_end(
                "request", rid, "request", max(close, r.request.arrival_ms),
                pid=REQUEST_PID, tid=rid,
                args={"status": status, "migrations": r.migrations})

    def _report(self, workload: Workload, slo_ms: float,
                end_ms: float) -> FleetReport:
        done = [r for r in self._primaries if r.finished_ms is not None]
        ttfts = [r.ttft_ms for r in done]
        e2es = [r.e2e_ms for r in done]
        m = self.metrics
        ttft_h = (m.histogram("fleet/ttft_ms") if m is not None
                  else Histogram(name="fleet/ttft_ms"))
        e2e_h = (m.histogram("fleet/e2e_ms") if m is not None
                 else Histogram(name="fleet/e2e_ms"))
        for t in ttfts:
            ttft_h.observe(t)
        for t in e2es:
            e2e_h.observe(t)
        gen = sum(len(r.tokens) for r in done)
        good = sum(len(r.tokens) for r in done
                   if r.ttft_ms is not None and r.ttft_ms <= slo_ms)
        digest = hashlib.sha256()
        for r in sorted(self._primaries, key=lambda r: r.request.rid):
            digest.update(bytes(f"{r.request.rid}:", "ascii"))
            digest.update(np.asarray(r.tokens, np.int32).tobytes())
        cs = self.chaos_stats
        sstats = [e.spec_stats for e in self.engines
                  if isinstance(e, SpecEngine)]
        sp_drafted = sum(s.drafted for s in sstats)
        sp_accepted = sum(s.accepted for s in sstats)
        rep = FleetReport(
            scenario=workload.scenario,
            router=self.policy,
            peers=len(self.engines),
            seed=workload.seed,
            completed=len(done),
            # client-facing rejections only: canary/ensemble shadows are
            # bookkeeping duplicates and must not read as shed client traffic
            rejected=sum(1 for r in self._primaries if r.rejected),
            p50_ttft_ms=ttft_h.percentile(50) if ttft_h.count else 0.0,
            p99_ttft_ms=ttft_h.percentile(99) if ttft_h.count else 0.0,
            p50_e2e_ms=e2e_h.percentile(50) if e2e_h.count else 0.0,
            p99_e2e_ms=e2e_h.percentile(99) if e2e_h.count else 0.0,
            slo_ms=slo_ms,
            slo_attainment=(sum(1 for t in ttfts if t <= slo_ms) / len(ttfts)
                            if ttfts else 0.0),
            sim_tokens_per_s=gen / (end_ms / 1e3) if end_ms > 0 else 0.0,
            generated_tokens=gen,
            kv_bytes_written=sum(e.kv_bytes_written for e in self.engines),
            refresh_bytes=self.refresh_bytes,
            refreshes=self.refreshes,
            refreshes_dropped_stale=self.refreshes_dropped_stale,
            peak_pool_utilization=max(e.peak_utilization
                                      for e in self.engines),
            canary=self.canary_stats.summary(),
            stream_digest=digest.hexdigest(),
            goodput_tokens_per_s=(good / (end_ms / 1e3) if end_ms > 0
                                  else 0.0),
            lost_tokens=sum(max(0, r.request.max_new - len(r.tokens))
                            for r in done),
            duplicated_tokens=sum(max(0, len(r.tokens) - r.request.max_new)
                                  for r in done),
            migrations=cs.migrations,
            migration_failures=cs.migration_failures,
            hedges=cs.hedges,
            hedge_wins=cs.hedge_wins,
            preemptions=sum(e.preemptions_hit for e in self.engines),
            peers_died=cs.peers_died,
            peers_recovered=cs.peers_recovered,
            spec_rounds=sum(s.rounds for s in sstats),
            spec_drafted_tokens=sp_drafted,
            spec_accepted_tokens=sp_accepted,
            spec_accept_rate=(sp_accepted / sp_drafted if sp_drafted
                              else 0.0),
            spec_fallback_ticks=sum(s.fallback_ticks for s in sstats),
        )
        self._finalize_trace(end_ms)
        if m is not None:
            # every numeric report field doubles as a gauge: the metrics
            # export and the CLI report are the same numbers by construction
            for k, v in rep.to_dict().items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    m.gauge(f"report/{k}").set(v)
            # the canary dict is skipped by the numeric mirror above, but
            # its divergence numbers are exactly what the canary alert rule
            # watches — surface them as gauges too
            m.gauge("report/canary_mean_mse").set(rep.canary["mean_mse"])
            m.gauge("report/canary_token_agreement").set(
                rep.canary["token_agreement"])
        return rep
