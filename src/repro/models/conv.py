"""ResNet / Wide-ResNet image classifiers — the paper's own vision workloads.

Used for paper-faithful experiments at reduced scale (codistillation vs
all_reduce on synthetic/CIFAR-like data) and the Section-5.1 multi-view
channel-split setup: ``forward(..., split=(i, n))`` zeroes all but the i-th of
n channel groups after the first stage, reproducing the frozen-bottleneck
"views" construction.

Adaptation note: BatchNorm is replaced with GroupNorm(8) — codistillation
experiments need deterministic, batch-size-independent normalization (the
paper's claims are not about BN statistics), and GroupNorm keeps the step
function pure (no mutable state to synchronize across codistilling replicas).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dense_init

PyTree = Any


@dataclass(frozen=True)
class ConvConfig:
    name: str
    kind: str                  # 'resnet' | 'wideresnet'
    depths: Tuple[int, ...]    # blocks per stage
    widths: Tuple[int, ...]    # channels per stage
    bottleneck: bool
    num_classes: int
    image_size: int
    groups: int = 8            # groupnorm groups
    source: str = ""

    @property
    def family(self) -> str:
        return "conv"


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    std = (2.0 / fan_in) ** 0.5
    return (jax.random.normal(key, (kh, kw, cin, cout)) * std).astype(dtype)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn(x, scale, bias, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g).astype(jnp.float32)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(n, h, w, c) * scale + bias).astype(x.dtype)


def _init_block(key, cin, cout, bottleneck, dtype=jnp.float32):
    kg = KeyGen(key)
    p: Dict = {}
    if bottleneck:
        mid = cout // 4
        p["conv1"] = _conv_init(kg(), 1, 1, cin, mid, dtype)
        p["conv2"] = _conv_init(kg(), 3, 3, mid, mid, dtype)
        p["conv3"] = _conv_init(kg(), 1, 1, mid, cout, dtype)
        dims = (mid, mid, cout)
    else:
        p["conv1"] = _conv_init(kg(), 3, 3, cin, cout, dtype)
        p["conv2"] = _conv_init(kg(), 3, 3, cout, cout, dtype)
        dims = (cout, cout)
    for i, d in enumerate(dims, 1):
        p[f"gn{i}_scale"] = jnp.ones((d,), dtype)
        p[f"gn{i}_bias"] = jnp.zeros((d,), dtype)
    if cin != cout:
        p["proj"] = _conv_init(kg(), 1, 1, cin, cout, dtype)
    return p


def _block_fwd(p, x, stride, cfg: ConvConfig):
    h = x
    if "conv3" in p:  # bottleneck
        h = jax.nn.relu(_gn(_conv(h, p["conv1"], 1), p["gn1_scale"], p["gn1_bias"], cfg.groups))
        h = jax.nn.relu(_gn(_conv(h, p["conv2"], stride), p["gn2_scale"], p["gn2_bias"], cfg.groups))
        h = _gn(_conv(h, p["conv3"], 1), p["gn3_scale"], p["gn3_bias"], cfg.groups)
    else:
        h = jax.nn.relu(_gn(_conv(h, p["conv1"], stride), p["gn1_scale"], p["gn1_bias"], cfg.groups))
        h = _gn(_conv(h, p["conv2"], 1), p["gn2_scale"], p["gn2_bias"], cfg.groups)
    sc = x
    if "proj" in p:
        sc = _conv(sc, p["proj"], stride)
    elif stride != 1:
        sc = sc[:, ::stride, ::stride]
    return jax.nn.relu(h + sc)


@dataclass(frozen=True)
class ConvNet:
    cfg: ConvConfig

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        kg = KeyGen(key)
        stem_out = cfg.widths[0] if not cfg.bottleneck else max(16, cfg.widths[0] // 4)
        params: Dict = {
            "stem": _conv_init(kg(), 3, 3, 3, stem_out, jnp.float32),
            "stem_gn_scale": jnp.ones((stem_out,)),
            "stem_gn_bias": jnp.zeros((stem_out,)),
        }
        cin = stem_out
        for s, (depth, width) in enumerate(zip(cfg.depths, cfg.widths)):
            for b in range(depth):
                params[f"s{s}b{b}"] = _init_block(kg(), cin, width,
                                                  cfg.bottleneck)
                cin = width
        params["head"] = dense_init(kg(), cin, (cfg.num_classes,))
        return params

    def forward(self, params: PyTree, batch: Dict,
                split: Optional[Tuple[int, int]] = None,
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
        """batch['images']: (B,H,W,3). split=(i,n): keep only the i-th of n
        channel groups after stage 0 (the Section-5.1 multi-view views)."""
        cfg = self.cfg
        x = batch["images"]
        x = jax.nn.relu(_gn(_conv(x, params["stem"], 1),
                            params["stem_gn_scale"], params["stem_gn_bias"],
                            cfg.groups))
        for s, (depth, _w) in enumerate(zip(cfg.depths, cfg.widths)):
            for b in range(depth):
                stride = 2 if (s > 0 and b == 0) else 1
                x = _block_fwd(params[f"s{s}b{b}"], x, stride, cfg)
            if s == 0 and split is not None:
                i, n = split
                c = x.shape[-1]
                w = c // n
                mask = jnp.zeros((c,), x.dtype).at[i * w:(i + 1) * w].set(1.0)
                x = x * mask
        x = jnp.mean(x, axis=(1, 2))
        logits = jnp.einsum("bc,ck->bk", x.astype(jnp.float32),
                            params["head"].astype(jnp.float32))
        return logits, jnp.zeros((), jnp.float32)


def freeze_mask(params: PyTree, prefixes: Tuple[str, ...]) -> PyTree:
    """1.0 for trainable leaves, 0.0 for frozen ones (stage prefixes, 'stem')."""
    def tag(path, _leaf):
        name = path[0].key if hasattr(path[0], "key") else str(path[0])
        return 0.0 if any(name.startswith(p) for p in prefixes) else 1.0
    return jax.tree_util.tree_map_with_path(tag, params)
