"""Deterministic synthetic data pipelines.

Two requirements drive the design:

1. **Learnable structure** — the paper's claims are about *training dynamics*
   (codistillation matching all_reduce, regularization effects), so batches
   must carry real signal. ``MarkovLM`` samples token streams from a fixed
   random first-order Markov chain: any LM can learn it and losses separate
   cleanly between runs.
2. **Coordinated sampling** (Section 3) — prediction-exchange codistillation
   requires that all codistilling groups process the SAME minibatch. Batches
   are pure functions of ``(seed, step [, group])``: with ``coordinated=True``
   the group index is dropped from the key, so every group reproduces the
   identical batch with zero communication (deterministic PRNG in place of a
   shared data service — the production analogue is a seed-synchronized
   dataloader, which is exactly how coordinated sampling is deployed).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MarkovLM:
    """First-order Markov chain over `vocab` tokens with `concentration`
    controlling how predictable transitions are (lower => more learnable)."""
    vocab: int
    seed: int = 0
    concentration: float = 0.3
    effective_vocab: int = 0  # 0 => vocab (cap for huge-vocab configs)

    def _transition_logits(self) -> jax.Array:
        v = self.effective_vocab or self.vocab
        key = jax.random.key(self.seed)
        return jax.random.normal(key, (v, v)) / self.concentration

    @partial(jax.jit, static_argnums=(0, 2, 3))
    def sample(self, key: jax.Array, batch: int, seq_len: int) -> jax.Array:
        v = self.effective_vocab or self.vocab
        logits = self._transition_logits()
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (batch,), 0, v)

        def step(tok, k):
            nxt = jax.random.categorical(k, logits[tok])
            return nxt, nxt

        keys = jax.random.split(k1, seq_len - 1)
        _, rest = jax.lax.scan(step, first, keys)
        toks = jnp.concatenate([first[None], rest], axis=0).T  # (B, S)
        return toks.astype(jnp.int32)


def _batch_key(seed: int, step: int, group: Optional[int]) -> jax.Array:
    data = jax.random.key(seed)
    data = jax.random.fold_in(data, step)
    if group is not None:
        data = jax.random.fold_in(data, 7919 + group)
    return data


def make_lm_batch(task: MarkovLM, batch: int, seq_len: int, step: int,
                  group: Optional[int] = None, seed: int = 0) -> Dict[str, jax.Array]:
    """Batch of (tokens, labels=next token, mask). Pure fn of (seed, step[, group])."""
    key = _batch_key(seed, step, group)
    toks = task.sample(key, batch, seq_len + 1)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "mask": jnp.ones((batch, seq_len), jnp.float32),
    }


def lm_batch_iterator(task: MarkovLM, batch: int, seq_len: int,
                      coordinated: bool, group: int = 0,
                      seed: int = 0) -> Iterator[Dict[str, jax.Array]]:
    """Infinite iterator; coordinated=True ignores the group (same batches
    for every codistilling model — prediction-exchange requirement)."""
    step = 0
    g = None if coordinated else group
    while True:
        yield make_lm_batch(task, batch, seq_len, step, g, seed)
        step += 1


def classification_batch(key: jax.Array, batch: int, dim: int,
                         num_classes: int, noise: float = 1.0,
                         image: bool = False, image_size: int = 32
                         ) -> Dict[str, jax.Array]:
    """Gaussian-cluster classification data (optionally shaped as images)."""
    kc, kx, ky = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (num_classes, dim)) * 2.0
    labels = jax.random.randint(ky, (batch,), 0, num_classes)
    x = centers[labels] + noise * jax.random.normal(kx, (batch, dim))
    out: Dict[str, jax.Array] = {"labels": labels}
    if image:
        side = image_size
        need = side * side * 3
        reps = -(-need // dim)
        img = jnp.tile(x, (1, reps))[:, :need].reshape(batch, side, side, 3)
        out["images"] = img
    else:
        out["features"] = x
    return out
