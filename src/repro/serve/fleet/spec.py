"""Peer-speculative decoding: the fleet drafts for itself.

The paper's central finding — codistilled peers converge to near-identical
functions despite weak synchronization — is exactly the property
speculative decoding wants in a draft model. ``SpecEngine`` turns it into
serving speed: a DRAFT peer (by default another codistilled replica, ring
paired; optionally a dedicated peer or a smaller student model) proposes
``k`` tokens autoregressively into a mirrored draft KV pool, and the
target peer verifies all ``k`` in ONE batched forward over its paged pool
(``model_exec.build_verify_step`` — each slot expands into k pseudo-slots
at per-slot vector positions).

Accept/reject is greedy and EXACT at temperature 0: position j's verify
logits condition only on the prompt plus drafts ``< j`` (the kernel's
causal mask), so the target's argmax at j is bitwise the token plain
decode would emit there. The engine accepts the longest matching draft
prefix, emits the target's own token at the first divergence
(reject-and-resample), and restores the rejected suffix rows of BOTH
pools from an undo log (``PagedCachePool.snapshot_rows``/``restore_rows``)
— after any round the pools are bit-identical to a never-drafted run's.
No bonus token on a full accept (at most k tokens per round): emitting
the k+1'th would leave the draft cache a row behind and need catch-up
machinery; keeping the pools in lockstep is worth one token.

Chaos interplay: a round only runs speculatively when the draft partner
is available (alive and not preempted) and every live slot's draft cache
is current. A plain-decode fallback tick marks all live slots
draft-dirty (their draft caches missed a row), so after a partner outage
the engine decodes plain until the in-flight slots drain, then resumes
speculating on fresh admissions — no replay machinery, and the output
stream is identical either way.

The accept rate is a live codistillation-quality signal (how often the
peers' argmaxes agree, measured on real traffic) — exported as the
``fleet/spec_accept`` histogram and per-report ``spec_accept_rate``,
alongside the offline ``distill_pair`` canary.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.fleet.batcher import (REQUEST_PID, FleetConfig, FleetEngine,
                                       _shared_exec, _shared_verify)
from repro.serve.fleet.cache import PagedCachePool

PyTree = Any


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs.

    Simulated cost model: a speculative round costs
    ``k * draft_ms_per_token + verify_ms`` instead of
    ``decode_ms_per_step``, and emits up to k tokens. ``draft_ms_per_token
    < decode_ms_per_step`` is the speculative bet made honest: the draft
    steps run on the PARTNER peer (concurrent hardware, overlapped with
    its own serving), and the verify is one memory-bound forward that
    streams the KV pool once — same traffic as one plain step.
    ``verify_ms`` None charges exactly ``decode_ms_per_step``.
    ``draft_peer`` None ring-pairs every peer with its neighbor (all
    peers serve); an int dedicates that peer to drafting (excluded from
    the serving rotation).
    """
    k: int = 4
    draft_ms_per_token: float = 0.25
    verify_ms: Optional[float] = None
    draft_peer: Optional[int] = None


@dataclass
class SpecStats:
    """Deterministic per-engine speculation counters (summed per-report)."""
    rounds: int = 0
    drafted: int = 0          # k per live slot per speculative round
    accepted: int = 0         # matching draft prefix length (raw agreement)
    fallback_ticks: int = 0   # decode ticks that ran plain (partner down /
                              # draft caches stale)

    @property
    def accept_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0


class SpecEngine(FleetEngine):
    """A FleetEngine whose decode tick speculates: k draft steps on the
    partner's weights against a mirrored draft pool, one batched k-token
    verify on its own, greedy accept/reject-and-resample. Attention-only
    models (rollback of recurrent sublayer state is not supported —
    ``build_verify_step`` raises)."""

    def __init__(self, model, params: PyTree, config: FleetConfig,
                 spec: SpecConfig, cache_dtype=jnp.float32,
                 keep_logits: bool = False, peer_id: int = 0, tracer=None,
                 metrics=None, draft_model=None, draft_params: PyTree = None):
        super().__init__(model, params, config, cache_dtype=cache_dtype,
                         keep_logits=keep_logits, peer_id=peer_id,
                         tracer=tracer, metrics=metrics)
        self.spec = spec
        self.spec_stats = SpecStats()
        self.partner: Optional[FleetEngine] = None   # ring/dedicated pairing
        self._draft_model = draft_model or model
        self._draft_params_static = draft_params     # student mode when set
        # the verify step rejects recurrent architectures at build time —
        # fail at engine construction, not mid-round
        self._verify = _shared_verify(model, cache_dtype,
                                      config.fused_attention, spec.k)
        self._draft_decode, self._draft_prefill = _shared_exec(
            self._draft_model, cache_dtype, config.fused_attention)
        self.draft_pool = PagedCachePool(
            self._draft_model, max_slots=config.max_slots,
            block_size=config.block_size, num_blocks=config.num_blocks,
            max_blocks_per_slot=config.max_blocks_per_slot,
            cache_dtype=cache_dtype)
        dcfg = self._draft_model.cfg
        n_attn = len(self.draft_pool.kv_subs) * self.draft_pool.n_scan
        per_row = (dcfg.num_kv_heads * dcfg.resolved_head_dim
                   * jnp.dtype(cache_dtype).itemsize)
        if self.draft_pool.quantized:
            per_row += 4
        self._draft_kv_bytes_per_token = int(n_attn * 2 * per_row)
        self._verify_ms = (spec.verify_ms if spec.verify_ms is not None
                           else config.decode_ms_per_step)
        self._draft_dirty: set = set()
        self._last_spec = False

    # ---- pairing -----------------------------------------------------------
    def set_partner(self, engine: FleetEngine) -> None:
        self.partner = engine

    def _partner_available(self) -> bool:
        if self._draft_params_static is not None:
            return True              # static student: always on this host
        p = self.partner
        return (p is not None and not p.dead
                and p.offline_until_ms <= self.now_ms)

    def _draft_params(self) -> PyTree:
        if self._draft_params_static is not None:
            return self._draft_params_static
        return self.partner.params   # read at draft time: refresh-current

    # ---- lifecycle sync: the draft pool mirrors the target pool ------------
    def _admit(self) -> int:
        before = set(self.slots)
        admitted_tokens = super()._admit()
        for s in sorted(set(self.slots) - before):
            req = self.slots[s].record.request
            # mirror the reservation even when the partner is down: block
            # sequencing in the draft pool stays deterministic either way
            self.draft_pool.allocate(s, req.prompt_len + req.max_new)
            if self._partner_available():
                tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
                _, dcache = self._draft_prefill(
                    self._draft_params(), {"tokens": tokens}, req.prompt_len)
                self.draft_pool.insert_prefill(s, dcache, req.prompt_len)
                self.kv_bytes_written += (req.prompt_len
                                          * self._draft_kv_bytes_per_token)
            else:
                self._draft_dirty.add(s)
        return admitted_tokens

    def _sync_draft_free(self) -> None:
        for s in range(self.config.max_slots):
            if s not in self.slots and self.draft_pool.slot_blocks[s]:
                self.draft_pool.free_slot(s)
                self._draft_dirty.discard(s)

    def _evict(self, finish_ms: float) -> None:
        super()._evict(finish_ms)
        self._sync_draft_free()

    def harvest(self) -> List:
        out = super().harvest()
        self._sync_draft_free()
        return out

    def cancel(self, rec) -> None:
        super().cancel(rec)
        self._sync_draft_free()

    def _defrag(self) -> None:
        super()._defrag()
        self.draft_pool.defrag()

    # ---- the speculative decode tick ---------------------------------------
    def _decode_cost_ms(self) -> float:
        if self._last_spec:
            return self._verify_ms + self.spec.k * self.spec.draft_ms_per_token
        return self.config.decode_ms_per_step

    def _decode_tick(self) -> int:
        live = sorted(s for s, sl in self.slots.items() if sl.remaining > 0)
        if not live:
            return 0
        if (not self._partner_available()
                or any(s in self._draft_dirty for s in live)):
            # plain fallback: every live slot's draft cache misses this row
            self._last_spec = False
            self._draft_dirty.update(live)
            self.spec_stats.fallback_ticks += 1
            if self.metrics is not None:
                self.metrics.counter("fleet/spec_fallback_ticks").inc()
            return super()._decode_tick()
        self._last_spec = True
        return self._spec_round(live)

    def _spec_round(self, live: List[int]) -> int:
        k = self.spec.k
        S = self.config.max_slots
        active = np.zeros((S,), bool)
        active[live] = True
        base_len = self.pool.lengths.copy()

        # --- draft phase: k sequential one-token steps on the partner's
        # weights against the mirrored draft pool (undo log first)
        d_snaps = {s: self.draft_pool.snapshot_rows(s, int(base_len[s]), k)
                   for s in live}
        d_wslots, d_woffs = self.draft_pool.write_maps_k(active, k)
        dparams = self._draft_params()
        dtable = jnp.asarray(self.draft_pool.table)
        dkv, dstates = self.draft_pool.kv, self.draft_pool.states
        tok = np.zeros((S, 1), np.int32)
        for s in live:
            tok[s, 0] = self.slots[s].next_token
        drafts = np.zeros((S, k), np.int32)
        verify_in = np.zeros((S, k), np.int32)
        for j in range(k):
            verify_in[:, j] = tok[:, 0]
            logits, dkv, dstates = self._draft_decode(
                dparams, dkv, dstates, dtable,
                jnp.asarray(self.draft_pool.lengths + j),
                jnp.asarray(d_wslots[j]), jnp.asarray(d_woffs[j]),
                jnp.asarray(tok))
            drafts[:, j] = np.asarray(jnp.argmax(logits, axis=-1))
            tok = drafts[:, j:j + 1].astype(np.int32)
        self.draft_pool.kv, self.draft_pool.states = dkv, dstates

        # --- verify phase: ONE batched k-token forward on the target pool
        t_snaps = {s: self.pool.snapshot_rows(s, int(base_len[s]), k)
                   for s in live}
        wslots, woffs = self.pool.write_maps_k(active, k)
        vlogits, kv, states = self._verify(
            self.params, self.pool.kv, self.pool.states,
            jnp.asarray(self.pool.table), jnp.asarray(base_len),
            jnp.asarray(wslots), jnp.asarray(woffs), jnp.asarray(verify_in))
        self.pool.kv, self.pool.states = kv, states
        greedy = np.asarray(jnp.argmax(vlogits, axis=-1))   # (S, k)

        # --- accept the matching prefix, resample the divergence, roll back
        ctx_rows = 0
        total_m = 0
        for s in live:
            sl = self.slots[s]
            m = 0
            while m < k and drafts[s, m] == greedy[s, m]:
                m += 1
            stream = ([int(t) for t in drafts[s, :m]] if m == k
                      else [int(t) for t in drafts[s, :m]] + [int(greedy[s, m])])
            e = min(sl.remaining, len(stream))
            if e < k:
                self.pool.restore_rows(t_snaps[s], start=e)
                self.draft_pool.restore_rows(d_snaps[s], start=e)
            for t in stream[:e]:
                sl.record.tokens.append(t)
            sl.next_token = stream[e - 1]
            sl.remaining -= e
            self.pool.lengths[s] += e
            self.draft_pool.lengths[s] = self.pool.lengths[s]
            self.decode_tokens += e
            self.kv_bytes_written += e * (self._kv_bytes_per_token
                                          + self._draft_kv_bytes_per_token)
            ctx_rows += sum(int(base_len[s]) + j + 1 for j in range(k))
            total_m += m
            self.spec_stats.drafted += k
            self.spec_stats.accepted += m
            if self.metrics is not None:
                self.metrics.histogram("fleet/spec_accept").observe(float(m))
            if self.tracer is not None and sl.record.traced:
                self.tracer.instant(
                    "spec_round", self.now_ms, pid=REQUEST_PID,
                    tid=sl.record.request.rid, cat="request",
                    args={"accepted": m, "drafted": k})
        self.spec_stats.rounds += 1
        if self.metrics is not None:
            self.metrics.counter("fleet/spec_rounds").inc()
            self.metrics.counter("fleet/spec_drafted_tokens").inc(
                k * len(live))
            self.metrics.counter("fleet/spec_accepted_tokens").inc(total_m)
            # running accept rate across THIS engine's rounds: the live
            # view of the label-free quality canary the accept-collapse
            # alert rule watches (the report's spec_accept_rate is the
            # same ratio aggregated fleet-wide at end of run)
            self.metrics.gauge("fleet/spec_accept_rate").set(
                round(self.spec_stats.accepted
                      / max(1, self.spec_stats.drafted), 6))
        if self.tracer is not None:
            d0 = self.now_ms
            d1 = d0 + k * self.spec.draft_ms_per_token
            self.tracer.complete(
                "draft", d0, d1, pid=self._pid, cat="spec",
                args={"k": k, "slots": len(live),
                      "draft_peer": (self.partner.peer_id
                                     if self.partner is not None else -1)})
            self.tracer.complete(
                "verify", d1, d1 + self._verify_ms, pid=self._pid, cat="spec",
                args={"accepted": total_m, "drafted": k * len(live)})
        return ctx_rows
