"""Figure 17: scaling n with a FIXED TOTAL update budget (steps per model =
budget / n) degrades — n-way codistillation does not buy linear scaling in
the number of codistilled models."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import CodistConfig, TrainConfig
from repro.train import train_codist

from benchmarks.common import coord_batches, lm_setup, timed


def run(quick: bool = False) -> List[Dict]:
    model, task = lm_setup()
    budget = 48 if quick else 160
    rows: List[Dict] = []
    losses = {}
    for n in (2, 4, 8):
        steps = budget // n
        tc = TrainConfig(lr=3e-3, total_steps=steps,
                         warmup_steps=max(2, steps // 10),
                         optimizer="adamw", lr_schedule="cosine", seed=0)
        codist = CodistConfig(n_models=n, alpha0=1.0)
        (_, hist), us = timed(
            lambda n=n, cd=codist, tc=tc: train_codist(
                model, cd, tc, coord_batches(task, n, 8, 32),
                log_every=max(1, steps - 1)),
            warmup=0, iters=1)
        loss = hist.records[-1]["task_loss"]
        losses[n] = loss
        rows.append({"name": f"fig17/n{n}_steps{steps}",
                     "us_per_call": us, "derived": round(loss, 4)})
    rows.append({"name": "fig17/degrades_with_n",
                 "derived": int(losses[8] > losses[2])})
    return rows
