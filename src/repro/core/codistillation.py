"""Codistillation (Algorithm 1) as a composable JAX module.

The ``n`` codistilling models are represented as a **stacked pytree** — every
parameter gains a leading axis of size ``n``. Under pjit that axis is sharded
over the ``"pod"`` mesh axis, so each pod physically holds and trains one
replica; referencing another model's logits inside the loss becomes a pod-axis
all-gather of logits, which is exactly the paper's "communicate predictions"
implementation (Section 3).

The total loss for one step is

    L(theta_1..n) = (1/n) sum_i [ task(f_i(x_i), y_i)
                    + alpha/(n-1) sum_{j!=i} D(f_i(x_i), sg(f_j(x_i))) ]

With coordinated sampling (prediction mode) x_i == x_j, so a single vmap'd
forward produces every f_j(x_i) needed; ``stop_gradient`` on the target side
makes one backward pass compute exactly the Algorithm-1 update for all models
simultaneously.

Loss math dispatches through the ``fused_losses`` flag (see ``_fused_enabled``
and docs/fused_losses.md): when enabled, the streaming custom-VJP Pallas
kernels in ``repro.kernels`` replace the jnp paths below, eliminating every
(T, V) fp32 temporary from the forward and backward of the hot path.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import CodistConfig

PyTree = Any


# ----------------------------------------------------------------------------
# fused-loss dispatch
# ----------------------------------------------------------------------------
# Every loss below takes ``fused``: None => auto (on for TPU), bool => forced.
# When enabled, the streaming custom-VJP Pallas kernels in repro.kernels.ops
# replace the jnp math — same values and gradients (parity-tested to <=1e-4 in
# tests/test_kernel_grads.py) without materializing (T, V) fp32 temporaries
# (logsumexp / softmax / one-hot at vocab width) in forward OR backward.

def _fused_enabled(fused: Optional[bool]) -> bool:
    if fused is None:
        # auto: pallas_call carries no SPMD partitioning rule, so when a
        # tensor-parallel axis is active (vocab-sharded lm head) the kernels
        # would force a full logits gather — exactly what the one-hot jnp CE
        # below avoids. Auto keeps the jnp path there; fused=True overrides.
        from repro.models.sharding_hints import tensor_parallel_active
        if tensor_parallel_active():
            return False
        from repro.kernels.ops import fused_losses_default
        return fused_losses_default()
    return bool(fused)


# ----------------------------------------------------------------------------
# task losses
# ----------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  label_smoothing: jax.Array | float = 0.0,
                  mask: Optional[jax.Array] = None,
                  fused: Optional[bool] = None) -> jax.Array:
    """Mean token-level CE with optional label smoothing and validity mask.

    logits: (..., V) float; labels: (...) int32; mask: (...) broadcastable.
    """
    if _fused_enabled(fused):
        from repro.kernels.ops import fused_cross_entropy_loss
        return fused_cross_entropy_loss(logits, labels, label_smoothing, mask)
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: SPMD-friendly when the
    # vocab axis is sharded (partial sums per shard + a scalar-sized psum,
    # instead of an all-gather of the full logits tensor).
    onehot = jax.nn.one_hot(labels, v, dtype=logits.dtype)
    true_logit = jnp.sum(logits * onehot, axis=-1)
    nll = logz - true_logit
    ls = jnp.asarray(label_smoothing, jnp.float32)
    # smoothed loss: (1-ls)*nll + ls * mean_v (logz - logit_v)
    smooth = logz - jnp.mean(logits, axis=-1)
    loss = (1.0 - ls) * nll + ls * smooth
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)


def accuracy(logits: jax.Array, labels: jax.Array,
             mask: Optional[jax.Array] = None) -> jax.Array:
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(correct * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(correct)


# ----------------------------------------------------------------------------
# distillation losses D(y, y')   (paper: MSE between UNCENTERED logits, A.3)
# ----------------------------------------------------------------------------

def distill_mse(logits: jax.Array, target_logits: jax.Array,
                mask: Optional[jax.Array] = None,
                fused: Optional[bool] = None) -> jax.Array:
    """Mean squared error between logits — the paper's D."""
    if _fused_enabled(fused):
        from repro.kernels.ops import fused_distill_mean
        return fused_distill_mean(logits, target_logits, "mse", mask)
    d = (logits.astype(jnp.float32) - target_logits.astype(jnp.float32)) ** 2
    per_tok = jnp.mean(d, axis=-1)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(per_tok)


def distill_kl(logits: jax.Array, target_logits: jax.Array,
               mask: Optional[jax.Array] = None,
               temperature: float = 1.0,
               fused: Optional[bool] = None) -> jax.Array:
    """KL(softmax(target) || softmax(logits)) — Zhang et al. / Anil et al.'s D."""
    if temperature == 1.0 and _fused_enabled(fused):
        from repro.kernels.ops import fused_distill_mean
        return fused_distill_mean(logits, target_logits, "kl", mask)
    lt = target_logits.astype(jnp.float32) / temperature
    ls = logits.astype(jnp.float32) / temperature
    p = jax.nn.softmax(lt, axis=-1)
    per_tok = jnp.sum(p * (jax.nn.log_softmax(lt, axis=-1)
                           - jax.nn.log_softmax(ls, axis=-1)), axis=-1)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(per_tok)


def distill_ce(logits: jax.Array, target_logits: jax.Array,
               mask: Optional[jax.Array] = None) -> jax.Array:
    """Soft cross-entropy against the peer's softmax."""
    p = jax.nn.softmax(target_logits.astype(jnp.float32), axis=-1)
    per_tok = -jnp.sum(p * jax.nn.log_softmax(logits.astype(jnp.float32), -1), -1)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(per_tok)


_DISTILL = {"mse": distill_mse, "kl": distill_kl, "ce": distill_ce}


def distill_pair(kind: str, logits: jax.Array, target_logits: jax.Array,
                 mask: Optional[jax.Array] = None,
                 fused: Optional[bool] = None) -> jax.Array:
    if kind in ("mse", "kl"):
        return _DISTILL[kind](logits, target_logits, mask, fused=fused)
    return _DISTILL[kind](logits, target_logits, mask)  # 'ce': jnp only


# ----------------------------------------------------------------------------
# beyond-paper: compressed prediction exchange
# ----------------------------------------------------------------------------

def _hierarchical_topk(x: jax.Array, k: int, segments: int = 16):
    """Exact top-k via per-segment top-k + top-k of the candidate union.

    Equivalent to ``jax.lax.top_k`` (every global top-k element is in its
    segment's top-k) but SPMD-friendly: with the vocab sharded over the tensor
    axis, stage 1 sorts only the unsharded within-segment dim — XLA's global
    top-k would otherwise gather the full fp32 logits tensor (the dominant
    cross-pod collective in the naive compressed exchange).
    """
    from repro.models.sharding_hints import hint
    *lead, v = x.shape
    if v % segments or v // segments < k:
        return jax.lax.top_k(x, k)
    seg = v // segments
    xs = hint(x.reshape(*lead, segments, seg), "wire")
    lv, li = jax.lax.top_k(xs, k)                       # (..., segments, k)
    lv, li = hint(lv, "wire"), hint(li, "wire")
    li = li + (jnp.arange(segments) * seg)[:, None]
    lv = lv.reshape(*lead, segments * k)
    li = li.reshape(*lead, segments * k)
    gv, gi = jax.lax.top_k(hint(lv, "wire"), k)         # (..., k)
    idx = jnp.take_along_axis(li, gi, axis=-1)
    return hint(gv, "wire"), hint(idx, "wire")


def compress_targets(cfg: CodistConfig, target_logits: jax.Array) -> Dict:
    """Compress the peer logits before they cross the pod boundary.

    Returns an array-only 'wire' pytree (vmappable over the stacked model
    axis — this is what makes compression happen on the PRODUCER pod, so the
    cross-pod collective moves the compressed representation, not the raw
    (B, S, V) logits). ``distill_vs_compressed`` consumes it; all static
    metadata (kind, stride) is recomputed from cfg + shapes.
    """
    if cfg.compression == "bf16":
        return {"vals": target_logits.astype(jnp.bfloat16)}
    if cfg.compression == "topk":
        vals, idx = _hierarchical_topk(target_logits, cfg.topk)
        return {"vals": vals, "idx": idx}
    if cfg.compression == "subsample" and cfg.subsample:
        # strided token subset along the sequence axis (axis=-2 of (B,S,V))
        s = target_logits.shape[-2]
        stride = max(1, s // cfg.subsample)
        sl = target_logits[..., ::stride, :][..., : cfg.subsample, :]
        return {"vals": sl}
    return {"vals": target_logits}


def _subsample_stride(cfg: CodistConfig, full_seq: int) -> int:
    return max(1, full_seq // cfg.subsample)


def _compress_stacked(cfg: CodistConfig, targets: jax.Array) -> Dict:
    """compress_targets over the stacked (n, ...) axis, pod-local when a
    pod-axis mesh is active (see codist_loss)."""
    from repro.models.sharding_hints import current_mesh
    mesh = current_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        from jax.sharding import PartitionSpec as P

        def comp(t):
            return compress_targets(cfg, t)

        out_specs = jax.tree.map(lambda _: P("pod"),
                                 jax.eval_shape(comp, targets))
        return compat.shard_map(comp, mesh=mesh, in_specs=P("pod"),
                             out_specs=out_specs, axis_names={"pod"},
                             check_vma=False)(targets)
    return compress_targets(cfg, targets)


def _podlocal_codist_terms(cfg: CodistConfig, mesh,
                           logits_all: jax.Array, labels_all: jax.Array,
                           alpha, label_smoothing,
                           mask_all: Optional[jax.Array]):
    """(task, distill) per model with a PINNED exchange schedule.

    Everything is computed inside a shard_map manual over "pod": each pod
    evaluates its own model's task CE and compresses its logits locally; the
    ONLY cross-pod communication is ``jax.lax.all_gather`` of the compressed
    wire. Consuming ``logits_all[i]`` at the pjit top level instead lets the
    partitioner mask+all-reduce full logits-shaped tensors across pods (the
    dominant cross-pod collective in the naive lowering).
    """
    from jax.sharding import PartitionSpec as P
    n = logits_all.shape[0]
    if mask_all is None:
        mask_all = jnp.ones(labels_all.shape, jnp.float32)

    def per_pod(lg1, lb1, m1, ls):
        lg, lb, m = lg1[0], lb1[0], m1[0]
        task = cross_entropy(lg, lb, ls, m)
        wire = compress_targets(cfg, jax.lax.stop_gradient(lg))
        wires_all = jax.tree.map(lambda x: jax.lax.all_gather(x, "pod"), wire)
        idx = jax.lax.axis_index("pod")
        dist = jnp.zeros((), jnp.float32)
        for j in range(n):
            wire_j = jax.tree.map(lambda x: x[j], wires_all)
            d = distill_vs_compressed(cfg, lg, wire_j, m)
            dist = dist + jnp.where(idx == j, 0.0, d)
        dist = dist / max(1, n - 1)
        return jnp.stack([task, dist])[None]        # (1, 2) pod-sharded

    rows = compat.shard_map(
        per_pod, mesh=mesh,
        in_specs=(P("pod"), P("pod"), P("pod"), P()),
        out_specs=P("pod", None),
        axis_names={"pod"}, check_vma=False,
    )(logits_all, labels_all, mask_all,
      jnp.asarray(label_smoothing, jnp.float32))
    return rows[:, 0], rows[:, 1]


def distill_vs_compressed(cfg: CodistConfig, logits: jax.Array, wire: Dict,
                          mask: Optional[jax.Array] = None,
                          fused: Optional[bool] = None) -> jax.Array:
    kind = cfg.compression if cfg.compression != "none" else "none"
    if cfg.compression == "subsample" and not cfg.subsample:
        kind = "none"
    if kind in ("none", "bf16"):
        # full-vocab-width targets: the streaming kernels apply
        return distill_pair(cfg.distill_loss, logits, wire["vals"], mask,
                            fused=fused)
    if kind == "topk":
        own = jnp.take_along_axis(logits, wire["idx"], axis=-1)
        if cfg.distill_loss == "mse":
            d = (own.astype(jnp.float32) - wire["vals"].astype(jnp.float32)) ** 2
            per_tok = jnp.mean(d, axis=-1)
        else:  # renormalized soft-CE over the top-k support
            p = jax.nn.softmax(wire["vals"].astype(jnp.float32), -1)
            per_tok = -jnp.sum(p * jax.nn.log_softmax(own.astype(jnp.float32), -1), -1)
        if mask is not None:
            m = mask.astype(jnp.float32)
            return jnp.sum(per_tok * m) / jnp.maximum(jnp.sum(m), 1.0)
        return jnp.mean(per_tok)
    if kind == "subsample":
        stride = _subsample_stride(cfg, logits.shape[-2])
        k = wire["vals"].shape[-2]
        own = logits[..., ::stride, :][..., :k, :]
        sub_mask = None
        if mask is not None:
            sub_mask = mask[..., ::stride][..., :k]
        # subsampled tokens keep full vocab width: kernels still apply
        return distill_pair(cfg.distill_loss, own, wire["vals"], sub_mask,
                            fused=fused)
    raise ValueError(kind)


# ----------------------------------------------------------------------------
# Algorithm 1: the combined codistillation loss over stacked logits
# ----------------------------------------------------------------------------

def codist_loss(cfg: CodistConfig,
                logits_all: jax.Array,          # (n, ..., V)
                labels_all: jax.Array,          # (n, ...)
                alpha: jax.Array | float,
                label_smoothing: jax.Array | float = 0.0,
                mask_all: Optional[jax.Array] = None,
                peer_logits_all: Optional[jax.Array] = None,
                peer_pairwise: Optional[jax.Array] = None,
                fused: Optional[bool] = None,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean over models of (task + alpha * mean_peers D(own, sg(peer))).

    ``peer_logits_all`` overrides the distillation targets (pipelined exchange
    provides stale logits); ``peer_pairwise`` has shape (n, n, ...) where
    [i, j] = model j's predictions on model i's batch (checkpoint mode, where
    every group evaluates the stale replicas on its OWN minibatch). Default is
    the live stacked logits (prediction mode with coordinated sampling).

    With ``fused`` enabled (auto on TPU) and a full-vocab-width first peer
    wire, each model's task CE and first distillation term come from the
    COMBINED Pallas kernel — one read of that model's (T, V) logits instead
    of two sweeps.
    """
    n = logits_all.shape[0]
    targets = peer_logits_all if peer_logits_all is not None else logits_all
    targets = jax.lax.stop_gradient(targets)
    if peer_pairwise is not None:
        peer_pairwise = jax.lax.stop_gradient(peer_pairwise)

    # pod-axis mesh active + live prediction exchange: pin the exchange
    # schedule with the pod-local shard_map path — the ONLY cross-pod
    # communication is the all_gather of the (compressed) wire. The naive
    # pjit lowering lets the partitioner mask+all-reduce full logits-shaped
    # tensors across pods instead.
    from repro.models.sharding_hints import current_mesh
    mesh = current_mesh()
    if (mesh is not None and "pod" in mesh.axis_names
            and cfg.compression == "topk"
            and peer_logits_all is None and peer_pairwise is None and n > 1):
        task, dist = _podlocal_codist_terms(cfg, mesh, logits_all, labels_all,
                                            alpha, label_smoothing, mask_all)
        alpha = jnp.asarray(alpha, jnp.float32)
        total = jnp.mean(task + alpha * dist)
        return total, {
            "loss": total, "task_loss": jnp.mean(task),
            "distill_loss": jnp.mean(dist),
            "task_loss_per_model": task, "distill_loss_per_model": dist,
            "alpha": alpha,
        }

    # compress on the PRODUCER side so only the compressed wire crosses the
    # pod links. XLA's sort partitioner REPLICATES top_k operands across every
    # mesh axis (it would move the raw logits cross-pod and compress after),
    # so when a pod-axis mesh is active the compression runs inside a narrow
    # shard_map manual over "pod" — correctness identical, schedule pinned.
    wires_all = _compress_stacked(cfg, targets)
    use_fused = _fused_enabled(fused)

    task_losses = []
    distill_losses = []
    for i in range(n):
        m_i = None if mask_all is None else mask_all[i]
        wires_i = []
        for j in range(n):
            if j == i:
                continue
            if peer_pairwise is not None:
                wires_i.append(compress_targets(cfg, peer_pairwise[i, j]))
            else:
                wires_i.append(jax.tree.map(lambda x: x[j], wires_all))
        # hot path: fuse the task CE with the first distillation term so the
        # student logits are swept once (combined kernel); extra peers reuse
        # the streaming pairwise kernel.
        combined = (use_fused and wires_i
                    and cfg.distill_loss in ("mse", "kl")
                    and set(wires_i[0]) == {"vals"}
                    and wires_i[0]["vals"].shape == logits_all[i].shape)
        if combined:
            from repro.kernels.ops import fused_ce_distill
            task_i, d0 = fused_ce_distill(
                logits_all[i], wires_i[0]["vals"], labels_all[i],
                mode=cfg.distill_loss, label_smoothing=label_smoothing,
                mask=m_i)
            wire_d = [d0] + [distill_vs_compressed(cfg, logits_all[i], w,
                                                   m_i, fused=use_fused)
                             for w in wires_i[1:]]
        else:
            task_i = cross_entropy(logits_all[i], labels_all[i],
                                   label_smoothing, m_i, fused=use_fused)
            wire_d = [distill_vs_compressed(cfg, logits_all[i], w, m_i,
                                            fused=use_fused)
                      for w in wires_i]
        task_losses.append(task_i)
        distill_losses.append(sum(wire_d) / (n - 1) if wire_d
                              else jnp.asarray(0.0, jnp.float32))

    task = jnp.stack(task_losses)
    dist = jnp.stack(distill_losses)
    alpha = jnp.asarray(alpha, jnp.float32)
    total = jnp.mean(task + alpha * dist)
    metrics = {
        "loss": total,
        "task_loss": jnp.mean(task),
        "distill_loss": jnp.mean(dist),
        "task_loss_per_model": task,
        "distill_loss_per_model": dist,
        "alpha": alpha,
    }
    return total, metrics


# ----------------------------------------------------------------------------
# stacked-pytree helpers
# ----------------------------------------------------------------------------

def init_stacked(init_fn: Callable[[jax.Array], PyTree], key: jax.Array,
                 n: int) -> PyTree:
    """n independent inits, stacked along a new leading axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def model_slice(stacked: PyTree, i: int) -> PyTree:
    return jax.tree.map(lambda x: x[i], stacked)


def stack_models(trees: list[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def param_distance_from(params: PyTree, ref: PyTree) -> jax.Array:
    """||theta - theta_0||_2 — used for the Fig. 7 regularization-effect study."""
    sq = jax.tree.map(lambda a, b: jnp.sum((a.astype(jnp.float32)
                                            - b.astype(jnp.float32)) ** 2),
                      params, ref)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))
