"""Figure 6: n-way codistillation under controlled multi-view structure.

Setup (mirrors the paper's frozen-bottleneck channel-split construction):
  * every sample's views are noisy random projections of ONE shared
    class-conditioned latent — each view partially predictive, views
    correlated through the latent (like channel splits of a pretrained
    representation);
  * a FIXED small training pool with 40% label noise (finite noisy data is
    where ensemble-like distillation signal has something to buy — the
    Allen-Zhu & Li mechanism);
  * eval on fresh, clean samples, each model evaluated on its own view.

Scenarios map to the paper's groups:
  * enforced — model i sees only view (i mod V) throughout ('pretrained,
    frozen'): consistent n-way gains expected;
  * shared   — all models see the SAME view ('random init' single split):
    at most a small n=2 bump, flat beyond;
  * all_views — unsplit upper bound.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import CodistConfig, TrainConfig
from repro.data.multiview import MultiViewTask, multiview_batch
from repro.models.mlp import MLP, MLPConfig
from repro.train import stack_batches, train_codist
from repro.train import make_codist_eval_step

from benchmarks.common import timed

TASK = MultiViewTask(n_views=8, view_dim=8, latent_dim=24, num_classes=10,
                     seed=0)
TRAIN_POOL = 8       # 8 x 64 = 512 fixed training samples
LABEL_NOISE = 0.4


def _noisy_labels(labels: jax.Array, pool_step: int) -> jax.Array:
    kn = jax.random.fold_in(jax.random.key(777), pool_step)
    flip = jax.random.bernoulli(kn, LABEL_NOISE, labels.shape)
    rand = jax.random.randint(jax.random.fold_in(kn, 1), labels.shape, 0,
                              TASK.num_classes)
    return jnp.where(flip, rand, labels)


def _batches(n: int, scenario: str, b: int = 64, seed: int = 0,
             fresh: bool = False):
    def fn(step):
        src = step if fresh else (step % TRAIN_POOL)
        raw = multiview_batch(TASK, b, src,
                              seed=seed + (100000 if fresh else 0))
        labels = raw["labels"] if fresh else _noisy_labels(raw["labels"], src)
        per_model = []
        for i in range(n):
            view = (i % TASK.n_views) if scenario == "enforced" else 0
            feats = raw["features"]
            if scenario != "all_views":
                feats = feats * TASK.view_mask(view)
            per_model.append({"features": feats, "labels": labels})
        return stack_batches(per_model)
    return fn


def _eval_acc(model, state, n, scenario, steps=8) -> float:
    """Held-out accuracy on FRESH CLEAN samples, per-model views."""
    ev = jax.jit(make_codist_eval_step(model))
    batches = _batches(n, scenario, fresh=True)
    accs = []
    for s in range(1000, 1000 + steps):
        accs.append(float(ev(state.params, batches(s))["eval_accuracy"]))
    return sum(accs) / len(accs)


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    steps = 150 if quick else 400
    model = MLP(MLPConfig(in_dim=TASK.dim, hidden=(128, 128),
                          num_classes=TASK.num_classes))
    tc = TrainConfig(lr=3e-3, total_steps=steps, warmup_steps=5,
                     optimizer="adamw", lr_schedule="cosine", seed=0)
    accs: Dict[str, Dict[int, float]] = {}
    for scenario in ("enforced", "shared", "all_views"):
        ns = (1, 2, 4, 8)
        if scenario == "all_views":
            ns = (1,)
        for n in ns:
            codist = CodistConfig(n_models=n, alpha0=2.0 if n > 1 else 0.0,
                                  distill_loss="kl")
            (state, hist), us = timed(
                lambda n=n, sc=scenario, cd=codist: train_codist(
                    model, cd, tc, _batches(n, sc), log_every=steps - 1),
                warmup=0, iters=1)
            acc = _eval_acc(model, state, n, scenario)
            accs.setdefault(scenario, {})[n] = acc
            rows.append({"name": f"fig6/{scenario}_n{n}",
                         "us_per_call": us, "derived": round(acc, 4)})
    e = accs["enforced"]
    s = accs["shared"]
    rows.append({"name": "fig6/enforced_monotone_gain",
                 "derived": int(e[8] > e[2] > e[1])})
    rows.append({"name": "fig6/shared_no_large_n_gain",
                 "derived": int((s[8] - s[1]) < (e[8] - e[1]))})
    return rows
