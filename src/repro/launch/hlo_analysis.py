"""Parse compiled HLO text for collective traffic.

Extracts every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op with its shape bytes and replica groups, then classifies
each collective as INTRA-POD or CROSS-POD given the mesh device layout — the
measurement behind the paper's Figure-1 claim (only inter-server/inter-pod
bytes count) derived directly from the compiled artifact.

Handles both explicit ``replica_groups={{0,1},{2,3}}`` and iota
``replica_groups=[8,2]<=[16]`` / ``[32,16]<=[16,32]T(1,0)`` forms.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[16,4096]{1,0}' or tuple '(f32[2], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def _parse_replica_groups(attr: str) -> Optional[List[List[int]]]:
    """Explicit groups '{{0,1},{2,3}}' -> [[0,1],[2,3]]."""
    m = re.search(r"replica_groups=\{(\{[^=]*?\})\}", attr)
    if not m:
        return None
    groups = re.findall(r"\{([0-9, ]*)\}", m.group(1))
    out = []
    for g in groups:
        g = g.strip()
        out.append([int(x) for x in g.split(",")] if g else [])
    return out


def _parse_iota_groups(attr: str) -> Optional[List[List[int]]]:
    """Iota form: replica_groups=[G,S]<=[d0,d1,...]T(perm) -> groups."""
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?",
        attr)
    if not m:
        return None
    g, s = int(m.group(1)), int(m.group(2))
    reshape_dims = [int(x) for x in m.group(3).split(",")]
    n = int(np.prod(reshape_dims))
    ids = np.arange(n).reshape(reshape_dims)
    if m.group(4):
        perm = [int(x) for x in m.group(4).split(",")]
        ids = ids.transpose(perm)
    return ids.reshape(g, s).tolist()


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    operand_bytes: int
    groups: Optional[List[List[int]]]
    cross_pod: bool
    line: str = ""


@dataclass
class CollectiveSummary:
    ops: List[CollectiveOp] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(o.operand_bytes for o in self.ops)

    @property
    def cross_pod_bytes(self) -> int:
        return sum(o.operand_bytes for o in self.ops if o.cross_pod)

    @property
    def intra_pod_bytes(self) -> int:
        return self.total_bytes - self.cross_pod_bytes

    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for o in self.ops:
            out[o.kind] = out.get(o.kind, 0) + o.operand_bytes
        return out

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for o in self.ops:
            out[o.kind] = out.get(o.kind, 0) + 1
        return out


def _crosses_pods(groups: Optional[List[List[int]]],
                  devices_per_pod: int) -> bool:
    if not groups or devices_per_pod <= 0:
        return False
    for g in groups:
        pods = {d // devices_per_pod for d in g}
        if len(pods) > 1:
            return True
    return False


def parse_collectives(hlo_text: str, devices_per_pod: int = 0
                      ) -> CollectiveSummary:
    """devices_per_pod=256 for the (2,16,16) multi-pod mesh; 0 => single pod."""
    summary = CollectiveSummary()
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match 'op-name(' as the instruction, e.g. '%ag = bf16[..] all-gather(..'
        m = re.search(r"=\s*(\([^)]*\)|\S+)\s+([a-z\-]+)(?:-start|-done)?\(", ls)
        if not m:
            continue
        kind = m.group(2)
        if kind not in _COLLECTIVES:
            continue
        result_bytes = _shape_bytes(m.group(1))
        # operand shapes: everything inside the call parens that looks like shapes
        call = ls[m.end():]
        operand_bytes = _shape_bytes(call.split(")")[0]) or result_bytes
        groups = _parse_replica_groups(ls) or _parse_iota_groups(ls)
        if kind == "collective-permute":
            # source_target_pairs instead of replica groups
            pairs = re.search(r"source_target_pairs=(\{\{.*?\}\})", ls)
            cross = False
            if pairs and devices_per_pod:
                for pm in re.finditer(r"\{(\d+),(\d+)\}", pairs.group(1)):
                    a, b = int(pm.group(1)), int(pm.group(2))
                    if a // devices_per_pod != b // devices_per_pod:
                        cross = True
                        break
            summary.ops.append(CollectiveOp(kind, result_bytes, operand_bytes,
                                            None, cross, ls[:160]))
            continue
        cross = _crosses_pods(groups, devices_per_pod)
        summary.ops.append(CollectiveOp(kind, result_bytes, operand_bytes,
                                        groups, cross, ls[:160]))
    return summary


def parse_flops_bytes(cost: Dict) -> Tuple[float, float]:
    """cost_analysis() dict -> (flops, bytes accessed)."""
    flops = float(cost.get("flops", 0.0))
    b = cost.get("bytes accessed", 0.0)
    return flops, float(b)
