"""RWKV6 "Finch" block: data-dependent decay linear attention (attention-free).

Time-mix uses the RWKV6 recurrence per head (hd = rwkv.head_dim):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

with data-dependent per-channel decay w_t = exp(-exp(w0 + lora_w(x))) and a
learned bonus u. Training/prefill uses a *chunked* form — within a chunk the
pairwise decay factors exp(L_{t-1} - L_j) <= 1 are computed from cumulative
log-decays (never overflow), across chunks a lax.scan carries S. This is the
TPU-native adaptation of the fused CUDA wkv kernel: the (C, C, hd) working set
is bounded by the chunk size and head sharding. A sequential lax.scan reference
(`rwkv_wkv_sequential`) is the oracle for property tests.

Channel-mix is the RWKV squared-relu FFN with token shift.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RWKVConfig
from repro.models.common import KeyGen, dense_init, zeros


def _dims(cfg: ModelConfig) -> Tuple[int, int, RWKVConfig]:
    r = cfg.rwkv or RWKVConfig()
    heads = cfg.d_model // r.head_dim
    return heads, r.head_dim, r


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------

def init_time_mix(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    d = cfg.d_model
    h, hd, r = _dims(cfg)
    p = {
        "w_r": dense_init(kg(), d, (d,), dtype),
        "w_k": dense_init(kg(), d, (d,), dtype),
        "w_v": dense_init(kg(), d, (d,), dtype),
        "w_g": dense_init(kg(), d, (d,), dtype),
        "w_o": dense_init(kg(), d, (d,), dtype,
                          scale=1.0 / max(1, cfg.num_layers) ** 0.5),
        # decay: w0 + lora (tanh bottleneck), per channel
        "decay_base": jnp.linspace(-6.0, -0.5, d).astype(dtype),
        "decay_lora_a": dense_init(kg(), d, (r.decay_lora,), dtype),
        "decay_lora_b": dense_init(kg(), r.decay_lora, (d,), dtype, scale=0.1),
        "bonus": (jax.random.normal(kg(), (h, hd)) * 0.1).astype(dtype),
        # token-shift data-dependent mixers: base mu + lora per stream (r,k,v,w,g)
        "mix_base": (jax.random.uniform(kg(), (5, d))).astype(dtype),
        "mix_lora_a": dense_init(kg(), d, (5, r.mix_lora), dtype),
        "mix_lora_b": (jax.random.normal(kg(), (5, r.mix_lora, d)) * 0.01).astype(dtype),
        "ln_x_scale": jnp.ones((d,), dtype),  # per-head groupnorm on y
        "ln_x_bias": zeros((d,), dtype),
    }
    return p


def init_channel_mix(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    d = cfg.d_model
    return {
        "w_k": dense_init(kg(), d, (cfg.d_ff,), dtype),
        "w_v": dense_init(kg(), cfg.d_ff, (d,), dtype,
                          scale=1.0 / max(1, cfg.num_layers) ** 0.5),
        "w_r": dense_init(kg(), d, (d,), dtype),
        "mix_k": (jax.random.uniform(kg(), (d,))).astype(dtype),
        "mix_r": (jax.random.uniform(kg(), (d,))).astype(dtype),
    }


# ----------------------------------------------------------------------------
# token shift
# ----------------------------------------------------------------------------

def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """x_{t-1}; first position takes `prev` (decode carry) or zeros."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _tm_streams(p: Dict, x: jax.Array, x_prev: jax.Array):
    """RWKV6 data-dependent token shift -> the 5 mixed streams (r,k,v,w,g)."""
    xx = x_prev - x
    # first-stage mix uses mix_base[0]'s sibling: RWKV6 uses a dedicated mu_x;
    # we reuse the mean of the bases for the lora input mix (faithful in spirit)
    mu_x = jnp.mean(p["mix_base"].astype(x.dtype), axis=0)
    xxx = x + xx * mu_x
    lora_in = jnp.tanh(jnp.einsum("bld,dsr->blsr", xxx,
                                  p["mix_lora_a"].astype(x.dtype)))
    deltas = jnp.einsum("blsr,srd->blsd", lora_in,
                        p["mix_lora_b"].astype(x.dtype))       # (B,L,5,d)
    mixes = p["mix_base"].astype(x.dtype)[None, None] + deltas  # (B,L,5,d)
    streams = x[:, :, None] + xx[:, :, None] * mixes            # (B,L,5,d)
    return [streams[:, :, i] for i in range(5)]


def _heads(x: jax.Array, h: int, hd: int) -> jax.Array:
    b, l, _ = x.shape
    return x.reshape(b, l, h, hd)


def _group_norm(y: jax.Array, scale: jax.Array, bias: jax.Array,
                eps: float = 64e-5) -> jax.Array:
    """Per-head layernorm on (B,L,H,hd), flattened back to (B,L,d)."""
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    b, l, h, hd = y.shape
    yn = yn.reshape(b, l, h * hd)
    return yn * scale + bias


# ----------------------------------------------------------------------------
# the wkv recurrence: sequential oracle + chunked parallel form
# ----------------------------------------------------------------------------

def rwkv_wkv_sequential(r: jax.Array, k: jax.Array, v: jax.Array,
                        w: jax.Array, u: jax.Array,
                        s0: jax.Array | None = None):
    """Exact recurrence via lax.scan. r/k/v/w: (B,L,H,hd) fp32; u: (H,hd).
    Returns (y (B,L,H,hd), s_final (B,H,hd,hd))."""
    b, l, h, hd = r.shape
    s_init = jnp.zeros((b, h, hd, hd), jnp.float32) if s0 is None else s0

    def step(s, xs):
        rt, kt, vt, wt = xs  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s_init, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin


def rwkv_wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array,
                     w: jax.Array, u: jax.Array, chunk: int = 64,
                     s0: jax.Array | None = None):
    """Chunked parallel form; matches the sequential oracle to fp32 tolerance."""
    b, l, h, hd = r.shape
    if l % chunk != 0:
        return rwkv_wkv_sequential(r, k, v, w, u, s0)
    nc = l // chunk
    s_init = jnp.zeros((b, h, hd, hd), jnp.float32) if s0 is None else s0

    rc, kc, vc, wc = (t.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)
                      for t in (r, k, v, w))

    def per_chunk(s, xs):
        rt, kt, vt, wt = xs                       # (B,C,H,hd)
        logw = jnp.log(jnp.maximum(wt, 1e-38))
        li = jnp.cumsum(logw, axis=1)             # inclusive L_t
        le = li - logw                            # exclusive L_{t-1}
        # inter-chunk: y_t += (r_t * exp(L_{t-1}))^T s
        y_inter = jnp.einsum("bchk,bhkv->bchv", rt * jnp.exp(le), s)
        # intra-chunk: pairwise decay exp(L_{t-1} - L_j), j < t (never > 1)
        decay = jnp.exp(jnp.clip(le[:, :, None] - li[:, None, :], -60.0, 0.0))
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
        att = jnp.einsum("bthk,bjhk,btjhk->bthj", rt, kt, decay)
        att = att * tri[None, :, None, :]
        y_intra = jnp.einsum("bthj,bjhv->bthv", att, vt)
        # diagonal bonus term
        bonus = jnp.einsum("bchk,bchk->bch", rt * u[None, None], kt)
        y = y_inter + y_intra + bonus[..., None] * vt
        # state update: S' = diag(exp(L_C)) S + sum_j diag(exp(L_C - L_j)) k_j v_j
        lc = li[:, -1:]                           # (B,1,H,hd)
        s_new = jnp.exp(lc[:, 0])[..., None] * s + jnp.einsum(
            "bjhk,bjhv->bhkv", kt * jnp.exp(jnp.clip(lc - li, -60.0, 0.0)), vt)
        return s_new, y

    s_fin, ys = jax.lax.scan(per_chunk, s_init, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, h, hd)
    return y, s_fin


def _decay(p: Dict, xw: jax.Array) -> jax.Array:
    """Data-dependent decay w_t in (0,1): exp(-exp(base + lora(xw)))."""
    lora = jnp.einsum("bld,dr->blr", xw, p["decay_lora_a"].astype(xw.dtype))
    lora = jnp.einsum("blr,rd->bld", jnp.tanh(lora),
                      p["decay_lora_b"].astype(xw.dtype))
    raw = p["decay_base"].astype(jnp.float32) + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(raw))


def time_mix_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                     shift_prev: jax.Array | None = None,
                     s0: jax.Array | None = None, chunk: int = 64,
                     sequential: bool = False):
    """x: (B,L,d) -> (y, (last_x, s_final)) — carries enable decode."""
    from repro.models.runtime_flags import resolve_chunk
    # NOTE: probe-mode widens the chunk to the full sequence so the wkv cost
    # is statically visible; the pairwise-decay part of the probed cost is
    # then an UPPER BOUND that overcounts by L/chunk (production chunk=64) —
    # EXPERIMENTS.md §Roofline applies the analytic correction.
    chunk = resolve_chunk(chunk, x.shape[1])
    h, hd, _ = _dims(cfg)
    x_prev = _shift(x, shift_prev)
    xr, xk, xv, xw, xg = _tm_streams(p, x, x_prev)
    r = _heads(jnp.einsum("bld,de->ble", xr, p["w_r"].astype(x.dtype)), h, hd)
    k = _heads(jnp.einsum("bld,de->ble", xk, p["w_k"].astype(x.dtype)), h, hd)
    v = _heads(jnp.einsum("bld,de->ble", xv, p["w_v"].astype(x.dtype)), h, hd)
    g = jax.nn.silu(jnp.einsum("bld,de->ble", xg, p["w_g"].astype(x.dtype)))
    w = _heads(_decay(p, xw), h, hd)
    u = p["bonus"].astype(jnp.float32)
    wkv = rwkv_wkv_sequential if sequential else (
        lambda *a, **kw: rwkv_wkv_chunked(*a, chunk=chunk, **kw))
    y, s_fin = wkv(r.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32), w, u, s0=s0)
    y = _group_norm(y, p["ln_x_scale"].astype(jnp.float32),
                    p["ln_x_bias"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bld,de->ble", y * g, p["w_o"].astype(x.dtype))
    return out, (x[:, -1:], s_fin)


def channel_mix_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                        shift_prev: jax.Array | None = None):
    x_prev = _shift(x, shift_prev)
    xx = x_prev - x
    xk = x + xx * p["mix_k"].astype(x.dtype)
    xr = x + xx * p["mix_r"].astype(x.dtype)
    k = jnp.einsum("bld,df->blf", xk, p["w_k"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("blf,fd->bld", k, p["w_v"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bld,de->ble", xr, p["w_r"].astype(x.dtype)))
    return r * v, x[:, -1:]
