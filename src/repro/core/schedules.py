"""Learning-rate, weight-decay, label-smoothing and alpha schedules.

The paper's key finding (Section 4 / A.4) is that codistillation is itself a
regularizer, so the *explicit* regularization must be decayed over training:

  - L2 weight decay 5e-4 initially, 1e-5 after the first LR decay, 0 after the
    second (vision workloads);
  - label smoothing removed/decayed for NMT;
  - LR-decay milestones shifted later (15/30/40 -> 18/38/44 epochs) because the
    codistilled training loss saturates more slowly;
  - alpha^k = 1 constant for vision, grown by gamma=1.1 per epoch for NMT.

All schedules are pure functions of the integer step so they can be evaluated
on host or traced into the step function as scalar args.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax.numpy as jnp


# ----------------------------------------------------------------------------
# learning rate
# ----------------------------------------------------------------------------

def linear_scaled_lr(base_lr: float, batch_size: int, base_batch: int = 256) -> float:
    """Goyal et al. linear LR scaling: lr = base_lr * batch / base_batch."""
    return base_lr * batch_size / base_batch


def warmup_factor(step, warmup_steps: int):
    if warmup_steps <= 0:
        return jnp.ones_like(jnp.asarray(step, jnp.float32))
    s = jnp.asarray(step, jnp.float32)
    return jnp.minimum(1.0, (s + 1.0) / float(warmup_steps))


def stepwise_lr(step, base_lr: float, total_steps: int,
                milestones: Sequence[float] = (0.5, 0.75, 0.9),
                decay: float = 0.1, warmup_steps: int = 0):
    """Step-wise schedule of Goyal et al.; milestones are fractions of total."""
    s = jnp.asarray(step, jnp.float32)
    factor = jnp.ones_like(s)
    for m in milestones:
        factor = factor * jnp.where(s >= m * total_steps, decay, 1.0)
    return base_lr * factor * warmup_factor(step, warmup_steps)


def cosine_lr(step, base_lr: float, total_steps: int, warmup_steps: int = 0,
              final_fraction: float = 0.0):
    """Half-cosine schedule (He et al., 'bag of tricks')."""
    s = jnp.asarray(step, jnp.float32)
    t = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * t))
    lo = final_fraction
    return base_lr * (lo + (1.0 - lo) * cos) * warmup_factor(step, warmup_steps)


def make_lr_fn(kind: str, base_lr: float, total_steps: int, warmup_steps: int = 0,
               milestones: Sequence[float] = (0.5, 0.75, 0.9), decay: float = 0.1):
    if kind == "step":
        return lambda step: stepwise_lr(step, base_lr, total_steps, milestones,
                                        decay, warmup_steps)
    if kind == "cosine":
        return lambda step: cosine_lr(step, base_lr, total_steps, warmup_steps)
    if kind == "constant":
        return lambda step: base_lr * warmup_factor(step, warmup_steps)
    raise ValueError(f"unknown lr schedule {kind!r}")


# ----------------------------------------------------------------------------
# weight decay — the paper's codistillation-aware schedule
# ----------------------------------------------------------------------------

def scheduled_weight_decay(step, total_steps: int,
                           values: Sequence[float] = (5e-4, 1e-5, 0.0),
                           milestones: Sequence[float] = (0.5, 0.75)):
    """Piecewise-constant weight decay keyed to LR-decay milestones.

    Paper (A.4): start at values[0]; after milestone[i] use values[i+1].
    len(values) == len(milestones) + 1.
    """
    assert len(values) == len(milestones) + 1
    s = jnp.asarray(step, jnp.float32)
    wd = jnp.full_like(s, values[0])
    for m, v in zip(milestones, values[1:]):
        wd = jnp.where(s >= m * total_steps, v, wd)
    return wd


def constant_weight_decay(step, value: float = 1e-4):
    return jnp.full_like(jnp.asarray(step, jnp.float32), value)


# ----------------------------------------------------------------------------
# label smoothing (NMT) — decayed to counter codistillation regularization
# ----------------------------------------------------------------------------

def decayed_label_smoothing(step, total_steps: int, initial: float = 0.1,
                            mode: str = "linear"):
    """Label smoothing decayed to zero over training (Section 4.2 / A.5)."""
    s = jnp.asarray(step, jnp.float32)
    t = jnp.clip(s / max(1, total_steps), 0.0, 1.0)
    if mode == "linear":
        return initial * (1.0 - t)
    if mode == "off":  # paper's strongest variant: remove it entirely
        return jnp.zeros_like(s)
    raise ValueError(mode)


# ----------------------------------------------------------------------------
# alpha (codistillation penalty coefficient)
# ----------------------------------------------------------------------------

def alpha_schedule(step, alpha0: float = 1.0, growth: float = 1.0,
                   steps_per_epoch: int = 1, burn_in_steps: int = 0,
                   max_alpha: float = 100.0):
    """alpha^k = alpha0 * growth^epoch(k); zero during burn-in.

    Paper: alpha = 1 constant for vision; growth = 1.1 per epoch for NMT.
    Burn-in follows Anil et al. (codistillation switched on after warm-up).
    """
    s = jnp.asarray(step, jnp.float32)
    epoch = jnp.floor(s / max(1, steps_per_epoch))
    a = alpha0 * jnp.power(growth, epoch)
    a = jnp.minimum(a, max_alpha)
    return jnp.where(s < burn_in_steps, 0.0, a)
