"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are the semantic ground truth: small, obviously-correct implementations
with fp32 internal math. Kernel tests sweep shapes/dtypes and assert each
Pallas kernel (interpret=True on CPU) matches its oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def cross_entropy_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token CE loss. logits (T, V), labels (T,) -> (T,) fp32."""
    lg = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    true = jnp.take_along_axis(lg, labels[:, None], axis=-1)[:, 0]
    return logz - true


def distill_mse_ref(logits: jax.Array, target: jax.Array) -> jax.Array:
    """Per-token mean-over-vocab squared error (the paper's D). (T,V)x2 -> (T,)."""
    d = logits.astype(jnp.float32) - target.astype(jnp.float32)
    return jnp.mean(d * d, axis=-1)


def distill_kl_ref(logits: jax.Array, target: jax.Array) -> jax.Array:
    """Per-token KL(softmax(target) || softmax(logits)). (T,V)x2 -> (T,)."""
    lt = target.astype(jnp.float32)
    ls = logits.astype(jnp.float32)
    p = jax.nn.softmax(lt, axis=-1)
    return jnp.sum(p * (jax.nn.log_softmax(lt, -1) - jax.nn.log_softmax(ls, -1)),
                   axis=-1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        window: int = 0,
                        scale: Optional[float] = None) -> jax.Array:
    """GQA attention. q (B,S,H,hd), k/v (B,T,KV,hd) -> (B,S,H,hd) fp32 math."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else hd ** -0.5
    qg = q.astype(jnp.float32).reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k.astype(jnp.float32)) * scale
    t = k.shape[1]
    if causal:
        i = jnp.arange(s)[:, None]
        j = jnp.arange(t)[None, :]
        mask = j <= i
        if window > 0:
            mask = mask & (i - j < window)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return o.reshape(b, s, h, hd)
