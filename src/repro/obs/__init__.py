"""Deterministic observability: simulated-clock tracing + metrics +
alerting.

    trace.py     span/event tracer keyed to the simulated clocks; exports
                 Chrome/Perfetto trace-event JSON, bit-identical per seed
    metrics.py   counters / gauges / fixed-bucket histograms with exact
                 quantiles — the one percentile implementation in the repo
    watch.py     Watchtower: declarative alert rules (threshold /
                 burn-rate / EWMA-drift) evaluated over the registry on
                 the simulated clock; bit-identical alert JSONL per seed
    recorder.py  FlightRecorder: bounded ring of recent trace events,
                 dumps postmortem bundles on alert or injected fault
    fsio.py      atomic artifact writes (tmp + fsync + os.replace)

Instrumented subsystems (all hooks are no-ops when no tracer/registry is
attached — the hot paths are untouched on the default path):

    runtime/scheduler.py   per-peer step/publish/recover spans, mailbox
                           staleness + comm counters
    train/loop.py          per-step spans, exchange markers, comm counters
    serve/fleet/           per-request span trees (admit→queue→prefill→
                           decode→…→emit, surviving migration), per-tick
                           engine spans, KV-pool occupancy and analytic
                           decode HBM/FLOP counter streams

Surfaced as ``--trace out.json --metrics out-metrics.json`` on
``repro.launch.train``, ``repro.launch.serve`` and ``repro.launch.sweep``;
``tools/trace_check.py`` validates exported traces in CI. See
docs/observability.md.
"""
from repro.obs.fsio import atomic_write_text  # noqa: F401
from repro.obs.metrics import (DEFAULT_BUCKETS, GAUGE_WINDOW,  # noqa: F401
                               METRICS_SCHEMA_VERSION, Counter, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.recorder import (POSTMORTEM_SCHEMA_VERSION,  # noqa: F401
                                FlightRecorder)
from repro.obs.trace import (TRACE_SCHEMA_VERSION, TraceError,  # noqa: F401
                             Tracer, for_sim_ms, for_sim_seconds, for_steps)
from repro.obs.watch import (ALERTS_SCHEMA_VERSION, Rule,  # noqa: F401
                             Watchtower, default_rules, load_rules,
                             parse_rules)
