"""Small MLP classifier — the controlled model for the Section-5.1
multi-view experiments (stands in for the channel-split Wide-ResNet: what
matters is which VIEW of the features each codistilling model receives)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dense_init

PyTree = Any


@dataclass(frozen=True)
class MLPConfig:
    name: str = "mlp"
    in_dim: int = 128
    hidden: Tuple[int, ...] = (256, 256)
    num_classes: int = 10
    kind: str = "mlp"  # marks non-LM path for the train steps

    @property
    def family(self) -> str:
        return "mlp"


@dataclass(frozen=True)
class MLP:
    cfg: MLPConfig

    def init(self, key: jax.Array) -> PyTree:
        kg = KeyGen(key)
        dims = (self.cfg.in_dim, *self.cfg.hidden, self.cfg.num_classes)
        return {f"w{i}": dense_init(kg(), a, (b,))
                for i, (a, b) in enumerate(zip(dims, dims[1:]))} | {
                f"b{i}": jnp.zeros((b,))
                for i, b in enumerate(dims[1:])}

    def forward(self, params: PyTree, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        x = batch["features"].astype(jnp.float32)
        n = len(self.cfg.hidden) + 1
        for i in range(n):
            x = x @ params[f"w{i}"] + params[f"b{i}"]
            if i < n - 1:
                x = jax.nn.relu(x)
        return x, jnp.zeros((), jnp.float32)
