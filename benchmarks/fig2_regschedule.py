"""Figure 2(a,b) / Section 4.1: constant explicit regularization
over-regularizes a codistilled model; the paper's decayed weight-decay
schedule (5e-4 -> 1e-5 -> 0 at LR milestones) closes the gap.

Reported: final held-out loss for codistillation with constant vs scheduled
weight decay (same data, steps, LR schedule)."""
from __future__ import annotations

from typing import Dict, List

import jax

from repro.configs import CodistConfig, TrainConfig
from repro.data import make_lm_batch
from repro.train import stack_batches, train_codist
from repro.train import make_codist_eval_step

from benchmarks.common import coord_batches, lm_setup, timed


def run(quick: bool = False) -> List[Dict]:
    model, task = lm_setup()
    steps = 60 if quick else 200
    base = dict(lr=3e-3, total_steps=steps, warmup_steps=5,
                optimizer="adamw", lr_schedule="step",
                step_milestones=(0.5, 0.75), seed=0)
    # heavy constant L2 vs the paper's decayed schedule
    tc_const = TrainConfig(weight_decay=5e-3, **base)
    tc_sched = TrainConfig(weight_decay=5e-3,
                           weight_decay_schedule=(5e-3, 1e-4, 0.0), **base)
    codist = CodistConfig(n_models=2, alpha0=1.0)
    ev = jax.jit(make_codist_eval_step(model))

    def heldout(state):
        vals = []
        for s in range(5000, 5008):
            batch = stack_batches([make_lm_batch(task, 16, 32, s, None, seed=9)
                                   for _ in range(2)])
            vals.append(float(ev(state.params, batch)["eval_loss"]))
        return sum(vals) / len(vals)

    rows: List[Dict] = []
    out = {}
    for tag, tc in (("constant_wd", tc_const), ("scheduled_wd", tc_sched)):
        (state, hist), us = timed(
            lambda tc=tc: train_codist(model, codist, tc,
                                       coord_batches(task, 2, 8, 32),
                                       log_every=steps - 1),
            warmup=0, iters=1)
        loss = heldout(state)
        out[tag] = loss
        rows.append({"name": f"fig2/heldout_{tag}", "us_per_call": us,
                     "derived": round(loss, 4)})
    rows.append({"name": "fig2/schedule_improves",
                 "derived": int(out["scheduled_wd"] <= out["constant_wd"])})
    return rows
