"""Core codistillation library (the paper's contribution)."""
from repro.core.codistillation import (  # noqa: F401
    accuracy,
    codist_loss,
    compress_targets,
    cross_entropy,
    distill_ce,
    distill_kl,
    distill_mse,
    distill_pair,
    distill_vs_compressed,
    init_stacked,
    model_slice,
    param_distance_from,
    stack_models,
)
from repro.core.comm_model import (  # noqa: F401
    CommCost,
    allreduce_bits,
    codist_checkpoint_bits,
    codist_cost,
    codist_prediction_bits,
    model_bits,
    paper_resnet50_numbers,
    prediction_bits_classifier,
    prediction_bits_lm,
)
from repro.core.exchange import StepPlan  # noqa: F401
