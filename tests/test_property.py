"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional dev dependency (declared in
requirements-dev.txt); the module skips cleanly where it isn't installed
instead of aborting collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import CodistConfig, get_reduced
from repro.core import codistillation as cd
from repro.core import comm_model as cm
from repro.core import schedules as sched
from repro.models.rwkv import rwkv_wkv_chunked, rwkv_wkv_sequential
from repro.models.mamba import mamba_scan, _scan_assoc

S = settings(max_examples=25, deadline=None)


class TestCommModelProperties:
    @S
    @given(b_model=st.floats(1e3, 1e12), n=st.integers(2, 16),
           t=st.integers(1, 10000))
    def test_checkpoint_cost_monotone_in_period(self, b_model, n, t):
        c1 = cm.codist_checkpoint_bits(b_model, n, t)
        c2 = cm.codist_checkpoint_bits(b_model, n, t * 2)
        assert c2.bits_per_iter_per_device == pytest.approx(
            c1.bits_per_iter_per_device / 2)

    @S
    @given(b_pred=st.floats(1.0, 1e9), batch=st.integers(1, 4096),
           n=st.integers(2, 16), t=st.integers(1, 1000))
    def test_prediction_cost_scales_linearly(self, b_pred, batch, n, t):
        c = cm.codist_prediction_bits(b_pred, batch, n, t)
        c2 = cm.codist_prediction_bits(b_pred, batch * 2, n, t)
        assert c2.bits_per_iter_per_device == pytest.approx(
            2 * c.bits_per_iter_per_device, rel=1e-9)
        assert c.bits_per_iter_per_device == pytest.approx(
            (n - 1) * b_pred * batch / t, rel=1e-9)


class TestDistillProperties:
    @S
    @given(seed=st.integers(0, 10_000), scale=st.floats(0.1, 10.0))
    def test_mse_symmetry(self, seed, scale):
        k1, k2 = jax.random.split(jax.random.key(seed))
        a = jax.random.normal(k1, (3, 5, 16)) * scale
        b = jax.random.normal(k2, (3, 5, 16)) * scale
        assert float(cd.distill_mse(a, b)) == pytest.approx(
            float(cd.distill_mse(b, a)), rel=1e-5)

    @S
    @given(seed=st.integers(0, 10_000))
    def test_kl_nonnegative_and_zero_iff_equal(self, seed):
        k1, k2 = jax.random.split(jax.random.key(seed))
        a = jax.random.normal(k1, (2, 4, 12))
        b = jax.random.normal(k2, (2, 4, 12))
        assert float(cd.distill_kl(a, b)) >= -1e-6
        assert float(cd.distill_kl(a, a)) == pytest.approx(0.0, abs=1e-5)

    @S
    @given(seed=st.integers(0, 10_000), shift=st.floats(-5.0, 5.0))
    def test_kl_shift_invariance(self, seed, shift):
        """Adding a constant to all logits leaves KL unchanged (softmax inv)."""
        k1, k2 = jax.random.split(jax.random.key(seed))
        a = jax.random.normal(k1, (2, 3, 8))
        b = jax.random.normal(k2, (2, 3, 8))
        d1 = float(cd.distill_kl(a, b))
        d2 = float(cd.distill_kl(a + shift, b + shift))
        assert d1 == pytest.approx(d2, rel=1e-3, abs=1e-5)


class TestScheduleProperties:
    @S
    @given(step=st.integers(0, 10_000), total=st.integers(100, 20_000),
           base=st.floats(1e-5, 1.0))
    def test_cosine_bounded(self, step, total, base):
        lr = float(sched.cosine_lr(step, base, total, warmup_steps=10))
        assert 0.0 <= lr <= base * (1 + 1e-6)

    @S
    @given(step=st.integers(0, 1000), growth=st.floats(1.0, 1.2))
    def test_alpha_monotone_nondecreasing(self, step, growth):
        a1 = float(sched.alpha_schedule(step, 1.0, growth, 10))
        a2 = float(sched.alpha_schedule(step + 10, 1.0, growth, 10))
        assert a2 >= a1 - 1e-6

    @S
    @given(total=st.integers(10, 1000))
    def test_wd_schedule_is_nonincreasing(self, total):
        vals = [float(sched.scheduled_weight_decay(s, total)) for s in
                range(0, total, max(1, total // 17))]
        assert all(x >= y - 1e-12 for x, y in zip(vals, vals[1:]))


class TestScanEquivalence:
    @S
    @given(seed=st.integers(0, 1000), chunk=st.sampled_from([4, 8, 16]))
    def test_rwkv_chunked_equals_sequential(self, seed, chunk):
        """The chunked wkv form is exactly the recurrence (assoc law)."""
        b, l, h, hd = 2, 32, 2, 8
        ks = jax.random.split(jax.random.key(seed), 5)
        r = jax.random.normal(ks[0], (b, l, h, hd))
        k = jax.random.normal(ks[1], (b, l, h, hd))
        v = jax.random.normal(ks[2], (b, l, h, hd))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, l, h, hd))) * 0.8 + 0.1
        u = jax.random.normal(ks[4], (h, hd)) * 0.1
        y1, s1 = rwkv_wkv_sequential(r, k, v, w, u)
        y2, s2 = rwkv_wkv_chunked(r, k, v, w, u, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)

    @S
    @given(seed=st.integers(0, 1000), chunk=st.sampled_from([4, 8, 32]))
    def test_mamba_chunked_scan_equals_full(self, seed, chunk):
        b, l, d, n = 2, 32, 4, 3
        k1, k2 = jax.random.split(jax.random.key(seed))
        a_bar = jax.nn.sigmoid(jax.random.normal(k1, (b, l, d, n)))
        bx = jax.random.normal(k2, (b, l, d, n))
        h_full = _scan_assoc(a_bar, bx)
        h_chunk = mamba_scan(a_bar, bx, chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_full), np.asarray(h_chunk),
                                   rtol=1e-4, atol=1e-5)

    @S
    @given(seed=st.integers(0, 1000))
    def test_rwkv_state_carry_composition(self, seed):
        """wkv over [x1;x2] == wkv(x2, s0=wkv(x1).state) — decode correctness."""
        b, l, h, hd = 1, 16, 2, 4
        ks = jax.random.split(jax.random.key(seed), 5)
        r = jax.random.normal(ks[0], (b, l, h, hd))
        k = jax.random.normal(ks[1], (b, l, h, hd))
        v = jax.random.normal(ks[2], (b, l, h, hd))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, l, h, hd))) * 0.8 + 0.1
        u = jax.random.normal(ks[4], (h, hd)) * 0.1
        y_full, s_full = rwkv_wkv_sequential(r, k, v, w, u)
        half = l // 2
        y1, s1 = rwkv_wkv_sequential(r[:, :half], k[:, :half], v[:, :half],
                                     w[:, :half], u)
        y2, s2 = rwkv_wkv_sequential(r[:, half:], k[:, half:], v[:, half:],
                                     w[:, half:], u, s0=s1)
        np.testing.assert_allclose(np.asarray(y_full[:, half:]),
                                   np.asarray(y2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)


class TestOptimizerProperties:
    @S
    @given(seed=st.integers(0, 1000), lr=st.floats(1e-4, 1e-1))
    def test_sgd_zero_grad_zero_wd_is_identity(self, seed, lr):
        from repro.optim import make_optimizer
        params = {"w": jax.random.normal(jax.random.key(seed), (4,))}
        init, update = make_optimizer("sgdm")
        state = init(params)
        grads = {"w": jnp.zeros((4,))}
        new, _ = update(params, grads, state, lr, 0.0)
        np.testing.assert_allclose(np.asarray(new["w"]),
                                   np.asarray(params["w"]))

    @S
    @given(seed=st.integers(0, 1000))
    def test_weight_decay_shrinks_params(self, seed):
        from repro.optim import make_optimizer
        params = {"w": jax.random.normal(jax.random.key(seed), (8,)) + 5.0}
        init, update = make_optimizer("sgdm")
        grads = {"w": jnp.zeros((8,))}
        new, _ = update(params, grads, init(params), 0.1, 0.5)
        assert float(jnp.linalg.norm(new["w"])) < float(
            jnp.linalg.norm(params["w"]))


class TestMicrobatchEquivalence:
    @S
    @given(seed=st.integers(0, 100))
    def test_grad_accumulation_matches_full_batch(self, seed):
        """k-microbatch fp32 accumulation == full-batch gradient (linearity
        of the mean-CE loss in the batch axis)."""
        from repro.train.engine import _grads_with_metrics
        w0 = jax.random.normal(jax.random.key(seed), (6, 4))
        x = jax.random.normal(jax.random.key(seed + 1), (8, 6))
        y = jax.random.randint(jax.random.key(seed + 2), (8,), 0, 4)

        def loss_fn(params, batch):
            logits = batch["x"] @ params
            l = cd.cross_entropy(logits, batch["y"])
            return l, {"loss": l}

        g_full, _ = _grads_with_metrics(loss_fn, w0, {"x": x, "y": y}, 1)
        mb = {"x": x.reshape(4, 2, 6), "y": y.reshape(4, 2)}
        g_acc, _ = _grads_with_metrics(loss_fn, w0, mb, 4)
        np.testing.assert_allclose(np.asarray(g_full), np.asarray(g_acc),
                                   rtol=1e-5, atol=1e-6)
