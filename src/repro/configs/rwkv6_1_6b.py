"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free [arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536. 32 heads of dim 64 in the WKV time-mix.
"""
from repro.configs.base import ModelConfig, RWKVConfig, reduced as _reduced

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,   # wkv heads = d_model / rwkv.head_dim
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    act="relu",  # rwkv channel-mix uses squared relu
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    source="RWKV-6 Finch 1.6B [arXiv:2404.05892]",
)


def reduced():
    return _reduced(CONFIG)
