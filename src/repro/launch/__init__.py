"""Launchers: production meshes, dry-run, train/serve CLIs, roofline."""
from repro.launch.mesh import (  # noqa: F401
    make_codist_mesh,
    make_host_mesh,
    make_production_mesh,
)
