"""Paged KV-cache gather/scatter Pallas kernels for the serving fleet.

The continuous batcher (``repro.serve.fleet``) stores decode-time KV in a
shared block pool ``(num_blocks, block_size, KV, hd)`` instead of one dense
``(B, cap, ...)`` buffer per call: a request owns ``ceil(ctx/block_size)``
blocks named by a per-slot block table, so HBM holds only live context and
slots of wildly different lengths share one allocation. Block 0 is the
reserved NULL block — never allocated, all-zero — and every dead table entry
points at it, which keeps the BlockSpec index maps total.

Two kernels move data between the pool and the decode step:

  ``paged_gather``   (pool, table, n_live) -> (S, MB*BS, KV, hd)
      grid (S, MB); program (s, m) DMAs pool block ``table[s, m]`` into the
      slot's contiguous view, zeroing blocks past ``n_live[s]`` — decode
      reads only live blocks (dead entries all alias the one null block).
  ``paged_scatter``  (pool, new, write_slot, write_off) -> pool
      grid (num_blocks,); the inverse block->writer map (computed host-side
      by the allocator: ``write_slot[b]`` = slot appending into block b this
      step, -1 = untouched) makes every output block written exactly once,
      so the update needs no atomics and no partially-covered outputs.

Both use ``PrefetchScalarGridSpec``: the table / write maps are scalar-
prefetched so the index maps can compute DMA sources before the body runs.
Interpret mode on CPU, Mosaic on TPU (``auto_interpret``), with jnp oracles
(``*_ref``) pinned against the kernels in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ----------------------------------------------------------------------------
# gather: pool blocks -> per-slot contiguous KV
# ----------------------------------------------------------------------------

def _gather_kernel(table_ref, nlive_ref, pool_ref, out_ref):
    s, m = pl.program_id(0), pl.program_id(1)
    live = m < nlive_ref[s]
    blk = pool_ref[0]
    out_ref[0, 0] = jnp.where(live, blk, jnp.zeros_like(blk))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gather(pool: jax.Array, table: jax.Array, n_live: jax.Array,
                 interpret: Optional[bool] = None) -> jax.Array:
    """pool (NB, BS, KV, hd); table (S, MB) int32; n_live (S,) int32 live
    blocks per slot. Returns (S, MB*BS, KV, hd): slot s's context at
    positions [0, n_live[s]*BS), zeros beyond."""
    if interpret is None:
        from repro.kernels.ops import auto_interpret
        interpret = auto_interpret()
    nb, bs, kv, hd = pool.shape
    s, mb = table.shape
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(s, mb),
            in_specs=[pl.BlockSpec((1, bs, kv, hd),
                                   lambda si, mi, t, nl: (t[si, mi], 0, 0, 0))],
            out_specs=pl.BlockSpec((1, 1, bs, kv, hd),
                                   lambda si, mi, t, nl: (si, mi, 0, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((s, mb, bs, kv, hd), pool.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), n_live.astype(jnp.int32), pool)
    return out.reshape(s, mb * bs, kv, hd)


def paged_gather_ref(pool: jax.Array, table: jax.Array,
                     n_live: jax.Array) -> jax.Array:
    """jnp oracle for ``paged_gather``."""
    s, mb = table.shape
    _, bs, kv, hd = pool.shape
    g = pool[table]                                     # (S, MB, BS, KV, hd)
    live = jnp.arange(mb)[None, :] < n_live[:, None]    # (S, MB)
    g = jnp.where(live[..., None, None, None], g, 0.0)
    return g.reshape(s, mb * bs, kv, hd)


# ----------------------------------------------------------------------------
# scatter: one new KV row per appending slot -> its (block, offset)
# ----------------------------------------------------------------------------

def _scatter_kernel(wslot_ref, woff_ref, new_ref, pool_ref, out_ref, *,
                    block_size: int):
    b = pl.program_id(0)
    w = wslot_ref[b]
    off = woff_ref[b]
    src = pl.load(new_ref, (pl.dslice(jnp.maximum(w, 0), 1),
                            slice(None), slice(None)))      # (1, KV, hd)
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_size, 1, 1), 0)
    mask = (rows == off) & (w >= 0)
    out_ref[0] = jnp.where(mask, src, pool_ref[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_scatter(pool: jax.Array, new: jax.Array, write_slot: jax.Array,
                  write_off: jax.Array,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Append one KV row per active slot into its owned block.

    pool (NB, BS, KV, hd); new (S, KV, hd); write_slot (NB,) int32 = the
    slot appending into block b this step (-1: block untouched); write_off
    (NB,) int32 = row within the block. The block->writer inversion is the
    allocator's (slots own disjoint blocks, so at most one writer per block)
    and makes each output block written exactly once.
    """
    if interpret is None:
        from repro.kernels.ops import auto_interpret
        interpret = auto_interpret()
    nb, bs, kv, hd = pool.shape
    s = new.shape[0]
    return pl.pallas_call(
        functools.partial(_scatter_kernel, block_size=bs),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nb,),
            in_specs=[
                pl.BlockSpec((s, kv, hd), lambda b, ws, wo: (0, 0, 0)),
                pl.BlockSpec((1, bs, kv, hd), lambda b, ws, wo: (b, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bs, kv, hd),
                                   lambda b, ws, wo: (b, 0, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        interpret=interpret,
    )(write_slot.astype(jnp.int32), write_off.astype(jnp.int32),
      new.astype(pool.dtype), pool)


def paged_scatter_ref(pool: jax.Array, new: jax.Array, write_slot: jax.Array,
                      write_off: jax.Array) -> jax.Array:
    """jnp oracle for ``paged_scatter``."""
    nb, bs, _, _ = pool.shape
    rows = jnp.arange(bs)[None, :]
    mask = (write_slot >= 0)[:, None] & (rows == write_off[:, None])  # (NB,BS)
    src = new.astype(pool.dtype)[jnp.clip(write_slot, 0)]             # (NB,KV,hd)
    return jnp.where(mask[..., None, None], src[:, None], pool)
