"""SGD-momentum and AdamW as pure pytree transforms.

Weight decay is decoupled and passed PER STEP as a traced scalar — this is how
the paper's codistillation-aware decay schedule (5e-4 -> 1e-5 -> 0 at the LR
milestones) enters the update without recompilation. An optional ``trainable``
mask (same pytree, 0/1 leaves) supports the Section-5.1 frozen-bottleneck
experiments.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    m: PyTree              # momentum / first moment
    v: Optional[PyTree]    # second moment (adamw only)


def _tmap(f, *trees, **kw):
    return jax.tree.map(f, *trees, **kw)


# ----------------------------------------------------------------------------
# SGD + momentum (the paper's vision optimizer)
# ----------------------------------------------------------------------------

def sgdm_init(params: PyTree, dtype=jnp.float32) -> OptState:
    m = _tmap(lambda p: jnp.zeros_like(p, dtype), params)
    return OptState(jnp.zeros((), jnp.int32), m, None)


def sgdm_update(params: PyTree, grads: PyTree, state: OptState, lr,
                weight_decay=0.0, momentum: float = 0.9,
                trainable: Optional[PyTree] = None) -> Tuple[PyTree, OptState]:
    lr = jnp.asarray(lr, jnp.float32)
    wd = jnp.asarray(weight_decay, jnp.float32)

    def upd(p, g, m):
        g32 = g.astype(jnp.float32) + wd * p.astype(jnp.float32)
        m_new = momentum * m + g32
        p_new = p.astype(jnp.float32) - lr * m_new
        return p_new.astype(p.dtype), m_new.astype(m.dtype)

    out = _tmap(upd, params, grads, state.m)
    new_params = _tmap(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = _tmap(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    if trainable is not None:
        new_params = _tmap(lambda n, o, t: jnp.where(t > 0, n, o),
                           new_params, params, trainable)
    return new_params, OptState(state.step + 1, new_m, None)


# ----------------------------------------------------------------------------
# AdamW (the paper's NMT optimizer)
# ----------------------------------------------------------------------------

def adamw_init(params: PyTree, dtype=jnp.float32) -> OptState:
    m = _tmap(lambda p: jnp.zeros_like(p, dtype), params)
    v = _tmap(lambda p: jnp.zeros_like(p, dtype), params)
    return OptState(jnp.zeros((), jnp.int32), m, v)


def adamw_update(params: PyTree, grads: PyTree, state: OptState, lr,
                 weight_decay=0.0, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8,
                 trainable: Optional[PyTree] = None) -> Tuple[PyTree, OptState]:
    lr = jnp.asarray(lr, jnp.float32)
    wd = jnp.asarray(weight_decay, jnp.float32)
    t = state.step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        p_new = (p.astype(jnp.float32)
                 - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32)))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = _tmap(upd, params, grads, state.m, state.v)
    is_t = lambda x: isinstance(x, tuple)
    new_params = _tmap(lambda o: o[0], out, is_leaf=is_t)
    new_m = _tmap(lambda o: o[1], out, is_leaf=is_t)
    new_v = _tmap(lambda o: o[2], out, is_leaf=is_t)
    if trainable is not None:
        new_params = _tmap(lambda n, o, tr: jnp.where(tr > 0, n, o),
                           new_params, params, trainable)
    return new_params, OptState(state.step + 1, new_m, new_v)


# ----------------------------------------------------------------------------
# factory
# ----------------------------------------------------------------------------

def make_optimizer(kind: str, **kw) -> Tuple[Callable, Callable]:
    """Returns (init_fn(params), update_fn(params, grads, state, lr, wd)).

    ``dtype`` sets the moment-buffer dtype (fp32 default; bf16 halves the
    optimizer-state HBM for the largest dry-run configs)."""
    dtype = jnp.dtype(kw.get("dtype", jnp.float32))
    if kind == "sgdm":
        momentum = kw.get("momentum", 0.9)
        return (lambda p: sgdm_init(p, dtype),
                lambda p, g, s, lr, wd, trainable=None: sgdm_update(
                    p, g, s, lr, wd, momentum, trainable))
    if kind == "adamw":
        b1, b2 = kw.get("b1", 0.9), kw.get("b2", 0.95)
        return (lambda p: adamw_init(p, dtype),
                lambda p, g, s, lr, wd, trainable=None: adamw_update(
                    p, g, s, lr, wd, b1, b2, trainable=trainable))
    raise ValueError(kind)
