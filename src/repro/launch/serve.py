"""Serving launcher: prefill + batched decode with a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
        --batch 4 --prompt-len 64 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced, list_archs
from repro.models import build_model
from repro.serve import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = Engine(model, params)

    key = jax.random.key(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.padded_vocab)}
    if cfg.num_patches:
        batch["patches"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model))
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_audio_frames, cfg.d_model))

    t0 = time.time()
    result = engine.generate(batch, args.max_new, args.temperature, args.seed)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {dt / args.max_new * 1e3:.1f} ms/step)")
    print("first sequence:", result.tokens[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
