"""Continuous-batching serving fleet over codistilled peers.

The deployment half of the codistillation story: training (PRs 1-4) yields N
independently-steppable replicas; this package serves them. See
docs/serving.md for the architecture and the scenario catalog.

    workload.py   seeded open-loop request generator (Poisson / bursty /
                  diurnal arrival curves, mixed length distributions)
    batcher.py    per-peer continuous batcher: join/evict into fixed decode
                  slots, admission control, simulated-time SLO accounting
    cache.py      slot-paged KV pool (block allocate / free / defrag)
    model_exec.py compile-once batched decode over the paged pool
                  (``repro.kernels.paged_cache`` gather/scatter)
    router.py     peer routing (round-robin / least-loaded / ensemble),
                  canary divergence via ``distill_pair``, staleness-bounded
                  keep-last weight refresh from checkpoint snapshots,
                  chaos defenses (health routing, migration, hedging,
                  degraded admission)
    chaos.py      seeded fault injection over the runtime's FaultSchedule
                  (stragglers / preemption / failure+recovery on the
                  fleet's decode-tick clock) — see docs/chaos.md
    spec.py       peer-speculative decoding: a codistilled partner (or a
                  student model) drafts k tokens, the target verifies them
                  in one batched forward — bit-identical to plain decode
                  at temperature 0; accept rate doubles as a live
                  codistillation-quality signal
"""
from repro.serve.fleet.batcher import (FleetConfig, FleetEngine,  # noqa: F401
                                       RequestRecord)
from repro.serve.fleet.cache import PagedCachePool  # noqa: F401
from repro.serve.fleet.chaos import (ChaosConfig, ChaosSchedule,  # noqa: F401
                                     ChaosStats, FleetDefense, PeerHealth)
from repro.serve.fleet.router import (FleetReport, FleetRouter,  # noqa: F401
                                      POLICIES)
from repro.serve.fleet.spec import (SpecConfig, SpecEngine,  # noqa: F401
                                    SpecStats)
from repro.serve.fleet.workload import (SCENARIOS, Request,  # noqa: F401
                                        Workload, generate_workload)
