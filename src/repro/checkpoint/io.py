"""Pytree checkpointing: npz payload + json treedef.

Flat key encoding uses jax.tree_util key-paths, so any nested dict/tuple/
NamedTuple state (TrainState, CodistState, OptState) round-trips. Used by the
examples/launchers and by checkpoint-exchange experiments that restart from a
published replica.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: PyTree, meta: Optional[dict] = None) -> None:
    """Write ``path + ".npz"`` (payload) and ``path + ".tree.json"`` (treedef
    + meta) ATOMICALLY: both files are fully written to temporaries and
    ``os.replace``d into place, payload first — a crash mid-save leaves
    either the previous complete snapshot or the new one, never a truncated
    payload (which recovery / the serving fleet's weight refresh would
    otherwise load). The meta file is replaced last, so its ``step`` never
    points ahead of the payload actually on disk."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp_npz = path + ".npz.tmp"
    with open(tmp_npz, "wb") as f:
        np.savez(f, **{f"leaf_{i}": np.asarray(x)
                       for i, x in enumerate(leaves)})
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp_npz, path + ".npz")
    doc = {"treedef": str(treedef), "n_leaves": len(leaves)}
    if meta:
        doc["meta"] = meta
    tmp_json = path + ".tree.json.tmp"
    with open(tmp_json, "w") as f:
        json.dump(doc, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp_json, path + ".tree.json")


def _load_npz_leaves(path: str, n: int):
    """Read ``n`` leading ``leaf_i`` arrays, raising a clear error for a
    corrupt/truncated payload instead of a garbage restore."""
    try:
        data = np.load(path)
        if len(data.files) < n:
            raise ValueError(f"has {len(data.files)} leaves, need {n}")
        return data, [np.asarray(data[f"leaf_{i}"]) for i in range(n)]
    except Exception as e:
        raise ValueError(
            f"corrupt or unreadable checkpoint payload {path!r}: "
            f"{type(e).__name__}: {e} — the snapshot was not restored; "
            "delete it (or re-save) and retry") from e


def read_meta(path: str) -> Optional[dict]:
    """The ``meta`` dict saved alongside a pytree (None if absent)."""
    try:
        with open(path + ".tree.json") as f:
            return json.load(f).get("meta")
    except (OSError, json.JSONDecodeError):
        return None


def snapshot_path(directory: str, peer: int) -> str:
    """Keep-latest snapshot slot for one async-runtime peer."""
    return os.path.join(directory, f"peer{peer}")


def save_snapshot(directory: str, peer: int, state: PyTree,
                  meta: Optional[dict] = None) -> None:
    """Overwrite peer's latest snapshot (the async runtime's recovery point:
    a failed peer rejoins from here instead of a fresh init). ``meta``
    (e.g. ``{"step": n}``) lets consumers — the serving fleet's weight
    refresh — order snapshots without loading payloads."""
    save_pytree(snapshot_path(directory, peer), state, meta)


def snapshot_meta(directory: str, peer: int) -> Optional[dict]:
    return read_meta(snapshot_path(directory, peer))


def has_snapshot(directory: str, peer: int) -> bool:
    return os.path.exists(snapshot_path(directory, peer) + ".npz")


def load_snapshot_params(directory: str, peer: int,
                         params_like: PyTree) -> PyTree:
    """Restore ONLY the params of a saved peer state.

    ``TrainState``/``CodistState`` are NamedTuples with ``params`` first, so
    the params leaves are the LEADING leaves of the flattened snapshot —
    serving-side consumers restore them against a params-only template
    without knowing the optimizer state's structure.
    """
    like_leaves, treedef = _flatten(params_like)
    _, raw = _load_npz_leaves(snapshot_path(directory, peer) + ".npz",
                              len(like_leaves))
    import jax.numpy as jnp
    restored = [jnp.asarray(x, dtype=l.dtype)
                for x, l in zip(raw, like_leaves)]
    for got, want in zip(restored, like_leaves):
        assert got.shape == want.shape, \
            (got.shape, want.shape, "snapshot params/template mismatch")
    return jax.tree_util.tree_unflatten(treedef, restored)


def load_snapshot(directory: str, peer: int, like: PyTree) -> PyTree:
    return load_pytree(snapshot_path(directory, peer), like)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    like_leaves, treedef = _flatten(like)
    data, leaves = _load_npz_leaves(path + ".npz", len(like_leaves))
    assert len(data.files) == len(like_leaves), "checkpoint/template mismatch"
    import jax.numpy as jnp
    restored = [jnp.asarray(x, dtype=l.dtype) for x, l in zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored)
