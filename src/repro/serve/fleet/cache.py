"""Slot-paged KV-cache pool: block allocate / free / defrag over one shared
buffer, plus dense per-slot states for the recurrent sublayers.

Attention is the only cache that grows with context, so only attention KV is
paged: per scanned layer step, K and V pools of shape
``(n_scan, num_blocks, block_size, KV, hd)`` shared by every decode slot,
with one host-side block table ``(max_slots, max_blocks_per_slot)`` naming
each slot's blocks in sequence order (the same table indexes every layer —
allocation is per-slot, not per-layer). Recurrent sublayers (mamba / rwkv)
are O(1) per slot and live in dense ``(n_scan, max_slots, ...)`` state
buffers. Block 0 is the reserved null block: never allocated, all dead table
entries point at it (see ``repro.kernels.paged_cache``).

Quantized ``cache_dtype`` (int8 / fp8): the pools store quantized rows plus
per-row fp32 scales in ``k_scale`` / ``v_scale`` ``(n_scan, NB, BS)``
arrays held alongside ``k`` / ``v`` in the same per-sublayer dict — they
ride the exact same allocate / defrag / scatter plumbing (a scale row is
just more per-block payload), and the decode kernel dequantizes in its
inner loop. Prefill rows are quantized here at insert time
(``quantize_rows``); decode appends are quantized inside the fused
``paged_scatter_quant`` kernel. Recurrent states stay at fp32 when the KV
pool is quantized (they are O(1) per slot — nothing to win, and recurrent
dynamics are precision-sensitive).

Allocation is deterministic (lowest-index free blocks first) so seeded fleet
runs are bit-reproducible. ``defrag()`` compacts live blocks to the lowest
indices — with table indirection fragmentation never breaks correctness, but
compaction keeps the live region contiguous (sequential HBM reads, cheap
pool shrink) after heavy join/evict churn.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_cache import is_quantized_dtype, quantize_rows
from repro.models.transformer import _init_sub_cache, _n_scan, _sub_kinds

PyTree = Any


class PagedCachePool:
    def __init__(self, model, *, max_slots: int, block_size: int,
                 num_blocks: int, max_blocks_per_slot: int,
                 cache_dtype=jnp.float32):
        cfg = model.cfg
        assert cfg.sliding_window <= 0, \
            "paged serving assumes full-length attention (no ring buffer)"
        self.cfg = cfg
        self.max_slots = max_slots
        self.block_size = block_size
        self.num_blocks = num_blocks          # includes the null block 0
        self.max_blocks_per_slot = max_blocks_per_slot
        self.cache_dtype = cache_dtype
        self.quantized = is_quantized_dtype(cache_dtype)
        self.kinds = _sub_kinds(cfg)
        self.n_scan = _n_scan(cfg)

        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        self.kv_subs = [i for i, (m, _f) in enumerate(self.kinds)
                        if m == "attn"]

        # device state: paged KV per attention sublayer (quantized pools
        # carry per-row fp32 scales alongside)...
        def pools():
            d = {
                "k": jnp.zeros((self.n_scan, num_blocks, block_size, kv, hd),
                               cache_dtype),
                "v": jnp.zeros((self.n_scan, num_blocks, block_size, kv, hd),
                               cache_dtype),
            }
            if self.quantized:
                d["k_scale"] = jnp.zeros(
                    (self.n_scan, num_blocks, block_size), jnp.float32)
                d["v_scale"] = jnp.zeros(
                    (self.n_scan, num_blocks, block_size), jnp.float32)
            return d
        self.kv: Dict[str, Dict[str, jax.Array]] = {
            f"sub{i}": pools() for i in self.kv_subs}
        # ...and dense per-slot recurrent states for the rest
        state_dtype = jnp.float32 if self.quantized else cache_dtype
        rec_subs = [(i, m) for i, (m, _f) in enumerate(self.kinds)
                    if m != "attn"]
        if rec_subs:
            def one(_):
                return {f"sub{i}": _init_sub_cache(cfg, m, max_slots, 1,
                                                   state_dtype)
                        for i, m in rec_subs}
            self.states: PyTree = jax.vmap(one)(jnp.arange(self.n_scan))
        else:
            self.states = {}

        # host-side allocator state (numpy: the scheduler is host-driven)
        self.table = np.zeros((max_slots, max_blocks_per_slot), np.int32)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.slot_blocks: List[List[int]] = [[] for _ in range(max_slots)]
        self.free: List[int] = list(range(1, num_blocks))  # 0 = null block

    # ---- allocator ---------------------------------------------------------
    def blocks_needed(self, total_tokens: int) -> int:
        return -(-total_tokens // self.block_size)

    def can_admit(self, total_tokens: int) -> bool:
        n = self.blocks_needed(total_tokens)
        return n <= len(self.free) and n <= self.max_blocks_per_slot

    def allocate(self, slot: int, total_tokens: int) -> List[int]:
        """Reserve the slot's full worst-case context (prompt + max output)
        at admission — reservation-on-admit admission control: an admitted
        request can never deadlock waiting for blocks mid-decode."""
        n = self.blocks_needed(total_tokens)
        assert self.can_admit(total_tokens), (n, len(self.free))
        assert not self.slot_blocks[slot], f"slot {slot} already allocated"
        blocks = [self.free.pop(0) for _ in range(n)]  # lowest-index first
        self.slot_blocks[slot] = blocks
        self.table[slot, :] = 0
        self.table[slot, :n] = blocks
        return blocks

    def free_slot(self, slot: int) -> None:
        self.free.extend(self.slot_blocks[slot])
        self.free.sort()                      # deterministic reuse order
        self.slot_blocks[slot] = []
        self.table[slot, :] = 0
        self.lengths[slot] = 0

    def live_blocks(self) -> int:
        return sum(len(b) for b in self.slot_blocks)

    def utilization(self) -> float:
        return self.live_blocks() / max(1, self.num_blocks - 1)

    # ---- data movement -----------------------------------------------------
    def insert_prefill(self, slot: int, cache: PyTree, length: int) -> None:
        """Scatter a per-request prefill cache (leaves ``(n_scan, 1, ...)``
        from ``model.prefill`` with ``cap == length``) into the slot's
        allocated blocks / state row."""
        bs = self.block_size
        nb = self.blocks_needed(length)
        ids = jnp.asarray(self.slot_blocks[slot][:nb], jnp.int32)
        pad = nb * bs - length
        for i in self.kv_subs:
            for name in ("k", "v"):
                src = cache[f"sub{i}"][name][:, 0]            # (n_scan, L, kv, hd)
                src = jnp.pad(src, ((0, 0), (0, pad), (0, 0), (0, 0)))
                src = src.reshape(self.n_scan, nb, bs, *src.shape[2:])
                if self.quantized:
                    src, scales = quantize_rows(src, self.cache_dtype)
                    self.kv[f"sub{i}"][f"{name}_scale"] = (
                        self.kv[f"sub{i}"][f"{name}_scale"].at[:, ids]
                        .set(scales))
                self.kv[f"sub{i}"][name] = (
                    self.kv[f"sub{i}"][name].at[:, ids]
                    .set(src.astype(self.cache_dtype)))
        self.states = jax.tree.map(
            lambda dst, full: dst.at[:, slot].set(full[:, 0].astype(dst.dtype)),
            self.states, _strip_attn(cache, self.kv_subs))
        self.lengths[slot] = length

    def write_maps(self, active: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Invert slot->(block, offset) appends into the per-block writer
        maps ``paged_scatter`` wants, for the slots flagged active."""
        wslot = np.full((self.num_blocks,), -1, np.int32)
        woff = np.zeros((self.num_blocks,), np.int32)
        for s in np.nonzero(active)[0]:
            pos = int(self.lengths[s])
            blk = self.slot_blocks[s][pos // self.block_size]
            wslot[blk] = s
            woff[blk] = pos % self.block_size
        return wslot, woff

    def write_maps_k(self, active: np.ndarray,
                     k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Writer maps for a k-token speculative append: row ``j`` maps each
        active slot's position ``lengths[s] + j`` to its (block, offset).
        Positions past a slot's reserved capacity are simply absent from the
        maps (the verify forward's outputs there are truncated by the
        engine, never emitted). One writer per block per row: within a row
        every position belongs to a different slot, and blocks are
        slot-exclusive."""
        wslots = np.full((k, self.num_blocks), -1, np.int32)
        woffs = np.zeros((k, self.num_blocks), np.int32)
        for s in np.nonzero(active)[0]:
            cap = len(self.slot_blocks[s]) * self.block_size
            base = int(self.lengths[s])
            for j in range(k):
                pos = base + j
                if pos >= cap:
                    break
                blk = self.slot_blocks[s][pos // self.block_size]
                wslots[j, blk] = s
                woffs[j, blk] = pos % self.block_size
        return wslots, woffs

    # ---- speculative rollback (undo log) -----------------------------------
    def snapshot_rows(self, slot: int, start_pos: int, n_rows: int):
        """Copy the pool rows (K/V and, when quantized, their scales) for
        positions ``[start_pos, start_pos + n_rows)`` of ``slot`` — the undo
        log a speculative verify takes before scattering draft tokens.
        Restoring a rejected suffix with :meth:`restore_rows` leaves the
        pool bit-identical to one that never saw the draft (freed blocks
        keep whatever their previous occupant wrote, so "restore previous
        contents" is the invariant, not "zero")."""
        cap = len(self.slot_blocks[slot]) * self.block_size
        pos = [p for p in range(start_pos, start_pos + n_rows) if p < cap]
        blocks = np.asarray([self.slot_blocks[slot][p // self.block_size]
                             for p in pos], np.int32)
        offs = np.asarray([p % self.block_size for p in pos], np.int32)
        data = {
            sub: {name: arr[:, blocks, offs] for name, arr in d.items()}
            for sub, d in self.kv.items()
        } if len(pos) else {}
        return (blocks, offs, data)

    def restore_rows(self, snap, start: int = 0) -> None:
        """Write back rows ``start..`` of a :meth:`snapshot_rows` snapshot
        (``start`` counts rows within the snapshot, i.e. draft positions)."""
        blocks, offs, data = snap
        if start >= len(blocks):
            return
        b, o = blocks[start:], offs[start:]
        for sub, d in data.items():
            for name, saved in d.items():
                self.kv[sub][name] = (
                    self.kv[sub][name].at[:, b, o].set(saved[:, start:]))

    # ---- defrag ------------------------------------------------------------
    def defrag(self) -> int:
        """Compact live blocks to the lowest pool indices (stable in
        (slot, sequence) order). Returns the number of blocks moved."""
        live: List[int] = []
        for s in range(self.max_slots):
            live.extend(self.slot_blocks[s])
        remap = {old: new for new, old in enumerate(live, start=1)}
        moved = sum(1 for o, n in remap.items() if o != n)
        if moved == 0:
            return 0
        # permutation: new block index -> old block index (identity for the
        # null block and the free tail)
        perm = np.arange(self.num_blocks)
        for old, new in remap.items():
            perm[new] = old
        used = 1 + len(live)
        perm[used:] = sorted(set(range(self.num_blocks))
                             - set(perm[:used].tolist()))
        perm_j = jnp.asarray(perm, jnp.int32)
        for i in self.kv_subs:
            for name in self.kv[f"sub{i}"]:     # k/v pools AND scale rows
                self.kv[f"sub{i}"][name] = self.kv[f"sub{i}"][name][:, perm_j]
        for s in range(self.max_slots):
            self.slot_blocks[s] = [remap[b] for b in self.slot_blocks[s]]
            n = len(self.slot_blocks[s])
            self.table[s, :] = 0
            self.table[s, :n] = self.slot_blocks[s]
        self.free = list(range(used, self.num_blocks))
        return moved


def _strip_attn(cache: PyTree, kv_subs: List[int]) -> Dict:
    """Drop the attention sublayer entries from a per-request prefill cache,
    leaving the recurrent-state subtree matching ``PagedCachePool.states``."""
    drop = {f"sub{i}" for i in kv_subs}
    return {k: v for k, v in cache.items() if k not in drop}
