"""Section-3 communication model — anchored to the paper's own numbers."""
import pytest

from repro.configs import CodistConfig, get_config
from repro.core import comm_model as cm


def test_paper_resnet50_worked_example():
    """b_model = 8e8 bits, b_pred = 3.2e4 bits, B = 256 (Section 3 / Fig 1)."""
    n = cm.paper_resnet50_numbers()
    assert n["all_reduce"] == pytest.approx(1.6e9)
    # predictions every iteration: (2-1) * 3.2e4 * 256 = 8.192e6
    assert n["pred_T1"] == pytest.approx(8.192e6)
    assert n["pred_T1_ratio"] == pytest.approx(195.3, rel=1e-3)
    # every 5 iterations: ~977x fewer bits — the paper's "up to 1000x"
    assert n["pred_T5_ratio"] == pytest.approx(976.5, rel=1e-3)
    assert n["pred_T100_ratio"] == pytest.approx(19531.25, rel=1e-3)
    # checkpoints every 625 iterations: (n-1) * b_model / T
    assert n["ckpt_T625"] == pytest.approx(8e8 / 625)
    assert n["ckpt_T625_ratio"] == pytest.approx(1250.0)


def test_checkpoint_cheaper_than_allreduce_iff_condition():
    """(n-1)/T < 2 is exactly the paper's break-even condition."""
    b_model = 1e9
    ar = cm.allreduce_bits(b_model)
    for n, t in [(2, 1), (3, 1), (5, 2), (2, 50), (9, 4)]:
        ck = cm.codist_checkpoint_bits(b_model, n, t)
        cheaper = ck.bits_per_iter_per_device < ar.bits_per_iter_per_device
        assert cheaper == ((n - 1) / t < 2)


def test_lm_prediction_bits_dwarf_resnet():
    """Hardware-adaptation finding: raw logits exchange at LM vocab sizes is
    orders of magnitude heavier than the ResNet case the paper studied."""
    cfg = get_config("qwen2-7b")
    lm_bits = cm.prediction_bits_lm(cfg, seq_len=4096)
    assert lm_bits > 1e4 * 3.2e4  # >1e4x the ResNet per-sample prediction


def test_compression_recovers_the_win():
    cfg = get_config("qwen2-7b")
    raw = cm.prediction_bits_lm(cfg, 4096)
    topk = cm.prediction_bits_lm(cfg, 4096, compression="topk", topk=64)
    sub = cm.prediction_bits_lm(cfg, 4096, compression="subsample",
                                subsample=256)
    bf16 = cm.prediction_bits_lm(cfg, 4096, logit_bits=32, compression="bf16")
    assert topk < raw / 500
    assert sub == pytest.approx(raw * 256 / 4096)
    assert bf16 == pytest.approx(raw / 2)


def test_codist_cost_dispatch():
    cfg = get_config("qwen1.5-0.5b")
    ck = cm.codist_cost(cfg, CodistConfig(n_models=2, mode="checkpoints",
                                          period=50), per_device_batch=8)
    assert ck.bits_per_iter_per_device == pytest.approx(
        cm.model_bits(cfg) / 50)
    pred = cm.codist_cost(cfg, CodistConfig(n_models=4, period=10),
                          per_device_batch=8, seq_len=128)
    expected = 3 * cm.prediction_bits_lm(cfg, 128) * 8 / 10
    assert pred.bits_per_iter_per_device == pytest.approx(expected)


def test_ratio_vs():
    a = cm.CommCost(100.0, "a")
    b = cm.CommCost(1.0, "b")
    assert b.ratio_vs(a) == pytest.approx(100.0)


def test_bits_per_exchange_event_identities():
    """Event-based accounting / period == the per-iteration Section-3 model."""
    b_model, b_pred, batch, n = 8e8, 3.2e4, 256, 2
    for period in (1, 5, 100):
        assert (cm.bits_per_exchange_event("predictions", n, b_pred=b_pred,
                                           batch=batch) / period
                == pytest.approx(cm.codist_prediction_bits(
                    b_pred, batch, n, period).bits_per_iter_per_device))
        assert (cm.bits_per_exchange_event("checkpoints", n, b_model=b_model)
                / period
                == pytest.approx(cm.codist_checkpoint_bits(
                    b_model, n, period).bits_per_iter_per_device))
    assert cm.bits_per_exchange_event("all_reduce", n, b_model=b_model) \
        == pytest.approx(cm.allreduce_bits(b_model).bits_per_iter_per_device)
    with pytest.raises(ValueError):
        cm.bits_per_exchange_event("bogus", 2)


def test_async_scheduler_meters_match_event_model():
    """The mailbox-metered bytes of a real AsyncScheduler run agree exactly
    with ``bits_per_exchange_event``: one event = one peer's exchange step
    receiving the (n-1) other replicas' prediction payloads."""
    from dataclasses import replace

    from repro.configs import CodistConfig, TrainConfig, get_reduced
    from repro.data import MarkovLM, make_lm_batch
    from repro.models import build_model
    from repro.runtime import AsyncScheduler, FaultConfig, simulate_allreduce
    from repro.train.engine import _param_bits

    cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=1, d_model=32,
                  d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                  head_dim=16)
    model = build_model(cfg)
    task = MarkovLM(vocab=64, seed=0)
    b, s, steps, n = 4, 16, 5, 2
    tc = TrainConfig(lr=1e-3, total_steps=steps, warmup_steps=2,
                     optimizer="adamw", seed=0)
    codist = CodistConfig(n_models=n, period=1)
    batches = (lambda k: make_lm_batch(task, b, s, k, None, seed=0))
    rep = AsyncScheduler(model, tc, codist, batches,
                         FaultConfig(n_peers=n, seed=0),
                         staleness_bound=0).run()
    assert rep.comm_events == n * steps
    b_pred = cm.prediction_bits_lm(cfg, s)  # fp32 payload over padded vocab
    expected = cm.bits_per_exchange_event("predictions", n, b_pred=b_pred,
                                          batch=b) / 8.0
    assert rep.comm_bytes == pytest.approx(rep.comm_events * expected)

    ar = simulate_allreduce(model, tc, batches,
                            FaultConfig(n_peers=n, seed=0))
    expected_ar = cm.bits_per_exchange_event(
        "all_reduce", n, b_model=_param_bits(ar.states[0].params)) / 8.0
    assert ar.comm_bytes == pytest.approx(ar.comm_events * expected_ar)

    # producer-side compression: the mailbox carries (and meters) the
    # compressed wire — topk fp32 vals + int32 idx per token
    topk = replace(codist, compression="topk", topk=8)
    rep_k = AsyncScheduler(model, tc, topk, batches,
                           FaultConfig(n_peers=n, seed=0),
                           staleness_bound=0).run()
    b_pred_k = cm.prediction_bits_lm(cfg, s, compression="topk", topk=8)
    expected_k = cm.bits_per_exchange_event("predictions", n,
                                            b_pred=b_pred_k, batch=b) / 8.0
    assert rep_k.comm_bytes == pytest.approx(rep_k.comm_events * expected_k)
    assert rep_k.comm_bytes < rep.comm_bytes / 10


def test_fleet_refresh_bills_through_checkpoint_event_model():
    """Serving and training share one comm ledger: the router's weight
    refresh bills exactly one n=2 checkpoint-exchange event per ADOPTED
    snapshot (keep-last metering — repeat polls of the same snapshot bill
    nothing), with b_model measured from the live params."""
    from dataclasses import replace

    import jax

    from repro.checkpoint.io import save_snapshot
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serve.fleet import FleetConfig, FleetRouter

    cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=1, d_model=32,
                  d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                  head_dim=16)
    model = build_model(cfg)
    p = model.init(jax.random.key(0))
    per_refresh = cm.bits_per_exchange_event(
        "checkpoints", 2, b_model=cm.param_bits_of(p)) / 8.0
    import tempfile
    with tempfile.TemporaryDirectory() as snap:
        save_snapshot(snap, 0, {"params": p}, meta={"step": 4})
        fc = FleetConfig(max_slots=1, block_size=4, num_blocks=16,
                         max_blocks_per_slot=4)
        router = FleetRouter(model, [p, p], config=fc, snapshot_dir=snap)
        assert router.refresh_now() == 1
        # bill-once: polling the unchanged directory adopts (and bills) nothing
        assert router.refresh_now() == 0
        assert router.refresh_now() == 0
        assert router.refreshes == 1
        assert router.refresh_bytes == pytest.approx(per_refresh)
        # a genuinely newer snapshot bills exactly one more event
        save_snapshot(snap, 0, {"params": p}, meta={"step": 9})
        assert router.refresh_now() == 1
        assert router.refresh_bytes == pytest.approx(2 * per_refresh)
    # the event model agrees with the fp32 byte count of the raw params
    from repro.models.common import count_params
    assert per_refresh == count_params(p) * 4
