"""Per-architecture smoke tests: a REDUCED variant of each assigned config
(<=2 layers, d_model<=512, <=4 experts) runs one forward and one train step on
CPU; output shapes and finiteness are asserted. Full configs are exercised
only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, TrainConfig, get_config, get_reduced
from repro.models import build_model
from repro.train import AllReduce, build_train_step, init_train_state
from repro.optim import make_optimizer


def _batch(cfg, b=2, s=16, key=0):
    k = jax.random.key(key)
    batch = {
        "tokens": jax.random.randint(k, (b, s), 0, cfg.padded_vocab),
        "labels": jax.random.randint(k, (b, s), 0, cfg.vocab_size),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.num_patches:
        batch["patches"] = 0.1 * jax.random.normal(
            k, (b, cfg.num_patches, cfg.d_model))
    if cfg.is_encdec:
        if cfg.num_audio_frames > 0:
            batch["frames"] = 0.1 * jax.random.normal(
                k, (b, cfg.num_audio_frames, cfg.d_model))
        else:
            batch["src_tokens"] = jax.random.randint(k, (b, s), 0,
                                                     cfg.padded_vocab)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    batch = _batch(cfg)
    params = model.init(jax.random.key(0))
    logits, aux = model.forward(params, batch)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"

    tc = TrainConfig(lr=1e-3, total_steps=10, warmup_steps=0,
                     optimizer="adamw")
    opt_init, _ = make_optimizer("adamw")
    state = init_train_state(model, jax.random.key(1), opt_init)
    step = jax.jit(
        build_train_step(model, tc, None, AllReduce()).variants["on"])
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(state2.step) == 1
    # params actually changed
    diff = jax.tree.map(lambda a, c: float(jnp.abs(a - c).max()),
                        state.params, state2.params)
    assert max(jax.tree.leaves(diff)) > 0, f"{arch}: no param update"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_exact_assignment(arch):
    """The registered full configs match the assigned table exactly."""
    cfg = get_config(arch)
    table = {
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    }
    l_, d, h, kv, ff, v = table[arch]
    assert cfg.num_layers == l_ and cfg.d_model == d
    assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v
    if arch == "arctic-480b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 2
        assert cfg.moe.dense_residual
    if arch == "jamba-v0.1-52b":
        assert cfg.attn_layer_period == 8 and cfg.moe.num_experts == 16
    if arch == "grok-1-314b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
    if arch == "whisper-tiny":
        assert cfg.encoder_layers == 4
    if arch == "rwkv6-1.6b":
        assert cfg.family == "ssm" and cfg.rwkv is not None
    if arch.startswith("qwen"):
        assert cfg.qkv_bias


def test_param_counts_plausible():
    """Analytic param counts land in the right ballpark for named sizes."""
    expect = {  # (arch, low, high) in billions
        "deepseek-67b": (55, 80),
        "qwen2-7b": (6, 9),
        "qwen1.5-0.5b": (0.3, 0.8),
        "qwen1.5-4b": (3, 5),
        "arctic-480b": (400, 560),
        "grok-1-314b": (250, 370),
        "rwkv6-1.6b": (1.2, 2.2),
        "jamba-v0.1-52b": (40, 65),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count() / 1e9
        assert lo < n < hi, f"{arch}: {n:.1f}B outside [{lo},{hi}]"
