"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
all in interpret=True mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distill_loss import fused_distill_loss
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_ce import fused_cross_entropy
from repro.kernels import ops


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


CE_SHAPES = [(128, 256), (256, 512), (384, 1024)]


@pytest.mark.parametrize("t,v", CE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ce_sweep(t, v, dtype):
    k = jax.random.key(t + v)
    logits = (jax.random.normal(k, (t, v)) * 4).astype(dtype)
    labels = jax.random.randint(jax.random.key(1), (t,), 0, v)
    out = fused_cross_entropy(logits, labels, block_t=128, block_v=128,
                              interpret=True)
    want = ref.cross_entropy_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("mode", ["mse", "kl"])
@pytest.mark.parametrize("t,v", [(128, 256), (256, 768)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_distill_sweep(mode, t, v, dtype):
    a = (jax.random.normal(jax.random.key(0), (t, v)) * 2).astype(dtype)
    b = (jax.random.normal(jax.random.key(1), (t, v)) * 2).astype(dtype)
    out = fused_distill_loss(a, b, mode=mode, block_t=128, block_v=128,
                             interpret=True)
    want = ref.distill_mse_ref(a, b) if mode == "mse" else ref.distill_kl_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **_tol(dtype))


ATTN_CASES = [
    # (B, S, H, KV, hd, causal, window)
    (1, 128, 4, 4, 64, True, 0),
    (2, 256, 4, 2, 64, True, 0),      # GQA 2:1
    (1, 128, 8, 2, 32, True, 0),      # GQA 4:1
    (1, 256, 4, 4, 64, True, 64),     # sliding window
    (2, 128, 4, 1, 64, True, 0),      # MQA
    (1, 128, 2, 2, 128, False, 0),    # encoder (non-causal)
]


@pytest.mark.parametrize("b,s,h,kv,hd,causal,window", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kv, hd, causal, window, dtype):
    keys = jax.random.split(jax.random.key(s + h), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd)).astype(dtype)
    k = jax.random.normal(keys[1], (b, s, kv, hd)).astype(dtype)
    v = jax.random.normal(keys[2], (b, s, kv, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_flash_attention_cross_lengths():
    """T != S (prefix cache reads)."""
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (1, 64, 4, 32))
    k = jax.random.normal(keys[1], (1, 256, 4, 32))
    v = jax.random.normal(keys[2], (1, 256, 4, 32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


class TestOpsWrappers:
    def test_ce_padding_paths(self):
        """Unaligned T and V get padded transparently."""
        t, v = 100, 300
        logits = jax.random.normal(jax.random.key(0), (2, 50, v)) * 3
        labels = jax.random.randint(jax.random.key(1), (2, 50), 0, v)
        out = ops.cross_entropy_tokens(logits, labels, block_t=64,
                                       block_v=128, interpret=True)
        want = ref.cross_entropy_ref(logits.reshape(t, v),
                                     labels.reshape(t)).reshape(2, 50)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_distill_padding_paths(self):
        t, v = 96, 200
        a = jax.random.normal(jax.random.key(0), (t, v))
        b = jax.random.normal(jax.random.key(1), (t, v))
        for mode in ("mse", "kl"):
            out = ops.distill_loss_tokens(a, b, mode=mode, block_t=64,
                                          block_v=128, interpret=True)
            want = (ref.distill_mse_ref if mode == "mse"
                    else ref.distill_kl_ref)(a, b)
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)

    def test_attention_padding(self):
        keys = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(keys[0], (1, 100, 4, 32))
        k = jax.random.normal(keys[1], (1, 100, 2, 32))
        v = jax.random.normal(keys[2], (1, 100, 2, 32))
        out = ops.attention(q, k, v, causal=True, block_q=64, block_k=64,
                            interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_distill_kernel_agrees_with_core_loss(self):
        """Kernel path == the core (model-level) distillation loss."""
        from repro.core.codistillation import distill_mse
        a = jax.random.normal(jax.random.key(0), (4, 16, 64))
        b = jax.random.normal(jax.random.key(1), (4, 16, 64))
        kern = float(jnp.mean(ops.distill_loss_tokens(a, b, mode="mse",
                                                      block_t=64, block_v=64,
                                                      interpret=True)))
        core = float(distill_mse(a, b))
        assert kern == pytest.approx(core, rel=1e-5)


class TestPagedCache:
    """Serving-fleet paged KV pool gather/scatter vs the jnp oracles."""

    def _pool(self, nb=10, bs=4, kv=2, hd=8, seed=0):
        key = jax.random.key(seed)
        return jax.random.normal(key, (nb, bs, kv, hd), jnp.float32)

    def test_gather_matches_ref_and_zeroes_dead_blocks(self):
        from repro.kernels.paged_cache import paged_gather, paged_gather_ref
        pool = self._pool()
        table = jnp.asarray([[1, 2, 0], [3, 0, 0], [4, 5, 6]], jnp.int32)
        n_live = jnp.asarray([2, 1, 3], jnp.int32)
        got = paged_gather(pool, table, n_live, interpret=True)
        want = paged_gather_ref(pool, table, n_live)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # positions past the live region are exactly zero (decode's mask
        # relies on masked scores, but the gather must not leak junk)
        np.testing.assert_array_equal(np.asarray(got[0, 8:]), 0.0)
        np.testing.assert_array_equal(np.asarray(got[1, 4:]), 0.0)

    def test_scatter_matches_ref_and_preserves_untouched(self):
        from repro.kernels.paged_cache import (paged_scatter,
                                               paged_scatter_ref)
        pool = self._pool()
        new = jax.random.normal(jax.random.key(1), (3, 2, 8), jnp.float32)
        wslot = np.full((10,), -1, np.int32)
        woff = np.zeros((10,), np.int32)
        wslot[2], woff[2] = 0, 3
        wslot[3], woff[3] = 1, 1
        wslot[6], woff[6] = 2, 2
        got = paged_scatter(pool, new, jnp.asarray(wslot), jnp.asarray(woff),
                            interpret=True)
        want = paged_scatter_ref(pool, new, jnp.asarray(wslot),
                                 jnp.asarray(woff))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # written rows carry the new KV; every other row is untouched
        np.testing.assert_array_equal(np.asarray(got[2, 3]),
                                      np.asarray(new[0]))
        np.testing.assert_array_equal(np.asarray(got[2, :3]),
                                      np.asarray(pool[2, :3]))
        untouched = [0, 1, 4, 5, 7, 8, 9]
        np.testing.assert_array_equal(np.asarray(got)[untouched],
                                      np.asarray(pool)[untouched])

    def test_scatter_then_gather_roundtrip(self):
        from repro.kernels.paged_cache import paged_gather, paged_scatter
        pool = jnp.zeros((6, 2, 1, 4), jnp.float32)
        table = jnp.asarray([[1, 2], [3, 0]], jnp.int32)
        # slot 0 appends 3 tokens, slot 1 appends 1: offsets walk the blocks
        writes = [(0, 1, 0), (0, 1, 1), (0, 2, 0), (1, 3, 0)]
        vals = {}
        for t, (s, blk, off) in enumerate(writes):
            new = jnp.full((2, 1, 4), float(t + 1), jnp.float32)
            wslot = np.full((6,), -1, np.int32)
            woff = np.zeros((6,), np.int32)
            wslot[blk], woff[blk] = s, off
            pool = paged_scatter(pool, new, jnp.asarray(wslot),
                                 jnp.asarray(woff), interpret=True)
            vals[(s, blk, off)] = float(t + 1)
        out = paged_gather(pool, table, jnp.asarray([2, 1], jnp.int32),
                           interpret=True)
        assert float(out[0, 0, 0, 0]) == vals[(0, 1, 0)]
        assert float(out[0, 1, 0, 0]) == vals[(0, 1, 1)]
        assert float(out[0, 2, 0, 0]) == vals[(0, 2, 0)]
        assert float(out[1, 0, 0, 0]) == vals[(1, 3, 0)]
        np.testing.assert_array_equal(np.asarray(out[1, 2:]), 0.0)
