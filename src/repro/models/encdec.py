"""Encoder-decoder transformer (whisper-style audio + the paper's NMT "big").

The audio conv/mel frontend is a STUB per the assignment carve-out: the
encoder consumes precomputed frame embeddings (B, frames, d_model) supplied by
``input_specs()``. With ``num_audio_frames == 0`` (transformer-big NMT) the
encoder consumes source *tokens* through the shared embedding instead.

Same scan-over-layers construction as the decoder-only LM. RoPE is used for
self-attention in both stacks (TPU-native adaptation; whisper's learned
absolute embeddings add nothing at dry-run scale), cross-attention is
position-free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (KeyGen, apply_norm, embed_tokens,
                                 init_embedding, init_rms_norm, lm_head)
from repro.models.ffn import ffn_forward, init_ffn

PyTree = Any


def _init_enc_layer(key: jax.Array, cfg: ModelConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {
        "norm1": init_rms_norm(cfg.d_model, dtype),
        "attn": attn.init_attention(kg(), cfg, dtype),
        "norm2": init_rms_norm(cfg.d_model, dtype),
        "ffn": init_ffn(kg(), cfg, dtype=dtype),
    }


def _init_dec_layer(key: jax.Array, cfg: ModelConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {
        "norm1": init_rms_norm(cfg.d_model, dtype),
        "self_attn": attn.init_attention(kg(), cfg, dtype),
        "norm_x": init_rms_norm(cfg.d_model, dtype),
        "cross_attn": attn.init_attention(kg(), cfg, dtype),
        "norm2": init_rms_norm(cfg.d_model, dtype),
        "ffn": init_ffn(kg(), cfg, dtype=dtype),
    }


@dataclass(frozen=True)
class EncDecLM:
    cfg: ModelConfig

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        kg = KeyGen(key)
        enc_keys = jax.random.split(kg(), cfg.encoder_layers)
        dec_keys = jax.random.split(kg(), cfg.num_layers)
        return {
            "embed": init_embedding(kg(), cfg, dtype),
            "enc_layers": jax.vmap(
                lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
            "dec_layers": jax.vmap(
                lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
            "enc_norm": init_rms_norm(cfg.d_model, dtype),
            "final_norm": init_rms_norm(cfg.d_model, dtype),
        }

    # ------------------------------------------------------------------
    def encode(self, params: PyTree, batch: Dict) -> jax.Array:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        if cfg.num_audio_frames > 0:
            x = batch["frames"].astype(dtype)        # stub frontend output
        else:
            x = embed_tokens(params["embed"], batch["src_tokens"], dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]

        def body(h, lp):
            a, _ = attn.attention_forward(
                lp["attn"], apply_norm(lp["norm1"], h, cfg.norm_eps), cfg,
                positions, causal=False)
            h = h + a
            h = h + ffn_forward(lp["ffn"],
                                apply_norm(lp["norm2"], h, cfg.norm_eps), cfg)
            return h, None

        from repro.models.runtime_flags import scan_unroll
        x, _ = jax.lax.scan(body, x, params["enc_layers"],
                            unroll=scan_unroll())
        return apply_norm(params["enc_norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------------
    def forward(self, params: PyTree, batch: Dict,
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        memory = self.encode(params, batch)
        x = embed_tokens(params["embed"], batch["tokens"], dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]

        def body(h, lp):
            a, _ = attn.attention_forward(
                lp["self_attn"], apply_norm(lp["norm1"], h, cfg.norm_eps),
                cfg, positions)
            h = h + a
            c = attn.cross_attention_forward(
                lp["cross_attn"], apply_norm(lp["norm_x"], h, cfg.norm_eps),
                memory, cfg)
            h = h + c
            h = h + ffn_forward(lp["ffn"],
                                apply_norm(lp["norm2"], h, cfg.norm_eps), cfg)
            return h, None

        from repro.models.runtime_flags import scan_unroll
        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec_layers"],
                            unroll=scan_unroll())
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        return lm_head(params["embed"], x), jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cap: int, dtype=jnp.bfloat16) -> PyTree:
        cfg = self.cfg
        mem_len = cfg.num_audio_frames or cap
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

        def one(_):
            return {
                "self": attn.init_kv_cache(cfg, batch, cap, dtype),
                "cross": {"k": jnp.zeros((batch, mem_len, kv, hd), dtype),
                          "v": jnp.zeros((batch, mem_len, kv, hd), dtype)},
            }

        return jax.vmap(one)(jnp.arange(cfg.num_layers))

    def prefill(self, params: PyTree, batch: Dict, cap: int,
                cache_dtype=jnp.bfloat16) -> Tuple[jax.Array, PyTree]:
        """Encode + teacher-forced decoder pass emitting both cache kinds."""
        cfg = self.cfg
        dtype = cfg.activation_dtype
        memory = self.encode(params, batch)
        x = embed_tokens(params["embed"], batch["tokens"], dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]

        def body(h, lp):
            a, kv = attn.attention_forward(
                lp["self_attn"], apply_norm(lp["norm1"], h, cfg.norm_eps),
                cfg, positions, return_cache=True)
            self_c = attn.prefill_into_cache(
                attn.init_kv_cache(cfg, h.shape[0], cap, cache_dtype),
                {"k": kv["k"].astype(cache_dtype),
                 "v": kv["v"].astype(cache_dtype)}, cfg)
            h = h + a
            c = attn.cross_attention_forward(
                lp["cross_attn"], apply_norm(lp["norm_x"], h, cfg.norm_eps),
                memory, cfg)
            cross_kv = attn.encoder_kv(lp["cross_attn"], memory, cfg)
            cross_c = {"k": cross_kv["k"].astype(cache_dtype),
                       "v": cross_kv["v"].astype(cache_dtype)}
            h = h + c
            h = h + ffn_forward(lp["ffn"],
                                apply_norm(lp["norm2"], h, cfg.norm_eps), cfg)
            return h, {"self": self_c, "cross": cross_c}

        from repro.models.runtime_flags import scan_unroll
        x, cache = jax.lax.scan(body, x, params["dec_layers"],
                                unroll=scan_unroll())
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        return lm_head(params["embed"], x[:, -1:]), cache

    def decode(self, params: PyTree, cache: PyTree, tokens: jax.Array,
               pos: jax.Array) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        x = embed_tokens(params["embed"], tokens, dtype)

        def body(h, xs):
            lp, c_in = xs
            a, self_c = attn.attention_decode(
                lp["self_attn"], apply_norm(lp["norm1"], h, cfg.norm_eps),
                c_in["self"], pos, cfg)
            h = h + a
            c = attn.cross_attention_decode(
                lp["cross_attn"], apply_norm(lp["norm_x"], h, cfg.norm_eps),
                c_in["cross"], cfg)
            h = h + c
            h = h + ffn_forward(lp["ffn"],
                                apply_norm(lp["norm2"], h, cfg.norm_eps), cfg)
            return h, {"self": self_c, "cross": c_in["cross"]}

        from repro.models.runtime_flags import scan_unroll
        x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache),
                                    unroll=scan_unroll())
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        return lm_head(params["embed"], x), new_cache
