"""Roofline model from the compiled dry-run artifact (no real hardware).

Hardware constants (TPU v5e, per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI link bandwidth ~50 GB/s per link

Terms (seconds, per step) — the compiled module is the per-device SPMD
program, so cost_analysis() numbers are per-device:

    compute    = HLO_FLOPs_per_device / peak
    memory     = HLO_bytes_per_device / hbm_bw
    collective = collective_bytes_per_device / link_bw

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) over the GLOBAL tokens per
step; the ratio MODEL_FLOPS / (HLO_FLOPs · chips) measures how much compiled
compute is "useful" (catches remat/dispatch/redundancy waste).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.configs.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw per-device numbers from the compiled module
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    cross_pod_bytes: float
    # terms in seconds
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    # usefulness
    model_flops: float
    useful_ratio: float
    note: str = ""

    def to_dict(self) -> Dict:
        return asdict(self)


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token: MoE layers count top_k of num_experts."""
    total = cfg.param_count()
    if cfg.moe is None:
        return total
    # subtract the inactive expert fraction of the expert FFN params
    m = cfg.moe
    d, dff = cfg.d_model, cfg.d_ff
    mult = 3 if cfg.act == "silu" else 2
    per_expert = mult * d * dff
    n_moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return total - inactive


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6·N_active·D with D = global tokens processed per step."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def build_report(arch: str, shape: InputShape, mesh_name: str, chips: int,
                 hlo_flops: float, hlo_bytes: float, collective_bytes: float,
                 cross_pod_bytes: float, cfg: Optional[ModelConfig],
                 note: str = "") -> RooflineReport:
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) if cfg is not None else 0.0
    total_hlo = hlo_flops * chips
    ratio = (mf / total_hlo) if total_hlo > 0 else 0.0
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes, cross_pod_bytes=cross_pod_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=mf, useful_ratio=ratio, note=note)


def format_table(reports) -> str:
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "bottleneck", "useful_ratio"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in reports:
        d = r.to_dict() if hasattr(r, "to_dict") else r
        row = []
        for c in cols:
            v = d[c]
            row.append(f"{v:.3e}" if isinstance(v, float) else str(v))
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)
