"""Host training loop: metric logging, plan-driven variant dispatch, comm
event/byte accounting, eval, and the Fig.-7 parameter-distance probe.

The loop is strategy-agnostic: ``strategy.plan(k)`` picks the compiled
variant and decides when an exchange happens; the strategy's
``host_exchange`` performs any host-side communication (the checkpoint-mode
stale refresh); ``strategy.comm_bytes`` prices each exchange event for the
Section-3 accounting. No mechanism-specific branching lives here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CodistConfig, TrainConfig
from repro.core.codistillation import param_distance_from
from repro.train.engine import (ExchangeStrategy, AllReduce, build_train_step,
                                resolve_strategy)

PyTree = Any


@dataclass
class History:
    records: List[Dict[str, float]] = field(default_factory=list)

    def log(self, step: int, metrics: Dict[str, Any], **extra):
        rec = {"step": step}
        for k, v in metrics.items():
            try:
                arr = jnp.asarray(v)
                if arr.ndim == 0:
                    rec[k] = float(arr)
                else:
                    for i, x in enumerate(arr.reshape(-1)):
                        rec[f"{k}_{i}"] = float(x)
            except Exception:
                pass
        rec.update(extra)
        self.records.append(rec)

    def last(self, key: str) -> float:
        for rec in reversed(self.records):
            if key in rec:
                return rec[key]
        raise KeyError(key)

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self.records if key in r]

    def save(self, path: str) -> None:
        """Persist as JSONL (one record per line) — async runs and benchmarks
        stream trajectories to disk instead of keeping them in memory."""
        import json
        import os
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec) + "\n")

    @classmethod
    def load(cls, path: str) -> "History":
        import json
        with open(path) as f:
            return cls([json.loads(line) for line in f if line.strip()])


def train(model, tc: TrainConfig, batches: Callable[[int], Dict],
          strategy: ExchangeStrategy, codist: Optional[CodistConfig] = None,
          eval_batches: Optional[Callable[[int], Dict]] = None,
          eval_every: int = 0, log_every: int = 10,
          state=None, trainable: Optional[PyTree] = None,
          track_param_distance: bool = False) -> tuple:
    """Generic strategy-driven loop. ``batches(step)`` returns the batch for
    that step (stacked with a leading n axis for codist strategies — it owns
    coordinated vs. independent sampling)."""
    from repro.optim import make_optimizer
    opt_init, _ = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                 b1=tc.adam_b1, b2=tc.adam_b2,
                                 dtype=tc.opt_dtype)
    example = batches(0)
    if state is None:
        state = strategy.init_state(model, tc, jax.random.key(tc.seed),
                                    opt_init, example)
    else:
        state = strategy.ensure_state(state, model, tc, example)
    bundle = build_train_step(model, tc, codist, strategy, trainable)
    eval_fn = jax.jit(bundle.eval_fn)
    params0 = (jax.tree.map(jnp.array, state.params)
               if track_param_distance else None)
    bytes_per_event = strategy.comm_bytes(model, state, example, tc.microbatch)
    hist = History()
    comm_events = 0
    for k in range(tc.total_steps):
        batch = example if k == 0 else batches(k)
        state, metrics, plan = bundle.apply(state, batch, k)
        if plan.exchange:
            comm_events += 1
        if k % log_every == 0 or k == tc.total_steps - 1:
            extra = {"comm_events": comm_events,
                     "comm_bytes": comm_events * bytes_per_event}
            if track_param_distance:
                extra["param_distance"] = float(
                    param_distance_from(state.params, params0))
            if eval_every and eval_batches is not None and (
                    k % eval_every == 0 or k == tc.total_steps - 1):
                metrics = {**metrics, **eval_fn(state.params, eval_batches(k))}
            hist.log(k, metrics, **extra)
    return state, hist


def train_allreduce(model, tc: TrainConfig, batches: Iterator[Dict],
                    eval_batches: Optional[Callable[[int], Dict]] = None,
                    eval_every: int = 0, log_every: int = 10,
                    state=None, trainable: Optional[PyTree] = None,
                    track_param_distance: bool = False) -> tuple:
    it = iter(batches)
    return train(model, tc, lambda k: next(it), AllReduce(),
                 eval_batches=eval_batches, eval_every=eval_every,
                 log_every=log_every, state=state, trainable=trainable,
                 track_param_distance=track_param_distance)


def train_codist(model, codist: CodistConfig, tc: TrainConfig,
                 batches: Callable[[int], Dict],
                 eval_batches: Optional[Callable[[int], Dict]] = None,
                 eval_every: int = 0, log_every: int = 10,
                 state=None, trainable: Optional[PyTree] = None,
                 track_param_distance: bool = False,
                 strategy: Optional[ExchangeStrategy] = None) -> tuple:
    """Codistillation loop; the mechanism comes from ``strategy`` (explicit
    instance, e.g. ``ShardMapCompressed``) or ``resolve_strategy(codist)``."""
    strategy = strategy if strategy is not None else resolve_strategy(codist)
    return train(model, tc, batches, strategy, codist=codist,
                 eval_batches=eval_batches, eval_every=eval_every,
                 log_every=log_every, state=state, trainable=trainable,
                 track_param_distance=track_param_distance)


def stack_batches(batch_list: List[Dict]) -> Dict:
    """[batch_i] -> stacked dict with leading n axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)
