"""Fused codistillation-loss Pallas TPU kernel (the paper's D(y, y')).

Computes the per-token distillation loss between two logit tensors without
materializing any (T, V) temporary: vocab tiles stream through VMEM and a
per-row accumulator carries across the innermost grid dimension.

Modes:
  * ``mse`` — mean over vocab of (a - b)^2, the paper's loss (A.3:
    "mean squared error between the logits of the two models");
  * ``kl``  — KL(softmax(target) || softmax(logits)) via a streaming
    five-accumulator form (online logsumexp for BOTH operands plus the
    max-rescaled cross term), Anil/Zhang et al.'s loss.

Both read each logit tile exactly once — this is the kernel that makes
every-step prediction exchange affordable at LM vocabulary sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mse_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_v: int, v_total: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    d = a - b
    acc_ref[...] = acc_ref[...] + jnp.sum(d * d, axis=-1)

    @pl.when(j == n_v - 1)
    def _fin():
        out_ref[...] = acc_ref[...] / v_total


def _kl_kernel(s_logits_ref, t_logits_ref, out_ref,
               mt_ref, st_ref, ms_ref, ss_ref, u_ref, *, n_v: int):
    """KL(softmax(t) || softmax(s)) streamed over vocab tiles."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        mt_ref[...] = jnp.full_like(mt_ref, NEG)
        ms_ref[...] = jnp.full_like(ms_ref, NEG)
        st_ref[...] = jnp.zeros_like(st_ref)
        ss_ref[...] = jnp.zeros_like(ss_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    lt = t_logits_ref[...].astype(jnp.float32)
    ls = s_logits_ref[...].astype(jnp.float32)

    # target-side online logsumexp + rescaled cross term U = sum e^{lt-Mt}(lt-ls)
    mt_prev = mt_ref[...]
    mt_new = jnp.maximum(mt_prev, jnp.max(lt, axis=-1))
    alpha_t = jnp.exp(mt_prev - mt_new)
    w = jnp.exp(lt - mt_new[:, None])
    st_ref[...] = st_ref[...] * alpha_t + jnp.sum(w, axis=-1)
    u_ref[...] = u_ref[...] * alpha_t + jnp.sum(w * (lt - ls), axis=-1)
    mt_ref[...] = mt_new

    # student-side online logsumexp
    ms_prev = ms_ref[...]
    ms_new = jnp.maximum(ms_prev, jnp.max(ls, axis=-1))
    ss_ref[...] = ss_ref[...] * jnp.exp(ms_prev - ms_new) + jnp.sum(
        jnp.exp(ls - ms_new[:, None]), axis=-1)
    ms_ref[...] = ms_new

    @pl.when(j == n_v - 1)
    def _fin():
        log_zt = mt_ref[...] + jnp.log(st_ref[...])
        log_zs = ms_ref[...] + jnp.log(ss_ref[...])
        out_ref[...] = u_ref[...] / st_ref[...] - log_zt + log_zs


@functools.partial(jax.jit,
                   static_argnames=("mode", "block_t", "block_v", "interpret"))
def fused_distill_loss(logits: jax.Array, target_logits: jax.Array,
                       mode: str = "mse", block_t: int = 256,
                       block_v: int = 512, interpret: bool = False
                       ) -> jax.Array:
    """Per-token distillation loss. (T, V) x2 -> (T,) fp32."""
    t, v = logits.shape
    assert logits.shape == target_logits.shape
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    n_t, n_v = t // block_t, v // block_v
    vm = lambda: pltpu.VMEM((block_t,), jnp.float32)
    if mode == "mse":
        kernel = functools.partial(_mse_kernel, n_v=n_v, v_total=v)
        scratch = [vm()]
    elif mode == "kl":
        kernel = functools.partial(_kl_kernel, n_v=n_v)
        scratch = [vm(), vm(), vm(), vm(), vm()]
    else:
        raise ValueError(mode)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_t, block_v), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(logits, target_logits)
