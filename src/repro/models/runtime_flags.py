"""Thread-local tracing flags used by the dry-run cost probes.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip count,
so scanned-layer costs are invisible at full depth. The dry-run therefore
compiles shallow PROBE variants with (a) the layer scan unrolled and (b)
chunked SSM scans widened to a single full-sequence chunk — making every FLOP
and collective statically visible — and extrapolates linearly in depth.
Normal execution paths never set these flags.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

_state = threading.local()


def scan_unroll() -> bool:
    return getattr(_state, "unroll", False)


def full_chunk() -> bool:
    return getattr(_state, "full_chunk", False)


@contextmanager
def probe_mode():
    _state.unroll = True
    _state.full_chunk = True
    try:
        yield
    finally:
        _state.unroll = False
        _state.full_chunk = False


def resolve_chunk(chunk: int, seq_len: int) -> int:
    return seq_len if full_chunk() else chunk
