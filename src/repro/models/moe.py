"""Top-k Mixture-of-Experts with GShard/Switch-style capacity dispatch.

TPU-idiomatic: routing is turned into dense one-hot dispatch/combine einsums
(no ragged gathers), so the whole block lowers to MXU matmuls + (under expert
sharding) all-to-all-shaped collectives inserted by SPMD. Supports Arctic's
dense-residual branch (a dense FFN running in parallel with the experts) and
top-2 weight normalization (Mixtral-style).

Capacity: C = ceil(top_k * T / E * capacity_factor); overflow tokens fall back
to the residual stream (their combine weight is zero), the standard
drop-with-residual policy.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import KeyGen, activation, dense_init
from repro.models.ffn import ffn_forward, init_ffn


def init_moe(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    m = cfg.moe
    assert m is not None
    kg = KeyGen(key)
    d, dff, e = cfg.d_model, cfg.d_ff, m.num_experts

    def expert_stack(k, in_dim, out_dim, scale=1.0):
        ks = jax.random.split(k, e)
        return jax.vmap(lambda kk: dense_init(kk, in_dim, (out_dim,),
                                              dtype, scale))(ks)

    p: Dict = {"router": dense_init(kg(), d, (e,), dtype)}
    if cfg.act in ("silu", "geglu"):
        p["w_gate"] = expert_stack(kg(), d, dff)
        p["w_up"] = expert_stack(kg(), d, dff)
        p["w_down"] = expert_stack(kg(), dff, d,
                                   1.0 / max(1, cfg.num_layers) ** 0.5)
    else:
        p["w_up"] = expert_stack(kg(), d, dff)
        p["w_down"] = expert_stack(kg(), dff, d,
                                   1.0 / max(1, cfg.num_layers) ** 0.5)
    if m.dense_residual:
        p["residual"] = init_ffn(kg(), cfg, dtype=dtype)
    return p


def _capacity(m: MoEConfig, tokens: int, capacity_factor: float = 1.25) -> int:
    """capacity per expert per group; capacity_factor<=0 => NO-DROP (cap =
    group size — serving paths use this so incremental decode is numerically
    identical to prefill; training keeps the GShard 1.25 drop policy)."""
    if capacity_factor <= 0:
        return tokens
    c = math.ceil(m.top_k * tokens / m.num_experts * capacity_factor)
    return max(min(4, tokens), min(tokens, c))


def router_decisions(m: MoEConfig, logits: jax.Array,
                     capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits: (T, E) float32. Returns (dispatch (T,E,C) bool-ish,
    combine (T,E,C) float32, aux load-balance loss scalar)."""
    t, e = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)       # (T,k)
    if m.top_k > 1:  # Mixtral-style renormalization over the chosen experts
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)    # (T,k,E)
    # GShard priority: all tokens' 1st choices first, then 2nd choices.
    # position_in_expert[t,k,e] = (# earlier (t',k') pairs routed to e)
    flat = onehot.transpose(1, 0, 2).reshape(m.top_k * t, e)   # (k*T, E)
    pos_flat = jnp.cumsum(flat, axis=0) - flat                 # exclusive cumsum
    pos = pos_flat.reshape(m.top_k, t, e).transpose(1, 0, 2)   # (T,k,E)
    keep = (pos < capacity).astype(jnp.float32) * onehot       # (T,k,E)
    slot = jnp.einsum("tke,tke->tk", pos, onehot)              # (T,k) slot index
    slot_oh = jax.nn.one_hot(slot, capacity, dtype=jnp.float32)  # (T,k,C)
    dispatch = jnp.einsum("tke,tkc->tec",
                          keep, slot_oh)                       # (T,E,C)
    combine = jnp.einsum("tke,tk,tkc->tec", keep, gate_vals, slot_oh)

    # Switch load-balance loss over the top-k assignment fractions
    frac_routed = jnp.mean(onehot, axis=(0, 1)) * m.top_k      # f_e, sums to k/k
    mean_prob = jnp.mean(probs, axis=0)                        # p_e
    aux = e * jnp.sum(frac_routed * mean_prob)
    return dispatch, combine, aux


def moe_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                router_key: Optional[jax.Array] = None,
                capacity_factor: float = 1.25):
    """x: (B,S,d) -> (y, aux_loss).

    GShard-style GROUPED dispatch: each batch row is a routing group with its
    own capacity C = ceil(top_k * S / E * cf). This keeps the one-hot
    dispatch/combine tensors at O(S * E * C) per group — with E*C ~= top_k*cf*S
    that is ~quadratic in the GROUP size (like attention), instead of
    quadratic in the global token count, and the group axis shards over
    "data" exactly like the batch.
    """
    m = cfg.moe
    assert m is not None
    b, s, d = x.shape
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if router_key is not None and m.router_jitter > 0:
        logits = logits * (1.0 + m.router_jitter * jax.random.uniform(
            router_key, logits.shape, minval=-1.0, maxval=1.0))
    cap = _capacity(m, s, capacity_factor)
    dispatch, combine, aux = jax.vmap(
        lambda lg: router_decisions(m, lg, cap))(logits)       # (G,s,E,C) x2
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)
    aux = jnp.mean(aux)

    xin = jnp.einsum("gsec,gsd->gecd", dispatch, x)            # (G,E,C,d)
    act = activation(cfg.act if cfg.act != "relu" else "gelu")
    if "w_gate" in p:
        h = act(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("gecd,edf->gecf", xin, p["w_up"].astype(x.dtype))
    else:
        h = act(jnp.einsum("gecd,edf->gecf", xin, p["w_up"].astype(x.dtype)))
    yout = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("gsec,gecd->gsd", combine, yout)

    if "residual" in p:  # Arctic dense-MoE hybrid
        y = y + ffn_forward(p["residual"], x, cfg)
    return y, aux * m.load_balance_weight
