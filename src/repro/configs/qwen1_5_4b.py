"""qwen1.5-4b [dense] — QKV bias, MHA [hf:Qwen/Qwen1.5-0.5B family card].

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.
"""
from repro.configs.base import ModelConfig, reduced as _reduced

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    act="silu",
    source="Qwen1.5-4B [hf:Qwen/Qwen1.5-4B]",
)


def reduced():
    return _reduced(CONFIG)
