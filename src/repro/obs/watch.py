"""Watchtower: deterministic alerting over the metrics registry.

The obs layer (PR 8) made every run emit byte-identical traces and
metrics — but they were write-only. The Watchtower closes the loop: it
evaluates **declarative rules** over the live ``MetricsRegistry`` streams
on the same simulated clock the subsystems tick on, firing and resolving
alerts as a canonical JSONL log that is bit-identical per seed and
therefore CI-gateable exactly like the SLO reports (the
``obs-watch-smoke`` job byte-compares two seeded chaos runs).

Three rule kinds:

* ``threshold``   — compare one signal of the watched stream against a
                    bound (``fleet/kv_utilization >= 0.95``)
* ``burn_rate``   — the fraction of the last ``window`` samples breaching
                    the bound must stay under ``budget`` (the SLO-burn
                    idiom: "more than half the recent TTFTs over the SLO")
* ``ewma_drift``  — compare the signal's deviation from its own
                    exponentially-weighted baseline (catches the paper's
                    codist-vs-baseline loss-gap drifting after it had
                    converged, without hardcoding an absolute loss level)

Hysteresis is explicit: a rule must breach ``fire_after`` consecutive
evaluations to fire and recover ``resolve_after`` consecutive evaluations
to resolve, so a single straggler tick does not flap the alert log.

Everything is a pure function of the observation stream: no wall clock,
no randomness, and — critically — evaluation only *reads* metrics through
the registry's non-creating ``peek``, so a run with alerting enabled
exports byte-identical metrics/trace/report artifacts to one without
(pinned by ``tests/test_watch.py`` and the overhead-off chaos gate).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.fsio import atomic_write_text
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

ALERTS_SCHEMA_VERSION = 1

KINDS = ("threshold", "burn_rate", "ewma_drift")
SIGNALS = ("value", "count", "window_mean", "window_min", "window_max",
           "p50", "p90", "p99")
OPS = (">", "<", ">=", "<=")
SEVERITIES = ("info", "warning", "critical")

# rule names key the alert log and CI `--expect counts.<rule>__firing>=1`
# clauses, whose dotted-path grammar allows [A-Za-z0-9_-] segments — so no
# dots (or anything else) here
_NAME_RE = re.compile(r"^[A-Za-z0-9_-]+$")

_OP_FN: Dict[str, Callable[[float, float], bool]] = {
    ">": lambda a, b: a > b,
    "<": lambda a, b: a < b,
    ">=": lambda a, b: a >= b,
    "<=": lambda a, b: a <= b,
}


@dataclass(frozen=True)
class Rule:
    """One declarative alert rule (see module docstring for semantics)."""

    name: str
    metric: str
    kind: str                  # threshold | burn_rate | ewma_drift
    op: str                    # > | < | >= | <=
    value: float               # the bound (threshold / per-sample / drift)
    signal: str = "value"      # which view of the stream to compare
    window: int = 8            # samples for window_* / p* / burn_rate
    fire_after: int = 1        # consecutive breaches before firing
    resolve_after: int = 1     # consecutive recoveries before resolving
    severity: str = "warning"
    alpha: float = 0.25        # EWMA smoothing (ewma_drift only)
    budget: float = 0.5        # breach fraction that fires (burn_rate only)
    min_count: int = 1         # samples required before evaluating at all

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name, "metric": self.metric, "kind": self.kind,
            "op": self.op, "value": self.value, "signal": self.signal,
            "window": self.window, "fire_after": self.fire_after,
            "resolve_after": self.resolve_after, "severity": self.severity,
            "alpha": self.alpha, "budget": self.budget,
            "min_count": self.min_count,
        }


_RULE_KEYS = frozenset(Rule(name="x", metric="x", kind="threshold", op=">",
                            value=0.0).to_dict())


def parse_rule(spec: Dict[str, Any], where: str = "") -> Rule:
    """Validate one rule spec dict; errors name the offending clause in
    the style of ``parse_faults`` so a typo'd rules file is a one-line
    fix, not a stack trace."""
    label = where or repr(spec.get("name", spec))

    def err(msg: str) -> ValueError:
        return ValueError(f"alert rule {label}: {msg}")

    if not isinstance(spec, dict):
        raise err(f"expected a mapping, got {type(spec).__name__}")
    unknown = sorted(set(spec) - _RULE_KEYS)
    if unknown:
        raise err(f"unknown key(s) {unknown} (known: {sorted(_RULE_KEYS)})")
    for key in ("name", "metric", "kind", "op", "value"):
        if key not in spec:
            raise err(f"missing required key {key!r}")
    name = spec["name"]
    if not isinstance(name, str) or not _NAME_RE.match(name):
        raise err(f"name {name!r} must match {_NAME_RE.pattern} "
                  "(it keys the alert log and CI expect-clauses)")
    if not isinstance(spec["metric"], str) or not spec["metric"]:
        raise err(f"metric {spec['metric']!r} must be a non-empty string")
    if spec["kind"] not in KINDS:
        raise err(f"kind {spec['kind']!r} not one of {KINDS}")
    if spec["op"] not in OPS:
        raise err(f"op {spec['op']!r} not one of {OPS}")
    if not isinstance(spec["value"], (int, float)) \
            or isinstance(spec["value"], bool):
        raise err(f"value {spec['value']!r} must be a number")
    if spec.get("signal", "value") not in SIGNALS:
        raise err(f"signal {spec.get('signal')!r} not one of {SIGNALS}")
    if spec.get("severity", "warning") not in SEVERITIES:
        raise err(f"severity {spec.get('severity')!r} not one of "
                  f"{SEVERITIES}")
    for key, lo in (("window", 1), ("fire_after", 1), ("resolve_after", 1),
                    ("min_count", 0)):
        v = spec.get(key, lo if lo else 1)
        if not isinstance(v, int) or isinstance(v, bool) or v < lo:
            raise err(f"{key} {v!r} must be an integer >= {lo}")
    for key in ("alpha", "budget"):
        v = spec.get(key, 0.5)
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or not 0.0 < float(v) <= 1.0:
            raise err(f"{key} {v!r} must be in (0, 1]")
    return Rule(
        name=name, metric=spec["metric"], kind=spec["kind"], op=spec["op"],
        value=float(spec["value"]), signal=spec.get("signal", "value"),
        window=spec.get("window", 8),
        fire_after=spec.get("fire_after", 1),
        resolve_after=spec.get("resolve_after", 1),
        severity=spec.get("severity", "warning"),
        alpha=float(spec.get("alpha", 0.25)),
        budget=float(spec.get("budget", 0.5)),
        min_count=spec.get("min_count", 1))


def parse_rules(specs: Sequence[Dict[str, Any]]) -> List[Rule]:
    if not isinstance(specs, (list, tuple)):
        raise ValueError(
            f"alert rules: expected a list of rule mappings, got "
            f"{type(specs).__name__}")
    rules: List[Rule] = []
    seen: set = set()
    for i, spec in enumerate(specs):
        where = (repr(spec["name"])
                 if isinstance(spec, dict) and isinstance(
                     spec.get("name"), str)
                 else f"#{i}")
        rule = parse_rule(spec, where=where)
        if rule.name in seen:
            raise ValueError(f"alert rule {rule.name!r}: duplicate name")
        seen.add(rule.name)
        rules.append(rule)
    return rules


def load_rules(path: str) -> List[Rule]:
    """Load rules from a JSON file: either a bare list of rule mappings
    or ``{"rules": [...]}``."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict):
        if "rules" not in doc:
            raise ValueError(
                f"alert rules file {path!r}: mapping form must have a "
                f"'rules' key (got keys {sorted(doc)})")
        doc = doc["rules"]
    return parse_rules(doc)


def default_rules(slo_ms: float = 50.0) -> List[Rule]:
    """The built-in rule pack over the codistillation-specific signals the
    repo already emits (docs/observability.md has the catalog)."""
    return parse_rules([
        # chaos straggler: the engine publishes its chaos slowdown
        # multiplier every tick; any recent tick over 2x means a peer is
        # visibly degraded. resolve_after=2 so the episode must genuinely
        # end, not dip for one tick.
        {"name": "straggler-slowdown", "metric": "fleet/slowdown",
         "kind": "threshold", "signal": "window_max", "op": ">",
         "value": 2.0, "window": 8, "fire_after": 1, "resolve_after": 2,
         "severity": "warning"},
        # speculative accept-rate collapse — the label-free quality
        # canary: mean accepted-prefix length under 1 token means the
        # drafter and verifier have diverged
        {"name": "spec-accept-collapse", "metric": "fleet/spec_accept",
         "kind": "threshold", "signal": "window_mean", "op": "<",
         "value": 1.0, "window": 16, "min_count": 16,
         "severity": "critical"},
        # distill_pair canary divergence (end-of-run report gauge)
        {"name": "canary-divergence", "metric": "report/canary_mean_mse",
         "kind": "threshold", "op": ">", "value": 1.0,
         "severity": "critical"},
        # async-runtime mailbox staleness breach
        {"name": "mailbox-staleness", "metric": "runtime/mailbox_staleness_mean",
         "kind": "threshold", "op": ">", "value": 4.0,
         "severity": "warning"},
        # SLO burn rate: more than half the last 16 first-token latencies
        # over the SLO
        {"name": "slo-burn-rate", "metric": "fleet/ttft_live_ms",
         "kind": "burn_rate", "op": ">", "value": float(slo_ms),
         "window": 16, "budget": 0.5, "min_count": 4,
         "severity": "critical"},
        # KV pool occupancy saturation: sustained >= 95% means admission
        # is about to stall
        {"name": "kv-pool-saturation", "metric": "fleet/kv_utilization",
         "kind": "threshold", "op": ">=", "value": 0.95, "fire_after": 3,
         "resolve_after": 2, "severity": "warning"},
        # codist-vs-baseline loss gap drifting above its own EWMA baseline
        # in sweeps (the paper's "properly accounted for" caveat)
        {"name": "loss-gap-drift", "metric": "sweep/loss_gap",
         "kind": "ewma_drift", "op": ">", "value": 0.5, "alpha": 0.25,
         "severity": "warning"},
    ])


class _RuleState:
    __slots__ = ("streak_bad", "streak_ok", "firing", "ewma")

    def __init__(self) -> None:
        self.streak_bad = 0
        self.streak_ok = 0
        self.firing = False
        self.ewma: Optional[float] = None


class Watchtower:
    """Evaluates rules against a registry on a simulated clock.

    Call ``evaluate(t)`` at natural points of the simulated timeline (the
    fleet calls it once per decode tick, the runtime once per virtual-time
    step, the trainer at log points). ``unit_us`` quantizes ``t`` to
    integer microseconds at record time, the same discipline as the
    tracer, so the alert log sorts and serializes identically on every
    machine.
    """

    def __init__(self, registry: MetricsRegistry, rules: Sequence[Rule],
                 unit_us: float = 1000.0, clock: str = "sim_ms"):
        if unit_us <= 0:
            raise ValueError(f"unit_us={unit_us} must be > 0")
        self.registry = registry
        self.rules = list(rules)
        self.unit_us = float(unit_us)
        self.clock = clock
        self._state: Dict[str, _RuleState] = {
            r.name: _RuleState() for r in self.rules}
        self._events: List[Dict[str, Any]] = []
        self._seq = 0
        self._alert_cbs: List[Callable[[Dict[str, Any]], None]] = []
        self._fault_cbs: List[Callable[[Dict[str, Any]], None]] = []

    # ---- callbacks (the flight recorder hooks in here) ---------------------
    def on_alert(self, cb: Callable[[Dict[str, Any]], None]) -> None:
        self._alert_cbs.append(cb)

    def on_fault(self, cb: Callable[[Dict[str, Any]], None]) -> None:
        self._fault_cbs.append(cb)

    def note_fault(self, kind: str, t: float,
                   context: Optional[Dict[str, Any]] = None) -> None:
        """An injected fault (preempt/fail/straggle) happened: notify the
        fault callbacks so the flight recorder can dump a bundle. Faults
        are *not* alert events — they are causes, recorded in the
        postmortem, while the alert log records observed symptoms."""
        ev = {"kind": kind, "ts": self._ts(t), "context": context or {}}
        for cb in self._fault_cbs:
            cb(ev)

    # ---- signal resolution -------------------------------------------------
    def _ts(self, t: float) -> int:
        ts = int(round(float(t) * self.unit_us))
        if ts < 0:
            raise ValueError(f"negative timestamp {t} on a simulated clock")
        return ts

    @staticmethod
    def _samples(stream: Any, window: int) -> Optional[List[float]]:
        if isinstance(stream, Histogram):
            return [float(v) for v in stream.values[-window:]]
        if isinstance(stream, Gauge):
            return stream.window(window)
        if isinstance(stream, Counter):
            return [float(stream.value)]
        return None

    def _signal(self, rule: Rule, stream: Any) -> Optional[float]:
        """The rule's view of the stream, or None when there is not enough
        data to evaluate (streaks are left untouched in that case)."""
        window = self._samples(stream, rule.window)
        if window is None:
            return None
        n_total = (stream.count if isinstance(stream, Histogram)
                   else len(window))
        if n_total < rule.min_count or not window:
            return None
        sig = rule.signal
        if sig == "value":
            if isinstance(stream, (Counter, Gauge)):
                return float(stream.value)
            return window[-1]
        if sig == "count":
            return float(stream.count if isinstance(stream, Histogram)
                         else len(window))
        if sig == "window_mean":
            return float(sum(window) / len(window))
        if sig == "window_min":
            return float(min(window))
        if sig == "window_max":
            return float(max(window))
        q = {"p50": 50.0, "p90": 90.0, "p99": 99.0}[sig]
        return float(np.percentile(np.asarray(window, np.float64), q))

    def _breach(self, rule: Rule, stream: Any) -> Optional[Dict[str, Any]]:
        """None = not enough data; otherwise {"bad": bool, "value": float}
        plus kind-specific context."""
        op = _OP_FN[rule.op]
        if rule.kind == "burn_rate":
            window = self._samples(stream, rule.window)
            if window is None:
                return None
            n_total = (stream.count if isinstance(stream, Histogram)
                       else len(window))
            if n_total < rule.min_count or not window:
                return None
            breaching = sum(1 for v in window if op(v, rule.value))
            frac = breaching / len(window)
            return {"bad": frac >= rule.budget, "value": float(frac),
                    "n": len(window)}
        sig = self._signal(rule, stream)
        if sig is None:
            return None
        if rule.kind == "threshold":
            return {"bad": op(sig, rule.value), "value": sig}
        # ewma_drift: deviation of the signal from its own EWMA baseline.
        # The baseline seeds on the first sample (no drift by definition)
        # and updates every evaluation, breaching or not — a sustained
        # breach therefore self-resolves once the new level becomes the
        # baseline, which is the point: this rule watches *change*.
        st = self._state[rule.name]
        if st.ewma is None:
            st.ewma = sig
            return {"bad": False, "value": 0.0, "ewma": sig}
        drift = sig - st.ewma
        st.ewma = st.ewma + rule.alpha * (sig - st.ewma)
        return {"bad": op(drift, rule.value), "value": float(drift),
                "ewma": float(st.ewma)}

    # ---- evaluation --------------------------------------------------------
    def evaluate(self, t: float) -> List[Dict[str, Any]]:
        """Evaluate every rule at simulated time ``t``; returns the alert
        events emitted by this call (also appended to the log)."""
        ts = self._ts(t)
        emitted: List[Dict[str, Any]] = []
        for rule in self.rules:
            stream = self.registry.peek(rule.metric)
            if stream is None:
                continue
            res = self._breach(rule, stream)
            if res is None:
                continue
            st = self._state[rule.name]
            if res["bad"]:
                st.streak_bad += 1
                st.streak_ok = 0
            else:
                st.streak_ok += 1
                st.streak_bad = 0
            new_state: Optional[str] = None
            if not st.firing and st.streak_bad >= rule.fire_after:
                st.firing = True
                new_state = "firing"
            elif st.firing and st.streak_ok >= rule.resolve_after:
                st.firing = False
                new_state = "resolved"
            if new_state is None:
                continue
            context = {k: v for k, v in res.items()
                       if k not in ("bad", "value")}
            context["signal"] = rule.signal
            context["window"] = rule.window
            ev = {"ts": ts, "seq": self._seq, "rule": rule.name,
                  "state": new_state, "value": res["value"],
                  "threshold": rule.value, "op": rule.op,
                  "metric": rule.metric, "kind": rule.kind,
                  "severity": rule.severity, "context": context}
            self._seq += 1
            self._events.append(ev)
            emitted.append(ev)
            for cb in self._alert_cbs:
                cb(ev)
        return emitted

    # ---- introspection / export --------------------------------------------
    @property
    def n_events(self) -> int:
        return len(self._events)

    def firing(self) -> List[str]:
        """Names of rules currently in the firing state, sorted."""
        return sorted(n for n, st in self._state.items() if st.firing)

    def summary(self) -> Dict[str, Any]:
        counts: Dict[str, int] = {}
        for ev in self._events:
            key = f"{ev['rule']}__{ev['state']}"
            counts[key] = counts.get(key, 0) + 1
        return {"n_events": len(self._events),
                "counts": dict(sorted(counts.items())),
                "firing": self.firing()}

    def to_jsonl(self) -> str:
        """Header line + one canonical JSON line per alert event, sorted
        by (ts, seq) — byte-identical per seed, the CI gate's whole
        contract."""
        header = {"schema_version": ALERTS_SCHEMA_VERSION, "kind": "alerts",
                  "clock": self.clock, "unit_us": self.unit_us,
                  "n_rules": len(self.rules)}
        lines = [json.dumps(header, sort_keys=True, separators=(",", ":"))]
        for ev in sorted(self._events, key=lambda e: (e["ts"], e["seq"])):
            lines.append(json.dumps(ev, sort_keys=True,
                                    separators=(",", ":")))
        return "\n".join(lines) + "\n"

    def save(self, path: str) -> None:
        atomic_write_text(path, self.to_jsonl())
