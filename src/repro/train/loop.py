"""Host training loops: metric logging, StepPlan-driven variant dispatch,
periodic checkpoint exchange, eval, and the Fig.-7 parameter-distance probe.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CodistConfig, TrainConfig
from repro.core.codistillation import param_distance_from
from repro.core.exchange import StepPlan
from repro.train import steps as steps_mod
from repro.train.state import CodistState, TrainState

PyTree = Any


@dataclass
class History:
    records: List[Dict[str, float]] = field(default_factory=list)

    def log(self, step: int, metrics: Dict[str, Any], **extra):
        rec = {"step": step}
        for k, v in metrics.items():
            try:
                arr = jnp.asarray(v)
                if arr.ndim == 0:
                    rec[k] = float(arr)
                else:
                    for i, x in enumerate(arr.reshape(-1)):
                        rec[f"{k}_{i}"] = float(x)
            except Exception:
                pass
        rec.update(extra)
        self.records.append(rec)

    def last(self, key: str) -> float:
        for rec in reversed(self.records):
            if key in rec:
                return rec[key]
        raise KeyError(key)

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self.records if key in r]


def train_allreduce(model, tc: TrainConfig, batches: Iterator[Dict],
                    eval_batches: Optional[Callable[[int], Dict]] = None,
                    eval_every: int = 0, log_every: int = 10,
                    state: Optional[TrainState] = None,
                    trainable: Optional[PyTree] = None,
                    track_param_distance: bool = False) -> tuple:
    from repro.optim import make_optimizer
    from repro.train.state import init_train_state
    opt_init, _ = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                 b1=tc.adam_b1, b2=tc.adam_b2)
    if state is None:
        state = init_train_state(model, jax.random.key(tc.seed), opt_init)
    params0 = jax.tree.map(jnp.array, state.params) if track_param_distance else None
    step_fn = jax.jit(steps_mod.make_allreduce_step(model, tc, trainable))
    eval_fn = jax.jit(steps_mod.make_eval_step(model, tc))
    hist = History()
    for k in range(tc.total_steps):
        state, metrics = step_fn(state, next(batches))
        if k % log_every == 0 or k == tc.total_steps - 1:
            extra = {}
            if track_param_distance:
                extra["param_distance"] = float(
                    param_distance_from(state.params, params0))
            if eval_every and eval_batches is not None and (
                    k % eval_every == 0 or k == tc.total_steps - 1):
                metrics = {**metrics, **eval_fn(state.params, eval_batches(k))}
            hist.log(k, metrics, **extra)
    return state, hist


def train_codist(model, codist: CodistConfig, tc: TrainConfig,
                 batches: Callable[[int], Dict],
                 eval_batches: Optional[Callable[[int], Dict]] = None,
                 eval_every: int = 0, log_every: int = 10,
                 state: Optional[CodistState] = None,
                 trainable: Optional[PyTree] = None,
                 track_param_distance: bool = False) -> tuple:
    """Generic codistillation loop.

    ``batches(step)`` returns the stacked batch dict (leading n axis) for that
    step — it owns coordinated vs. independent sampling.
    """
    from repro.optim import make_optimizer
    from repro.train.state import init_codist_state
    opt_init, _ = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                 b1=tc.adam_b1, b2=tc.adam_b2)
    ckpt_mode = codist.mode == "checkpoints"
    if state is None:
        state = init_codist_state(model, jax.random.key(tc.seed),
                                  codist.n_models, opt_init,
                                  with_stale=ckpt_mode)
    params0 = jax.tree.map(jnp.array, state.params) if track_param_distance else None

    if codist.pipelined:
        step_on = jax.jit(steps_mod.make_codist_pipelined_step(model, codist, tc))
        step_off = None
    elif ckpt_mode:
        step_on = jax.jit(steps_mod.make_codist_checkpoint_step(
            model, codist, tc, trainable))
        step_off = None
    else:
        step_on = jax.jit(steps_mod.make_codist_step(model, codist, tc, True,
                                                     trainable))
        step_off = jax.jit(steps_mod.make_codist_step(model, codist, tc, False,
                                                      trainable))
    eval_fn = jax.jit(steps_mod.make_codist_eval_step(model, tc))
    hist = History()
    comm_events = 0
    for k in range(tc.total_steps):
        batch_all = batches(k)
        plan = StepPlan.for_step(codist, k)
        if codist.pipelined:
            if state.peer is None:
                n = codist.n_models
                # peer logits shape: infer from a dry forward on model 0
                logits_shape = jax.eval_shape(
                    lambda p, b: model.forward(
                        jax.tree.map(lambda x: x[0], p),
                        jax.tree.map(lambda x: x[0], b))[0],
                    state.params, batch_all).shape
                state = state._replace(peer=steps_mod.init_peer_state(
                    batch_all, (n, *logits_shape)))
            state, metrics = step_on(state, batch_all)
            comm_events += 1
        elif ckpt_mode:
            if plan.exchange:
                state = steps_mod.refresh_stale(state)
                comm_events += 1
            state, metrics = step_on(state, batch_all)
        else:
            if plan.distill:
                state, metrics = step_on(state, batch_all)
                comm_events += 1
            else:
                state, metrics = step_off(state, batch_all)
        if k % log_every == 0 or k == tc.total_steps - 1:
            extra = {"comm_events": comm_events}
            if track_param_distance:
                extra["param_distance"] = float(
                    param_distance_from(state.params, params0))
            if eval_every and eval_batches is not None and (
                    k % eval_every == 0 or k == tc.total_steps - 1):
                metrics = {**metrics, **eval_fn(state.params, eval_batches(k))}
            hist.log(k, metrics, **extra)
    return state, hist


def stack_batches(batch_list: List[Dict]) -> Dict:
    """[batch_i] -> stacked dict with leading n axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)
