"""Step-time microbenchmarks (CPU, tiny model): every exchange strategy
through the unified ``build_train_step`` engine, plus the kernels vs their
jnp references. Wall-clock on this container is NOT TPU-predictive —
roofline terms in the dry-run are — but relative step structure (distill
on/off, checkpoint n-forwards, pipelined replay, shard_map exchange) is.
Each strategy row's ``derived`` carries its Section-3 comm accounting:
``strategy.comm_bytes`` per exchange event."""
from __future__ import annotations

from typing import Dict, List

import jax

from repro.configs import CodistConfig, TrainConfig
from repro.data import make_lm_batch
from repro.optim import make_optimizer
from repro.train import (AllReduce, CheckpointExchange, PipelinedPredictions,
                         PredictionExchange, ShardMapCompressed,
                         build_train_step, stack_batches)

from benchmarks.common import lm_setup, timed


def _strategy_rows(model, task, quick: bool) -> List[Dict]:
    """ms/step + comm bytes for every strategy via the unified builder."""
    tc = TrainConfig(lr=1e-3, total_steps=100, optimizer="adamw")
    opt_init, _ = make_optimizer("adamw")
    n, b, s = 2, 8, 64
    batch = stack_batches([make_lm_batch(task, b, s, 0, None, seed=0)
                           for _ in range(n)])
    single = make_lm_batch(task, b, s, 0, None, seed=0)
    pred_cfg = CodistConfig(n_models=n)
    topk_cfg = CodistConfig(n_models=n, compression="topk", topk=16)
    ckpt_cfg = CodistConfig(n_models=n, mode="checkpoints")
    pipe_cfg = CodistConfig(n_models=n, pipelined=True)
    setups = [
        ("allreduce", AllReduce(), single, "on"),
        ("prediction", PredictionExchange(pred_cfg), batch, "on"),
        ("prediction_off", PredictionExchange(pred_cfg), batch, "off"),
        ("prediction_topk", PredictionExchange(topk_cfg), batch, "on"),
        ("checkpoint", CheckpointExchange(ckpt_cfg), batch, "on"),
        ("pipelined", PipelinedPredictions(pipe_cfg), batch, "on"),
    ]
    rows: List[Dict] = []
    if jax.device_count() >= n:
        mesh = jax.make_mesh((n,), ("pod",))
        setups.append(("shardmap", ShardMapCompressed(topk_cfg, mesh), batch,
                       "on"))
    else:
        # no silent skips: the shard_map strategy needs an n-device "pod"
        # axis (jax is already initialized, so host devices can't be forced
        # here); record the row with its comm accounting and zero timing
        st = PredictionExchange(topk_cfg).init_state(
            model, tc, jax.random.key(0), opt_init, batch)
        comm = PredictionExchange(topk_cfg).comm_bytes(model, st, batch)
        rows.append({"name": "throughput/strategy_shardmap",
                     "us_per_call": 0.0,
                     "derived": f"skipped_needs_{n}_devices,"
                                f"comm_bytes={comm:.0f}"})
    for name, strategy, bt, variant in setups:
        # build_train_step falls back to strategy.codist for the schedules
        bundle = build_train_step(model, tc, None, strategy)
        state = strategy.init_state(model, tc, jax.random.key(0), opt_init,
                                    bt)
        comm = strategy.comm_bytes(model, state, bt)
        fn = bundle.jitted(variant)
        _, us = timed(lambda f=fn, st=state, bb=bt: f(st, bb), warmup=1,
                      iters=2 if quick else 5)
        rows.append({"name": f"throughput/strategy_{name}",
                     "us_per_call": us,
                     "derived": f"comm_bytes={comm:.0f}"})
    return rows


def run(quick: bool = False) -> List[Dict]:
    model, task = lm_setup()
    rows = _strategy_rows(model, task, quick)

    # kernels vs jnp references (interpret mode: correctness-path timing only)
    from repro.core import codistillation as cd
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    t, v = (256, 512) if quick else (512, 2048)
    lg = jax.random.normal(jax.random.key(0), (t, v))
    lb = jax.random.randint(jax.random.key(1), (t,), 0, v)
    tgt = jax.random.normal(jax.random.key(2), (t, v))
    _, us_k = timed(lambda: kops.cross_entropy_tokens(lg, lb, interpret=True),
                    iters=2)
    _, us_r = timed(lambda: kref.cross_entropy_ref(lg, lb), iters=2)
    rows.append({"name": "throughput/fused_ce_interp_vs_ref",
                 "us_per_call": us_k, "derived": f"{us_k / us_r:.1f}x_ref"})
    # both paper loss variants: mse (A.3) and kl (Anil-style)
    for mode in ("mse", "kl"):
        _, us_k = timed(lambda m=mode: kops.distill_loss_tokens(
            lg, tgt, mode=m, interpret=True), iters=2)
        ref_fn = kref.distill_mse_ref if mode == "mse" else kref.distill_kl_ref
        _, us_r = timed(lambda f=ref_fn: f(lg, tgt), iters=2)
        rows.append({"name": f"throughput/fused_distill_{mode}_interp_vs_ref",
                     "us_per_call": us_k,
                     "derived": f"{us_k / us_r:.1f}x_ref"})

    # GRADIENT timings: jax.grad through the custom-VJP kernels vs the jnp
    # losses (the training path the fused_losses flag switches)
    grad_pairs = {
        "ce": (
            jax.jit(jax.grad(lambda x: kops.fused_cross_entropy_loss(
                x, lb, 0.1, interpret=True))),
            jax.jit(jax.grad(lambda x: cd.cross_entropy(x, lb, 0.1,
                                                        fused=False))),
        ),
    }
    for mode in ("mse", "kl"):
        ref_loss = cd.distill_mse if mode == "mse" else cd.distill_kl
        grad_pairs[f"distill_{mode}"] = (
            jax.jit(jax.grad(lambda x, m=mode: kops.fused_distill_mean(
                x, tgt, m, interpret=True))),
            jax.jit(jax.grad(lambda x, f=ref_loss: f(x, tgt, fused=False))),
        )
    for name, (fused_g, ref_g) in grad_pairs.items():
        _, us_k = timed(lambda f=fused_g: f(lg), iters=2)
        _, us_r = timed(lambda f=ref_g: f(lg), iters=2)
        rows.append({"name": f"throughput/grad_{name}_fused_vs_jnp",
                     "us_per_call": us_k,
                     "derived": f"{us_k / us_r:.1f}x_ref"})
    return rows
