"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --mode codist --codist-n 2 --steps 200 --batch 8 --seq 128 \
        --reduced --out results/train_run

``--mode`` maps one-to-one onto the engine's exchange strategies:

    allreduce         AllReduce            gradient sync baseline
    codist            PredictionExchange   Algorithm 1 logits exchange
    codist-ckpt       CheckpointExchange   Anil et al. stale replicas
    codist-pipelined  PipelinedPredictions previous-step targets
    codist-shardmap   ShardMapCompressed   explicit compressed pod exchange
    codist-async      AsyncPrediction      virtual cluster on independent
                                           step clocks (repro.runtime) with
                                           seeded fault injection: --faults,
                                           --elastic, --staleness-bound

On this container it runs REDUCED configs on CPU with synthetic data; on a
real cluster the same entrypoint takes the full config (drop ``--reduced``)
and the production mesh, where pjit shards the step exactly as the dry-run
proved. ``codist-shardmap`` shard_maps over a "pod" mesh axis of size
``--codist-n``; on CPU that many host devices are forced (via XLA_FLAGS,
before jax initializes — hence the deferred imports below).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

MODES = ["codist", "codist-ckpt", "codist-pipelined", "codist-shardmap",
         "codist-async", "allreduce"]


def _ensure_pod_devices(argv) -> None:
    """codist-shardmap needs a "pod" mesh axis of size n_models; on hosts
    without that many devices, force host devices BEFORE jax initializes."""
    pre = argparse.ArgumentParser(add_help=False)
    pre.add_argument("--mode", default="codist")
    pre.add_argument("--codist-n", type=int, default=2)
    args, _ = pre.parse_known_args(argv)
    flags = os.environ.get("XLA_FLAGS", "")
    if (args.mode == "codist-shardmap"
            and "xla_force_host_platform_device_count" not in flags):
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={args.codist_n}"
        ).strip()


def main() -> None:
    _ensure_pod_devices(sys.argv[1:])
    import jax

    from repro.configs import (CodistConfig, TrainConfig, get_config,
                               get_reduced, list_archs)
    from repro.data import MarkovLM, make_lm_batch
    from repro.models import build_model
    from repro.train import (ShardMapCompressed, stack_batches,
                             train_allreduce, train_codist)

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--mode", default="codist", choices=MODES)
    ap.add_argument("--codist-n", type=int, default=2)
    ap.add_argument("--period", type=int, default=1)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--alpha-growth", type=float, default=1.0)
    ap.add_argument("--distill-loss", default="mse",
                    choices=["mse", "kl", "ce"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "bf16", "subsample"])
    ap.add_argument("--topk", type=int, default=64)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8, help="per-model batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lr-schedule", default="cosine",
                    choices=["cosine", "step", "constant"])
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--weight-decay", type=float, default=1e-4)
    ap.add_argument("--wd-schedule", action="store_true",
                    help="paper's decayed weight decay")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgdm"])
    ap.add_argument("--fused-losses", default="auto",
                    choices=["auto", "on", "off"],
                    help="custom-VJP Pallas loss kernels (auto: on for TPU; "
                         "'on' uses interpret mode on CPU — slow)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--faults", default="",
                    help="codist-async fault spec, e.g. "
                         "'straggler=1*4@0.2,preempt=1@3+5,fail=1@30,"
                         "hetero=0.3' (see repro.runtime.parse_faults)")
    ap.add_argument("--elastic", type=float, default=0.0,
                    help="codist-async: a fresh peer joins at this simulated "
                         "time (burn-in before it distills)")
    ap.add_argument("--staleness-bound", type=int, default=-1,
                    help="codist-async: drop peer payloads older than S "
                         "local steps (-1 = keep-last, unbounded)")
    ap.add_argument("--join-burn-in", type=int, default=5,
                    help="codist-async: local steps a joining peer trains "
                         "before its distillation loss activates")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="codist-async: snapshot each peer every N local "
                         "steps (enables failure recovery)")
    ap.add_argument("--recover-after", type=float, default=10.0,
                    help="codist-async: simulated seconds before a failed "
                         "peer rejoins from its snapshot")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default="")
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto trace here (codist-async: "
                         "virtual cluster clock; other modes: step clock). "
                         "Bit-identical per seed — see docs/observability.md")
    ap.add_argument("--metrics", default="",
                    help="write the repro.obs metrics registry as JSON here")
    ap.add_argument("--alerts", default="",
                    help="evaluate Watchtower alert rules over the live "
                         "metrics (codist-async: virtual cluster clock; "
                         "other modes: step clock) and write the alert "
                         "JSONL here")
    ap.add_argument("--rules", default="",
                    help="JSON alert-rules file for --alerts (default: the "
                         "built-in rule pack)")
    ap.add_argument("--flight-recorder", default="",
                    help="dump postmortem bundles into this directory on "
                         "every fired alert or injected fault "
                         "(requires --alerts)")
    args = ap.parse_args()

    if args.rules and not args.alerts:
        ap.error("--rules requires --alerts")
    if args.flight_recorder and not args.alerts:
        ap.error("--flight-recorder requires --alerts")
    tracer = metrics = watch = recorder = None
    if args.metrics or args.alerts:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    if args.alerts:
        from repro.obs import Watchtower, default_rules, load_rules
        rules = (load_rules(args.rules) if args.rules else default_rules())
        # the Watchtower rides the same clock as the tracer would: virtual
        # cluster seconds for codist-async, the step clock otherwise
        if args.mode == "codist-async":
            watch = Watchtower(metrics, rules, unit_us=1_000_000.0,
                               clock="sim_s")
        else:
            watch = Watchtower(metrics, rules, unit_us=1000.0,
                               clock="steps")

    def _save_obs():
        if tracer is not None and args.trace:
            tracer.save(args.trace)
            print(f"wrote {args.trace} ({tracer.n_events} trace events)")
        if metrics is not None and args.metrics:
            metrics.save(args.metrics)
            print(f"wrote {args.metrics}")
        if watch is not None:
            watch.save(args.alerts)
            s = watch.summary()
            print(f"wrote {args.alerts} ({s['n_events']} alert events; "
                  f"still firing: {', '.join(s['firing']) or 'none'})")
        if recorder is not None:
            print(f"flight recorder: {len(recorder.dumped)} postmortem "
                  f"bundle(s) in {args.flight_recorder}")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    vocab = min(cfg.vocab_size, 512)
    task = MarkovLM(vocab=vocab, seed=args.seed,
                    effective_vocab=min(vocab, 256))
    tc = TrainConfig(
        lr=args.lr, lr_schedule=args.lr_schedule, warmup_steps=args.warmup,
        total_steps=args.steps, weight_decay=args.weight_decay,
        weight_decay_schedule=(5e-4, 1e-5, 0.0) if args.wd_schedule else (),
        optimizer=args.optimizer, seed=args.seed,
        fused_losses={"auto": None, "on": True, "off": False}[
            args.fused_losses])

    def eval_batches(step):
        if args.mode == "allreduce":
            return make_lm_batch(task, args.batch, args.seq, 10_000 + step,
                                 None, seed=args.seed + 1)
        return stack_batches([
            make_lm_batch(task, args.batch, args.seq, 10_000 + step, None,
                          seed=args.seed + 1)
            for _ in range(args.codist_n)])

    if args.mode == "codist-async":
        from dataclasses import replace as _replace

        from repro.runtime import AsyncScheduler, parse_faults

        faults = parse_faults(args.faults, args.codist_n, seed=args.seed)
        if args.elastic > 0:
            faults = _replace(faults,
                              joins=((faults.n_peers, args.elastic),))
        codist = CodistConfig(
            n_models=args.codist_n, mode="predictions", period=args.period,
            alpha0=args.alpha, alpha_growth=args.alpha_growth,
            distill_loss=args.distill_loss, compression=args.compression,
            topk=args.topk, steps_per_epoch=max(1, args.steps // 10))

        def async_batches(step):
            return make_lm_batch(task, args.batch, args.seq, step, None,
                                 seed=args.seed)

        ckpt_dir = None
        if args.checkpoint_every:
            ckpt_dir = os.path.join(args.out or ".", "runtime_ckpt")
        if args.trace or args.flight_recorder:
            from repro.obs import for_sim_seconds
            tracer = for_sim_seconds()
        if args.flight_recorder:
            from repro.obs import FlightRecorder
            recorder = FlightRecorder(args.flight_recorder, metrics=metrics)
            tracer.recorder = recorder
            watch.on_alert(recorder.on_alert)
            watch.on_fault(recorder.on_fault)
        t0 = time.time()
        report = AsyncScheduler(
            model, tc, codist, async_batches, faults,
            staleness_bound=(None if args.staleness_bound < 0
                             else args.staleness_bound),
            checkpoint_dir=ckpt_dir, checkpoint_every=args.checkpoint_every,
            recover_after=(args.recover_after if args.checkpoint_every
                           else None),
            join_burn_in=args.join_burn_in, log_every=args.log_every,
            tracer=tracer, metrics=metrics, watch=watch).run()
        dt = time.time() - t0
        for pid in sorted(report.histories):
            for rec in report.histories[pid].records:
                msg = " ".join(
                    f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in rec.items()
                    if k in ("peer", "step", "task_loss", "distill_loss",
                             "staleness", "alpha", "sim_time"))
                print(msg, flush=True)
        print(f"sim_time={report.sim_time:.2f} "
              f"time_to_first={report.time_to_first:.2f} "
              f"comm_events={report.comm_events} "
              f"comm_bytes={report.comm_bytes:.0f} "
              f"staleness_mean={report.staleness['staleness_mean']:.3f} "
              f"dropped={report.staleness['payloads_dropped']}")
        print(f"done: {args.steps} steps x {faults.n_total} peers "
              f"in {dt:.1f}s (simulated {report.sim_time:.1f}s)")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            report.save_histories(args.out)
            from repro.checkpoint import save_pytree
            for pid, st in report.states.items():
                save_pytree(os.path.join(args.out, f"final_peer{pid}"),
                            st.params)
            print(f"wrote per-peer JSONL histories + checkpoints to "
                  f"{args.out}")
        _save_obs()
        return

    if args.trace or args.flight_recorder:
        from repro.obs import for_steps
        tracer = for_steps()
    if args.flight_recorder:
        from repro.obs import FlightRecorder
        recorder = FlightRecorder(args.flight_recorder, metrics=metrics)
        tracer.recorder = recorder
        watch.on_alert(recorder.on_alert)
        watch.on_fault(recorder.on_fault)
    t0 = time.time()
    if args.mode == "allreduce":
        def it():
            s = 0
            while True:
                yield make_lm_batch(task, args.batch, args.seq, s, None,
                                    seed=args.seed)
                s += 1
        state, hist = train_allreduce(model, tc, it(),
                                      eval_batches=eval_batches,
                                      eval_every=args.eval_every,
                                      log_every=args.log_every,
                                      tracer=tracer, metrics=metrics,
                                      watch=watch)
    else:
        codist = CodistConfig(
            n_models=args.codist_n,
            mode="checkpoints" if args.mode == "codist-ckpt" else "predictions",
            pipelined=args.mode == "codist-pipelined",
            period=args.period, alpha0=args.alpha,
            alpha_growth=args.alpha_growth, distill_loss=args.distill_loss,
            compression=args.compression, topk=args.topk,
            steps_per_epoch=max(1, args.steps // 10))
        strategy = None
        if args.mode == "codist-shardmap":
            if jax.device_count() < args.codist_n:
                raise SystemExit(
                    f"codist-shardmap needs >= {args.codist_n} devices for "
                    f"the 'pod' axis; have {jax.device_count()}")
            mesh = jax.make_mesh((args.codist_n,), ("pod",))
            strategy = ShardMapCompressed(codist, mesh)
        coordinated = codist.mode == "predictions"

        def batches(step):
            return stack_batches([
                make_lm_batch(task, args.batch, args.seq, step,
                              None if coordinated else g, seed=args.seed)
                for g in range(args.codist_n)])

        state, hist = train_codist(model, codist, tc, batches,
                                   eval_batches=eval_batches,
                                   eval_every=args.eval_every,
                                   log_every=args.log_every,
                                   strategy=strategy,
                                   tracer=tracer, metrics=metrics,
                                   watch=watch)
    dt = time.time() - t0

    for rec in hist.records:
        msg = " ".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in rec.items()
                       if k in ("step", "task_loss", "distill_loss",
                                "eval_loss", "lr", "wd", "alpha",
                                "comm_bytes"))
        print(msg, flush=True)
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({dt / args.steps * 1e3:.0f} ms/step)")
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        with open(os.path.join(args.out, "history.json"), "w") as f:
            json.dump(hist.records, f, indent=1)
        from repro.checkpoint import save_pytree
        save_pytree(os.path.join(args.out, "final"), state.params)
        print(f"wrote {args.out}/history.json and final checkpoint")
    _save_obs()


if __name__ == "__main__":
    main()
