"""Flight recorder: a bounded ring of recent trace events + postmortem
bundle dumps.

A chaos run that trips an alert deep into a sweep is useless to debug
from a 50k-event trace — what you want is *what the system was doing
right then*. The ``FlightRecorder`` keeps the last ``capacity`` trace
events in a ``deque`` ring (attached to a ``Tracer`` via its
``recorder`` hook, so it sees events as they are recorded, even while
spans are still open), and on an alert or injected fault dumps a
**postmortem bundle**: the ring contents, a full metric snapshot, the
triggering alert/fault context, and whatever run context the host wires
in (live request ids, peer states).

Determinism contract: bundles are pure functions of the simulated event
stream (canonical ordering + serialization), so two seeded runs dump
byte-identical bundles — they are CI-gated alongside the alert log. The
recorder only *reads* (the ring is a copy of events the tracer records
anyway; the metric snapshot is ``to_dict``), so enabling it perturbs
nothing.
"""
from __future__ import annotations

import json
import os
import re
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.obs.fsio import atomic_write_text
from repro.obs.metrics import MetricsRegistry

POSTMORTEM_SCHEMA_VERSION = 1

_SLUG_RE = re.compile(r"[^A-Za-z0-9_-]+")


def _slug(text: str) -> str:
    return _SLUG_RE.sub("-", text).strip("-") or "event"


class FlightRecorder:
    """Bounded ring of ``(ts, seq, event)`` plus postmortem dumping.

    ``capacity`` bounds the ring (oldest events fall off — enforced by
    ``tests/test_watch.py``); ``max_dumps`` bounds how many bundles one
    run may write, so a pathological alert storm cannot fill a disk.
    ``context_fn`` is an optional zero-arg callable returning a
    JSON-serializable dict of live run state (offending request/peer
    ids) captured at dump time.
    """

    def __init__(self, out_dir: str, capacity: int = 256,
                 max_dumps: int = 8,
                 metrics: Optional[MetricsRegistry] = None,
                 context_fn: Optional[Callable[[], Dict[str, Any]]] = None):
        if capacity <= 0:
            raise ValueError(f"flight recorder capacity {capacity} must be "
                             "positive")
        if max_dumps <= 0:
            raise ValueError(f"flight recorder max_dumps {max_dumps} must "
                             "be positive")
        self.out_dir = out_dir
        self.capacity = capacity
        self.max_dumps = max_dumps
        self.metrics = metrics
        self.context_fn = context_fn
        self._ring: Deque[Tuple[int, int, Dict[str, Any]]] = deque(
            maxlen=capacity)
        self.n_offered = 0
        self.dumped: List[str] = []

    # ---- tracer hook -------------------------------------------------------
    def offer(self, ts: int, seq: int, ev: Dict[str, Any]) -> None:
        """Called by ``Tracer._push`` for every recorded event."""
        self._ring.append((int(ts), int(seq), ev))
        self.n_offered += 1

    def events(self) -> List[Dict[str, Any]]:
        """Ring contents in canonical (ts, seq) order."""
        return [ev for _, _, ev in sorted(self._ring,
                                          key=lambda e: (e[0], e[1]))]

    # ---- watchtower hooks --------------------------------------------------
    def on_alert(self, alert: Dict[str, Any]) -> Optional[str]:
        """Watchtower ``on_alert`` callback: dump on newly-firing alerts
        (resolutions are logged, not dumped — the interesting state is at
        fire time)."""
        if alert.get("state") != "firing":
            return None
        return self.dump(f"alert-{alert['rule']}", alert["ts"], alert=alert)

    def on_fault(self, fault: Dict[str, Any]) -> Optional[str]:
        """Watchtower ``on_fault`` callback: dump on injected faults."""
        return self.dump(f"fault-{fault['kind']}", fault["ts"],
                         alert=None, extra=fault.get("context"))

    # ---- bundles -----------------------------------------------------------
    def bundle(self, reason: str, ts: int,
               alert: Optional[Dict[str, Any]] = None,
               extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        context: Dict[str, Any] = dict(extra or {})
        if self.context_fn is not None:
            context.update(self.context_fn())
        return {
            "schema_version": POSTMORTEM_SCHEMA_VERSION,
            "kind": "postmortem",
            "reason": reason,
            "ts": int(ts),
            "alert": alert,
            "context": context,
            "events": self.events(),
            "n_events_seen": self.n_offered,
            "metrics": (self.metrics.to_dict()
                        if self.metrics is not None else None),
        }

    def dump(self, reason: str, ts: int,
             alert: Optional[Dict[str, Any]] = None,
             extra: Optional[Dict[str, Any]] = None) -> Optional[str]:
        """Write a postmortem bundle; returns its path, or None once the
        ``max_dumps`` budget is spent."""
        if len(self.dumped) >= self.max_dumps:
            return None
        name = (f"postmortem_{len(self.dumped):03d}_"
                f"{_slug(reason)}.json")
        path = os.path.join(self.out_dir, name)
        doc = self.bundle(reason, ts, alert=alert, extra=extra)
        atomic_write_text(path, json.dumps(
            doc, sort_keys=True, separators=(",", ":")) + "\n")
        self.dumped.append(path)
        return path
