"""Pytree checkpointing: npz payload + json treedef.

Flat key encoding uses jax.tree_util key-paths, so any nested dict/tuple/
NamedTuple state (TrainState, CodistState, OptState) round-trips. Used by the
examples/launchers and by checkpoint-exchange experiments that restart from a
published replica.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: PyTree, meta: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    np.savez(path + ".npz", **{f"leaf_{i}": np.asarray(x)
                               for i, x in enumerate(leaves)})
    doc = {"treedef": str(treedef), "n_leaves": len(leaves)}
    if meta:
        doc["meta"] = meta
    with open(path + ".tree.json", "w") as f:
        json.dump(doc, f)


def read_meta(path: str) -> Optional[dict]:
    """The ``meta`` dict saved alongside a pytree (None if absent)."""
    try:
        with open(path + ".tree.json") as f:
            return json.load(f).get("meta")
    except (OSError, json.JSONDecodeError):
        return None


def snapshot_path(directory: str, peer: int) -> str:
    """Keep-latest snapshot slot for one async-runtime peer."""
    return os.path.join(directory, f"peer{peer}")


def save_snapshot(directory: str, peer: int, state: PyTree,
                  meta: Optional[dict] = None) -> None:
    """Overwrite peer's latest snapshot (the async runtime's recovery point:
    a failed peer rejoins from here instead of a fresh init). ``meta``
    (e.g. ``{"step": n}``) lets consumers — the serving fleet's weight
    refresh — order snapshots without loading payloads."""
    save_pytree(snapshot_path(directory, peer), state, meta)


def snapshot_meta(directory: str, peer: int) -> Optional[dict]:
    return read_meta(snapshot_path(directory, peer))


def has_snapshot(directory: str, peer: int) -> bool:
    return os.path.exists(snapshot_path(directory, peer) + ".npz")


def load_snapshot_params(directory: str, peer: int,
                         params_like: PyTree) -> PyTree:
    """Restore ONLY the params of a saved peer state.

    ``TrainState``/``CodistState`` are NamedTuples with ``params`` first, so
    the params leaves are the LEADING leaves of the flattened snapshot —
    serving-side consumers restore them against a params-only template
    without knowing the optimizer state's structure.
    """
    data = np.load(snapshot_path(directory, peer) + ".npz")
    like_leaves, treedef = _flatten(params_like)
    assert len(data.files) >= len(like_leaves), \
        (len(data.files), len(like_leaves), "snapshot smaller than params")
    import jax.numpy as jnp
    restored = [jnp.asarray(data[f"leaf_{i}"], dtype=l.dtype)
                for i, l in enumerate(like_leaves)]
    for got, want in zip(restored, like_leaves):
        assert got.shape == want.shape, \
            (got.shape, want.shape, "snapshot params/template mismatch")
    return jax.tree_util.tree_unflatten(treedef, restored)


def load_snapshot(directory: str, peer: int, like: PyTree) -> PyTree:
    return load_pytree(snapshot_path(directory, peer), like)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path + ".npz")
    leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    like_leaves, treedef = _flatten(like)
    assert len(leaves) == len(like_leaves), "checkpoint/template mismatch"
    import jax.numpy as jnp
    restored = [jnp.asarray(x, dtype=l.dtype) for x, l in zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored)
