"""Distributed correctness on 8 forced host devices (subprocess — the main
test process must keep its single-device view).

Verifies the production sharding path end-to-end at CI scale:
  * the pjit codistillation step on a (2,2,2) pod/data/model mesh produces
    numerically identical results to the single-device stacked step;
  * cross-pod collective bytes appear for codist (logits) and baseline
    (gradients), with codist << baseline for a small-vocab model.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> dict:
    env = dict(os.environ,
               PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=520)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


PREAMBLE = """
import json
import jax, jax.numpy as jnp
from dataclasses import replace
from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.models import build_model
from repro.data import MarkovLM, make_lm_batch
from repro.train import stack_batches, init_codist_state
from repro.train.engine import (AllReduce, PredictionExchange,
                                build_train_step)
from repro.optim import make_optimizer
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.launch import sharding as sh

cfg = replace(get_reduced('qwen1.5-0.5b'), num_layers=2, d_model=64,
              d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=2,
              head_dim=32)
model = build_model(cfg)
task = MarkovLM(vocab=64, seed=0)
tc = TrainConfig(lr=1e-2, total_steps=10, warmup_steps=0, optimizer='sgdm')
codist = CodistConfig(n_models=2)
opt_init, _ = make_optimizer('sgdm')
state = init_codist_state(model, jax.random.key(0), 2, opt_init)
batch = stack_batches([make_lm_batch(task, 4, 16, 0, None, seed=0)
                       for _ in range(2)])
step = build_train_step(model, tc, codist,
                        PredictionExchange(codist)).variants['on']
"""


def test_sharded_codist_step_matches_single_device():
    code = PREAMBLE + """
# single-device reference
ref_state, ref_metrics = jax.jit(step)(state, batch)
ref_loss = float(ref_metrics['loss'])
ref_leaf = jax.tree.leaves(ref_state.params)[0]

# sharded on the (2,2,2) pod/data/model mesh
mesh = make_host_mesh()
state_sds = jax.eval_shape(lambda: state)
state_sh = sh.state_shardings(state_sds, mesh, stacked=True)
batch_sh = sh.batch_shardings(jax.eval_shape(lambda: batch), mesh,
                              stacked=True)
state_p = jax.device_put(state, state_sh)
batch_p = jax.device_put(batch, batch_sh)
with set_mesh(mesh):
    out_state, out_metrics = jax.jit(
        step, in_shardings=(state_sh, batch_sh))(state_p, batch_p)
loss = float(out_metrics['loss'])
leaf = jax.tree.leaves(out_state.params)[0]
err = float(jnp.abs(jnp.asarray(leaf) - jnp.asarray(ref_leaf)).max())
print('RESULT ' + json.dumps({'ref_loss': ref_loss, 'loss': loss,
                              'param_err': err,
                              'ndev': jax.device_count()}))
"""
    r = run_sub(code)
    assert r["ndev"] == 8
    assert abs(r["loss"] - r["ref_loss"]) < 1e-4
    assert r["param_err"] < 1e-4


def test_cross_pod_traffic_codist_vs_allreduce():
    code = PREAMBLE + """
from repro.launch.hlo_analysis import parse_collectives
from repro.train.state import TrainState
mesh = make_host_mesh()
state_sds = jax.eval_shape(lambda: state)
state_sh = sh.state_shardings(state_sds, mesh, stacked=True)
batch_sds = jax.eval_shape(lambda: batch)
batch_sh = sh.batch_shardings(batch_sds, mesh, stacked=True)
with set_mesh(mesh):
    comp_c = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(
        state_sds, batch_sds).compile()
coll_c = parse_collectives(comp_c.as_text(), devices_per_pod=4)

# baseline: single model, batch over (pod, data)
from repro.train import init_train_state
ar_state = init_train_state(model, jax.random.key(0), opt_init)
ar_batch = make_lm_batch(task, 8, 16, 0, None, seed=0)
ar_step = build_train_step(model, tc, None, AllReduce()).variants['on']
ar_state_sds = jax.eval_shape(lambda: ar_state)
ar_state_sh = sh.state_shardings(ar_state_sds, mesh)
ar_batch_sh = sh.batch_shardings(jax.eval_shape(lambda: ar_batch), mesh)
with set_mesh(mesh):
    comp_a = jax.jit(ar_step, in_shardings=(ar_state_sh, ar_batch_sh)).lower(
        ar_state_sds, jax.eval_shape(lambda: ar_batch)).compile()
coll_a = parse_collectives(comp_a.as_text(), devices_per_pod=4)
print('RESULT ' + json.dumps({
    'codist_cross': coll_c.cross_pod_bytes,
    'allreduce_cross': coll_a.cross_pod_bytes}))
"""
    r = run_sub(code)
    # both communicate cross-pod; the baseline syncs gradients across pods
    assert r["allreduce_cross"] > 0
    assert r["codist_cross"] > 0


def test_dryrun_runner_smoke():
    """launch.dryrun's run_one works end-to-end on a reduced config and a
    small mesh (patched via the module's own helpers)."""
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import jax
from dataclasses import replace
import repro.launch.dryrun as dr
import repro.launch.mesh as mesh_mod

# shrink the production mesh + arch for CI
orig = mesh_mod.make_production_mesh
def small_mesh(*, multi_pod=False):
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod \
        else jax.make_mesh((4, 2), ("data", "model"))
dr.make_production_mesh = small_mesh
orig_cfg = dr.dryrun_config
from repro.configs import get_reduced
def small_cfg(arch):
    return replace(get_reduced(arch), dtype='bfloat16',
                   param_dtype='bfloat16')
dr.dryrun_config = small_cfg
from repro.configs.base import INPUT_SHAPES, InputShape
INPUT_SHAPES['train_4k'] = InputShape('train_4k', 64, 8, 'train')
INPUT_SHAPES['decode_32k'] = InputShape('decode_32k', 64, 8, 'decode')
rec1 = dr.run_one('qwen2-7b', 'train_4k', multi_pod=False, verbose=False)
rec2 = dr.run_one('qwen2-7b', 'decode_32k', multi_pod=False, verbose=False)
rec3 = dr.run_one('jamba-v0.1-52b', 'train_4k', multi_pod=True,
                  mode='codist', verbose=False)
print('RESULT ' + json.dumps({
    's1': rec1['status'], 's2': rec2['status'], 's3': rec3['status'],
    'cross3': rec3['collectives']['cross_pod_bytes']}))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=520)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    r = json.loads(line[len("RESULT "):])
    assert r["s1"] == "ok" and r["s2"] == "ok" and r["s3"] == "ok"
    assert r["cross3"] > 0  # codist logits exchange crosses pods
