"""Roofline table generator: reads the dry-run JSONs and emits the
EXPERIMENTS.md §Roofline markdown plus summary CSV rows."""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.launch.roofline import format_table


def load(dryrun_dir: str = "results/dryrun") -> List[Dict]:
    recs = []
    for fname in sorted(os.listdir(dryrun_dir)) if os.path.isdir(dryrun_dir) else []:
        if fname.endswith(".json"):
            for r in json.load(open(os.path.join(dryrun_dir, fname))):
                if r.get("status") == "ok" and "roofline" in r:
                    recs.append(r)
    return recs


def markdown(dryrun_dir: str = "results/dryrun") -> str:
    recs = load(dryrun_dir)
    return format_table([r["roofline"] for r in recs])


def run(quick: bool = False) -> List[Dict]:
    recs = load()
    rows: List[Dict] = []
    ok = [r for r in recs]
    rows.append({"name": "roofline/combos_ok", "derived": len(ok)})
    by_bn: Dict[str, int] = {}
    for r in ok:
        bn = r["roofline"]["bottleneck"]
        by_bn[bn] = by_bn.get(bn, 0) + 1
    for bn, c in sorted(by_bn.items()):
        rows.append({"name": f"roofline/bottleneck_{bn}", "derived": c})
    for r in ok:
        rr = r["roofline"]
        rows.append({
            "name": f"roofline/{rr['arch']}_{rr['shape']}_{rr['mesh']}_{r.get('mode','')}",
            "derived": (f"compute={rr['compute_s']:.3e};mem={rr['memory_s']:.3e};"
                        f"coll={rr['collective_s']:.3e};bn={rr['bottleneck']};"
                        f"useful={rr['useful_ratio']:.3f}")})
    return rows
