"""Core codistillation semantics (Algorithm 1) and exchange strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CodistConfig
from repro.core import codistillation as cd
from repro.core import schedules as sched
from repro.core.exchange import StepPlan


def _logits(key, n=2, b=4, s=8, v=32):
    return jax.random.normal(jax.random.key(key), (n, b, s, v))


def _labels(key, n=2, b=4, s=8, v=32):
    return jax.random.randint(jax.random.key(key), (n, b, s), 0, v)


class TestDistillLosses:
    def test_mse_matches_manual(self):
        a = jax.random.normal(jax.random.key(0), (4, 8, 16))
        b = jax.random.normal(jax.random.key(1), (4, 8, 16))
        got = cd.distill_mse(a, b)
        want = jnp.mean((a - b) ** 2)
        assert jnp.allclose(got, want, atol=1e-6)

    def test_zero_at_equality(self):
        a = jax.random.normal(jax.random.key(0), (4, 8, 16))
        for kind in ("mse", "kl"):
            assert float(cd.distill_pair(kind, a, a)) == pytest.approx(
                0.0, abs=1e-5)

    def test_kl_nonnegative(self):
        a = _logits(0)[0]
        b = _logits(1)[0]
        assert float(cd.distill_kl(a, b)) >= 0.0

    def test_mask_excludes_tokens(self):
        a = jax.random.normal(jax.random.key(0), (2, 4, 8))
        b = a.at[:, 2:].add(100.0)  # only masked-out positions differ
        mask = jnp.array([[1, 1, 0, 0], [1, 1, 0, 0]], jnp.float32)
        assert float(cd.distill_mse(a, b, mask)) == pytest.approx(0.0)


class TestCodistLoss:
    def test_alpha_zero_is_independent_training(self):
        cfg = CodistConfig(n_models=2)
        lg, lb = _logits(0), _labels(1)
        total, m = cd.codist_loss(cfg, lg, lb, alpha=0.0)
        want = jnp.mean(jnp.stack([
            cd.cross_entropy(lg[0], lb[0]), cd.cross_entropy(lg[1], lb[1])]))
        assert jnp.allclose(total, want, atol=1e-6)

    def test_alpha_linearity(self):
        cfg = CodistConfig(n_models=2)
        lg, lb = _logits(0), _labels(1)
        t0, m0 = cd.codist_loss(cfg, lg, lb, alpha=0.0)
        t1, m1 = cd.codist_loss(cfg, lg, lb, alpha=1.0)
        t2, m2 = cd.codist_loss(cfg, lg, lb, alpha=2.0)
        assert jnp.allclose(t2 - t0, 2 * (t1 - t0), atol=1e-5)

    def test_gradient_matches_algorithm1(self):
        """grad wrt model i only flows through its own logits (stop_gradient
        on targets): d/dlg_i [CE_i + alpha*mean_j MSE(lg_i, sg(lg_j))]."""
        cfg = CodistConfig(n_models=2, distill_loss="mse")
        lg, lb = _logits(0), _labels(1)
        alpha = 0.7

        def total(l):
            return cd.codist_loss(cfg, l, lb, alpha)[0]

        g = jax.grad(total)(lg)

        def manual_i(l_i, l_j, lb_i):
            return (cd.cross_entropy(l_i, lb_i)
                    + alpha * cd.distill_mse(l_i, jax.lax.stop_gradient(l_j)))

        g0 = jax.grad(lambda l: manual_i(l, lg[1], lb[0]) / 2)(lg[0])
        np.testing.assert_allclose(np.asarray(g[0]), np.asarray(g0),
                                   rtol=1e-4, atol=1e-6)

    def test_n_way_pairwise_targets(self):
        """Checkpoint-mode pairwise targets [i, j] are honored."""
        cfg = CodistConfig(n_models=3)
        lg = _logits(0, n=3)
        lb = _labels(1, n=3)
        pw = jax.random.normal(jax.random.key(2), (3, 3, 4, 8, 32))
        total, m = cd.codist_loss(cfg, lg, lb, 1.0, peer_pairwise=pw)
        d0 = (cd.distill_mse(lg[0], pw[0, 1]) + cd.distill_mse(lg[0], pw[0, 2])) / 2
        assert jnp.allclose(m["distill_loss_per_model"][0], d0, atol=1e-5)

    def test_compressed_topk_targets(self):
        cfg = CodistConfig(n_models=2, compression="topk", topk=8)
        lg, lb = _logits(0, v=64), _labels(1, v=64)
        total, m = cd.codist_loss(cfg, lg, lb, 1.0)
        assert bool(jnp.isfinite(total))
        # exact-equality logits => zero distill loss even compressed
        same = jnp.stack([lg[0], lg[0]])
        _, m2 = cd.codist_loss(cfg, same, lb, 1.0)
        assert float(m2["distill_loss"]) == pytest.approx(0.0, abs=1e-6)

    def test_subsample_compression(self):
        cfg = CodistConfig(n_models=2, compression="subsample", subsample=4)
        lg, lb = _logits(0), _labels(1)
        total, _ = cd.codist_loss(cfg, lg, lb, 1.0)
        assert bool(jnp.isfinite(total))


class TestCrossEntropy:
    def test_matches_onehot_definition(self):
        v = 16
        lg = jax.random.normal(jax.random.key(0), (4, 6, v))
        lb = jax.random.randint(jax.random.key(1), (4, 6), 0, v)
        got = cd.cross_entropy(lg, lb)
        p = jax.nn.log_softmax(lg, -1)
        want = -jnp.mean(jnp.take_along_axis(p, lb[..., None], -1))
        assert jnp.allclose(got, want, atol=1e-5)

    def test_label_smoothing_increases_loss_at_confidence(self):
        v = 8
        lb = jnp.zeros((2, 4), jnp.int32)
        lg = jax.nn.one_hot(lb, v) * 20.0
        l0 = cd.cross_entropy(lg, lb, 0.0)
        l1 = cd.cross_entropy(lg, lb, 0.1)
        assert float(l1) > float(l0)


class TestSchedules:
    def test_wd_schedule_paper_values(self):
        """5e-4 -> 1e-5 -> 0 at the LR milestones (Section 4.1)."""
        total = 100
        wd = lambda s: float(sched.scheduled_weight_decay(
            s, total, (5e-4, 1e-5, 0.0), (0.5, 0.75)))
        assert wd(0) == pytest.approx(5e-4)
        assert wd(49) == pytest.approx(5e-4)
        assert wd(50) == pytest.approx(1e-5)
        assert wd(75) == pytest.approx(0.0)

    def test_alpha_growth_nmt(self):
        """alpha grows 1.1x per epoch (A.3)."""
        a = lambda s: float(sched.alpha_schedule(s, 1.0, 1.1, steps_per_epoch=10))
        assert a(0) == pytest.approx(1.0)
        assert a(10) == pytest.approx(1.1)
        assert a(25) == pytest.approx(1.1 ** 2)

    def test_alpha_burn_in(self):
        a = sched.alpha_schedule(jnp.arange(10), 1.0, 1.0, 1, burn_in_steps=5)
        assert float(a[4]) == 0.0 and float(a[5]) == 1.0

    def test_stepwise_lr(self):
        lr = lambda s: float(sched.stepwise_lr(s, 1.0, 100, (0.5, 0.75), 0.1))
        assert lr(10) == pytest.approx(1.0)
        assert lr(60) == pytest.approx(0.1)
        assert lr(80) == pytest.approx(0.01)

    def test_linear_scaling_rule(self):
        assert sched.linear_scaled_lr(0.1, 512) == pytest.approx(0.2)

    def test_label_smoothing_decays_to_zero(self):
        ls = sched.decayed_label_smoothing(jnp.array([0, 100]), 100, 0.1)
        assert float(ls[0]) == pytest.approx(0.1)
        assert float(ls[1]) == pytest.approx(0.0)


class TestStepPlan:
    def test_predictions_period(self):
        cfg = CodistConfig(n_models=2, mode="predictions", period=5)
        plans = [StepPlan.for_step(cfg, k) for k in range(10)]
        assert [p.distill for p in plans] == [True, False, False, False, False] * 2
        assert [p.exchange for p in plans] == [p.distill for p in plans]

    def test_checkpoints_distill_every_step(self):
        cfg = CodistConfig(n_models=2, mode="checkpoints", period=5)
        plans = [StepPlan.for_step(cfg, k) for k in range(10)]
        assert all(p.distill for p in plans)
        assert sum(p.exchange for p in plans) == 2

    def test_burn_in(self):
        cfg = CodistConfig(n_models=2, burn_in_steps=3)
        assert not StepPlan.for_step(cfg, 2).distill
        assert StepPlan.for_step(cfg, 3).distill

    def test_single_model_never_distills(self):
        cfg = CodistConfig(n_models=1)
        assert not StepPlan.for_step(cfg, 0).distill


def test_param_distance():
    p0 = {"a": jnp.zeros((3,)), "b": jnp.zeros((2,))}
    p1 = {"a": jnp.ones((3,)) * 2, "b": jnp.zeros((2,))}
    assert float(cd.param_distance_from(p1, p0)) == pytest.approx(
        np.sqrt(12.0))


def test_init_stacked_models_differ():
    def init(key):
        return {"w": jax.random.normal(key, (4, 4))}
    stacked = cd.init_stacked(init, jax.random.key(0), 3)
    assert stacked["w"].shape == (3, 4, 4)
    assert not jnp.allclose(stacked["w"][0], stacked["w"][1])
