"""Peer-aware routing across N codistilled replicas + the fleet driver.

Codistillation's deployment story (Anil et al. 2018; PAPER.md Section 6.6)
is that training yields N independently-serveable, equally-good models. The
router turns that into capacity and safety:

  * ``round_robin``   — cyclic assignment (equal-quality peers need no
                        affinity);
  * ``least_loaded``  — assign to the peer with the fewest queued+live
                        requests at arrival (ties -> lowest peer id);
  * ``ensemble``      — every request runs on ALL peers; the rotating
                        primary answers the client, the shadows feed the
                        agreement signal (the expensive, fully-covered
                        variant of the canary).

Because the peers trained against each other's predictions, their logits
agree far more than independently-trained models' — so DISAGREEMENT is a
cheap health signal. Every ``canary_every``-th request is duplicated to the
next peer and the pair's prefill logits are compared with
``distill_pair("mse", ...)`` (the training-side agreement metric, reused
verbatim): a peer whose canary divergence spikes has drifted (bad refresh,
corrupt weights) and is flagged, mirroring how codistillation monitors
peer agreement during training.

Weight refresh mirrors the async runtime mailbox's keep-last policy
(docs/runtime.md): ``checkpoint/io.py`` snapshots are polled every
``refresh_every_ms`` of simulated time; only a snapshot STRICTLY NEWER than
the peer's current weights is adopted (keep-last — never roll back), and a
snapshot more than ``staleness_bound`` steps behind the newest available is
dropped rather than adopted, exactly the mailbox's drop-vs-keep decision.
Refreshes happen at tick boundaries (serving never blocks on a load).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import (load_snapshot_params, snapshot_meta)
from repro.core.codistillation import distill_pair
from repro.models.common import count_params
from repro.serve.fleet.batcher import FleetConfig, FleetEngine, RequestRecord
from repro.serve.fleet.workload import Workload

PyTree = Any

POLICIES = ("round_robin", "least_loaded", "ensemble")


@dataclass
class CanaryStats:
    count: int = 0
    mse_sum: float = 0.0
    mse_max: float = 0.0
    token_agree: int = 0
    token_total: int = 0

    def observe(self, primary: RequestRecord, shadow: RequestRecord) -> None:
        if primary.prefill_logits is None or shadow.prefill_logits is None:
            return
        a = jnp.asarray(primary.prefill_logits)[None, :]
        b = jnp.asarray(shadow.prefill_logits)[None, :]
        mse = float(distill_pair("mse", a, b))
        self.count += 1
        self.mse_sum += mse
        self.mse_max = max(self.mse_max, mse)
        n = min(len(primary.tokens), len(shadow.tokens))
        self.token_total += n
        self.token_agree += sum(1 for x, y in zip(primary.tokens[:n],
                                                  shadow.tokens[:n]) if x == y)

    def summary(self) -> Dict:
        return {
            "count": self.count,
            "mean_mse": self.mse_sum / self.count if self.count else 0.0,
            "max_mse": self.mse_max,
            "token_agreement": (self.token_agree / self.token_total
                                if self.token_total else 1.0),
        }


@dataclass
class FleetReport:
    """SLO + accounting summary of one fleet run (all times simulated ms)."""
    scenario: str
    router: str
    peers: int
    seed: int
    completed: int
    rejected: int
    p50_ttft_ms: float
    p99_ttft_ms: float
    p50_e2e_ms: float
    p99_e2e_ms: float
    slo_ms: float
    slo_attainment: float            # fraction with TTFT <= slo_ms
    sim_tokens_per_s: float
    generated_tokens: int
    kv_bytes_written: int
    refresh_bytes: int
    refreshes: int
    refreshes_dropped_stale: int
    peak_pool_utilization: float
    canary: Dict = field(default_factory=dict)
    stream_digest: str = ""          # sha256 over client token streams

    def to_json(self) -> str:
        return json.dumps(self.__dict__, indent=1, sort_keys=True)


def _pct(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs), q))


class FleetRouter:
    def __init__(self, model, peer_params: List[PyTree],
                 config: Optional[FleetConfig] = None,
                 policy: str = "round_robin",
                 cache_dtype=jnp.float32,
                 canary_every: int = 0,
                 snapshot_dir: Optional[str] = None,
                 refresh_every_ms: float = 0.0,
                 staleness_bound: int = 0):
        assert policy in POLICIES, (policy, POLICIES)
        assert len(peer_params) >= 1
        self.policy = policy
        self.config = config or FleetConfig()
        self.engines = [FleetEngine(model, p, self.config,
                                    cache_dtype=cache_dtype,
                                    keep_logits=(policy == "ensemble"))
                        for p in peer_params]
        self.canary_every = canary_every
        self.snapshot_dir = snapshot_dir
        self.refresh_every_ms = refresh_every_ms
        self.staleness_bound = staleness_bound
        self._next_refresh_ms = refresh_every_ms
        self._rr = 0
        self._since_canary = 0
        self._param_bytes = sum(
            count_params(p) * 4 for p in peer_params) // len(peer_params)
        self.refresh_bytes = 0
        self.refreshes = 0
        self.refreshes_dropped_stale = 0
        self.canary_stats = CanaryStats()
        # (primary record, shadow record) pairs compared after the run
        self._pairs: List[tuple] = []
        self._primaries: List[RequestRecord] = []

    # ---- routing -----------------------------------------------------------
    def _pick(self) -> int:
        if self.policy == "least_loaded":
            loads = [e.load for e in self.engines]
            return int(np.argmin(loads))     # ties -> lowest peer id
        peer = self._rr % len(self.engines)
        self._rr += 1
        return peer

    def _route(self, request) -> None:
        n = len(self.engines)
        if self.policy == "ensemble":
            primary = self._rr % n
            self._rr += 1
            prec = self.engines[primary].enqueue(request)
            self._primaries.append(prec)
            for off in range(1, n):
                srec = self.engines[(primary + off) % n].enqueue(
                    request, canary=True)
                self._pairs.append((prec, srec))
            return
        peer = self._pick()
        prec = self.engines[peer].enqueue(request)
        self._primaries.append(prec)
        self._since_canary += 1
        if (self.canary_every and n > 1
                and self._since_canary >= self.canary_every):
            self._since_canary = 0
            prec.canary = True       # keep the primary's prefill logits too
            shadow = (peer + 1) % n
            srec = self.engines[shadow].enqueue(request, canary=True)
            self._pairs.append((prec, srec))

    # ---- weight refresh (keep-last, staleness-bounded) ---------------------
    def refresh_now(self) -> int:
        """One poll of the snapshot directory; returns peers refreshed."""
        if not self.snapshot_dir:
            return 0
        n0 = self.refreshes
        metas = [snapshot_meta(self.snapshot_dir, i)
                 for i in range(len(self.engines))]
        steps = [m.get("step", -1) if m else -1 for m in metas]
        newest = max(steps) if steps else -1
        for i, eng in enumerate(self.engines):
            step = steps[i]
            if step < 0 or step <= eng.weights_version:
                continue             # keep-last: never adopt older weights
            if self.staleness_bound and newest - step > self.staleness_bound:
                self.refreshes_dropped_stale += 1
                continue             # too stale vs the fleet's newest: drop
            params = load_snapshot_params(self.snapshot_dir, i, eng.params)
            eng.set_params(params)
            eng.weights_version = step
            self.refreshes += 1
            self.refresh_bytes += self._param_bytes
        return self.refreshes - n0

    def _maybe_refresh(self, t_ms: float) -> None:
        if not self.snapshot_dir or self.refresh_every_ms <= 0:
            return
        if t_ms >= self._next_refresh_ms:
            # one poll per catch-up, however long the simulated gap: the
            # intermediate polls would all observe the same directory state
            periods = int((t_ms - self._next_refresh_ms)
                          // self.refresh_every_ms) + 1
            self._next_refresh_ms += periods * self.refresh_every_ms
            self.refresh_now()

    # ---- the run loop ------------------------------------------------------
    def run(self, workload: Workload, slo_ms: float = 50.0) -> FleetReport:
        for req in sorted(workload.requests, key=lambda r: r.arrival_ms):
            self._maybe_refresh(req.arrival_ms)
            for eng in self.engines:
                eng.advance_to(req.arrival_ms)
            self._route(req)
        for eng in self.engines:
            eng.drain()
        end_ms = max((eng.now_ms for eng in self.engines), default=0.0)
        self._maybe_refresh(end_ms)
        for prec, srec in self._pairs:
            self.canary_stats.observe(prec, srec)
        return self._report(workload, slo_ms, end_ms)

    def _report(self, workload: Workload, slo_ms: float,
                end_ms: float) -> FleetReport:
        done = [r for r in self._primaries if r.finished_ms is not None]
        ttfts = [r.ttft_ms for r in done]
        e2es = [r.e2e_ms for r in done]
        gen = sum(len(r.tokens) for r in done)
        digest = hashlib.sha256()
        for r in sorted(self._primaries, key=lambda r: r.request.rid):
            digest.update(bytes(f"{r.request.rid}:", "ascii"))
            digest.update(np.asarray(r.tokens, np.int32).tobytes())
        return FleetReport(
            scenario=workload.scenario,
            router=self.policy,
            peers=len(self.engines),
            seed=workload.seed,
            completed=len(done),
            # client-facing rejections only: canary/ensemble shadows are
            # bookkeeping duplicates and must not read as shed client traffic
            rejected=sum(1 for r in self._primaries if r.rejected),
            p50_ttft_ms=_pct(ttfts, 50), p99_ttft_ms=_pct(ttfts, 99),
            p50_e2e_ms=_pct(e2es, 50), p99_e2e_ms=_pct(e2es, 99),
            slo_ms=slo_ms,
            slo_attainment=(sum(1 for t in ttfts if t <= slo_ms) / len(ttfts)
                            if ttfts else 0.0),
            sim_tokens_per_s=gen / (end_ms / 1e3) if end_ms > 0 else 0.0,
            generated_tokens=gen,
            kv_bytes_written=sum(e.kv_bytes_written for e in self.engines),
            refresh_bytes=self.refresh_bytes,
            refreshes=self.refreshes,
            refreshes_dropped_stale=self.refreshes_dropped_stale,
            peak_pool_utilization=max(e.peak_utilization
                                      for e in self.engines),
            canary=self.canary_stats.summary(),
            stream_digest=digest.hexdigest(),
        )
