"""Train states (single-model and stacked codistillation)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.codistillation import init_stacked
from repro.optim import OptState

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: OptState
    step: jax.Array  # int32 scalar


class CodistState(NamedTuple):
    """State for n codistilling models: every leaf of ``params``/``opt`` has a
    leading axis of size n (sharded over the "pod" mesh axis in production).

    ``stale`` (checkpoint mode): replica set as of the last exchange, same
    stacked layout but conceptually replicated to every group.
    ``peer`` (pipelined prediction mode): previous exchange's logits + batch.
    """
    params: PyTree
    opt: OptState
    step: jax.Array
    stale: Optional[PyTree] = None
    peer: Optional[PyTree] = None


def init_train_state(model, key: jax.Array, opt_init) -> TrainState:
    params = model.init(key)
    return TrainState(params, opt_init(params), jnp.zeros((), jnp.int32))


def init_codist_state(model, key: jax.Array, n: int, opt_init,
                      with_stale: bool = False) -> CodistState:
    params = init_stacked(model.init, key, n)
    opt = opt_init(params)
    stale = jax.tree.map(jnp.array, params) if with_stale else None
    return CodistState(params, opt, jnp.zeros((), jnp.int32), stale, None)


def init_peer_state(batch_all: Dict, logits_shape: Tuple[int, ...]) -> Dict:
    """Pipelined-prediction peer buffer: previous batch + logits, invalid
    until the first exchange (``valid`` gates the distillation weight)."""
    return {"batch": jax.tree.map(jnp.zeros_like, batch_all),
            "logits": jnp.zeros(logits_shape, jnp.float32),
            "valid": jnp.zeros((), jnp.bool_)}
