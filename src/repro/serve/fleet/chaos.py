"""Seeded fault injection + router defenses for the serving fleet.

The paper's resilience claim (weak synchronization tolerates replica
failure — Anil et al. arXiv:1804.03235; straggler analysis of Chen et al.
arXiv:1604.00981) was demonstrated for *training* by the async runtime's
fault schedule. This module extends the SAME seeded machinery
(:class:`repro.runtime.clock.FaultSchedule`) to serving: a fault-schedule
"step" becomes a decode tick, and a "duration" a multiple of the engine's
deterministic per-tick cost, so one ``--faults`` spec drives both worlds.

Fault model (applied inside ``FleetEngine.step`` when a schedule is
attached):

  * **straggler episodes** — the whole tick cost is multiplied by
    ``FaultSchedule.slowdown(peer, tick)`` (base speed x episode factor);
  * **preemption** — after the tick named in the schedule the peer goes
    offline for ``pause x unit_ms`` simulated ms: its clock jumps past the
    pause and in-flight slots are frozen (no decode progress, KV intact);
  * **permanent failure** — the peer dies at the start of the scheduled
    tick; its KV state is lost. With ``recover_after_ms`` set, the router
    revives it from its ``checkpoint/io.py`` snapshot (or, absent one, its
    last adopted in-memory weights — a warm spare).

Defenses (:class:`FleetDefense`, applied by ``FleetRouter``):

  * **health tracking** — per-peer EWMA of the observed/clean tick-cost
    ratio; a peer whose EWMA exceeds ``unhealthy_factor`` stops receiving
    new work until it recovers (routing falls back to unhealthy-but-alive
    peers only when nothing better exists);
  * **migration** — admitted-but-unfinished requests on a dead peer (or one
    preempted for longer than ``migrate_pause_over_ms``) are re-prefilled on
    a healthy peer as a *continuation*: already-emitted tokens become prompt
    context, so the client stream has at-most-once token emission — no
    duplicates, no gaps. Placement failures retry with exponential backoff
    up to ``max_migrations`` attempts;
  * **hedged dispatch** — the slowest-decile requests (by prompt+output
    size) run on two peers; the first complete response answers the client
    and the other copy is cancelled (whole-response hedging: nothing is
    delivered until a copy completes, so cancellation never rewinds the
    client stream);
  * **degraded admission** — queue bounds scale with the fraction of
    available peers, so a shrunken fleet sheds at the edge instead of
    accepting latency it cannot serve.

Everything is a pure function of (configs, seed): chaos runs are replayable
bit-for-bit, which the ``serve-chaos-smoke`` CI job and the
``benchmarks/serving_chaos.py`` rows pin.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.runtime.clock import FaultConfig, FaultSchedule


@dataclass(frozen=True)
class ChaosConfig:
    """Fault injection for one fleet run.

    ``faults`` is the runtime's own config (so ``parse_faults`` specs work
    unchanged); ``unit_ms`` converts its unit-less pause durations into
    simulated milliseconds (1.0 => spec pauses are written in ms).
    """
    faults: FaultConfig
    horizon_ticks: int = 4096        # fault-schedule realization horizon
    unit_ms: float = 1.0             # sim-ms per fault-schedule time unit
    recover_after_ms: float = 0.0    # 0 = dead peers stay dead

    def __post_init__(self):
        if self.horizon_ticks <= 0:
            raise ValueError(f"horizon_ticks={self.horizon_ticks} must be >0")
        if self.unit_ms <= 0:
            raise ValueError(f"unit_ms={self.unit_ms} must be > 0")
        if self.recover_after_ms < 0:
            raise ValueError(
                f"recover_after_ms={self.recover_after_ms} is negative")


class ChaosSchedule:
    """Deterministic realization of a :class:`ChaosConfig` in fleet units
    (ticks and simulated ms). Thin adapter over ``FaultSchedule`` — all
    randomness is the schedule's, drawn once from ``faults.seed``."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.sched = FaultSchedule(cfg.faults, cfg.horizon_ticks)

    def slowdown(self, peer: int, tick: int) -> float:
        """Multiplier on the peer's full tick cost (>= its base speed)."""
        return self.sched.slowdown(peer, tick)

    def pause_ms(self, peer: int, tick: int) -> float:
        """Preemption pause in simulated ms after local tick ``tick``."""
        return self.sched.pause_after(peer, tick) * self.cfg.unit_ms

    def fails_at(self, peer: int) -> Optional[int]:
        """Tick at which the peer dies permanently (None = never)."""
        return self.sched.fails_at(peer)

    def describe(self, peer: int, tick: int) -> Dict:
        """Deterministic snapshot of the fault state one peer sees at one
        tick — the Watchtower's postmortem bundles embed this so a dumped
        alert names the injected cause next to the observed symptom."""
        fails = self.fails_at(peer)
        return {
            "peer": peer,
            "tick": tick,
            "slowdown": self.slowdown(peer, tick),
            "pause_ms": self.pause_ms(peer, tick),
            "fails_at_tick": fails if fails is not None else -1,
        }


@dataclass(frozen=True)
class FleetDefense:
    """Router-side chaos defenses. Constructing one and passing it to
    ``FleetRouter`` turns the defenses on; ``None`` is the undefended
    baseline the chaos benchmark compares against."""
    # health: EWMA of observed/clean tick-cost ratio per peer
    health_alpha: float = 0.25
    unhealthy_factor: float = 2.0    # EWMA above this => route around
    # migration of admitted-but-unfinished work off dead/preempted peers
    migration: bool = True
    migrate_pause_over_ms: float = 10.0   # preemption timeout threshold
    retry_backoff_ms: float = 5.0         # base for exponential backoff
    max_migrations: int = 3               # attempts per logical request
    # hedged dispatch of the slowest-decile requests
    hedging: bool = False
    hedge_quantile: float = 0.9
    hedge_min_samples: int = 8            # sizes seen before hedging starts
    # admission control under reduced capacity
    degraded_admission: bool = True
    # drain-phase maintenance cadence (simulated ms between router sweeps)
    maintenance_quantum_ms: float = 20.0

    def __post_init__(self):
        if not 0.0 < self.health_alpha <= 1.0:
            raise ValueError(f"health_alpha={self.health_alpha} "
                             "must be in (0, 1]")
        if self.unhealthy_factor <= 1.0:
            raise ValueError(f"unhealthy_factor={self.unhealthy_factor} must "
                             "be > 1 (1.0 would flag healthy peers)")
        if not 0.0 < self.hedge_quantile < 1.0:
            raise ValueError(f"hedge_quantile={self.hedge_quantile} "
                             "must be in (0, 1)")
        if self.retry_backoff_ms <= 0 or self.maintenance_quantum_ms <= 0:
            raise ValueError("retry_backoff_ms and maintenance_quantum_ms "
                             "must be > 0")


@dataclass
class PeerHealth:
    """EWMA of a peer's observed tick cost relative to the clean cost model.

    1.0 = nominal; a straggler episode at factor F drives it toward F within
    ``~1/alpha`` ticks, and it decays back once the episode ends — that lag
    is the detector's (deterministic) reaction time.
    """
    alpha: float = 0.25
    ewma: float = 1.0
    ticks: int = 0

    def observe(self, ratio: float) -> None:
        self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * ratio
        self.ticks += 1

    def healthy(self, unhealthy_factor: float) -> bool:
        return self.ewma <= unhealthy_factor


@dataclass
class ChaosStats:
    """Router-side chaos accounting (all deterministic counters)."""
    migrations: int = 0              # continuations successfully placed
    migration_failures: int = 0      # gave up after max_migrations
    hedges: int = 0                  # requests dispatched to two peers
    hedge_wins: int = 0              # hedge copy answered the client
    peers_died: int = 0
    peers_recovered: int = 0

    def to_dict(self) -> Dict:
        """One serialization path, shared with ``FleetReport.to_dict`` —
        the CLI report, bench rows, and metrics export all read this."""
        return dict(self.__dict__)

    def summary(self) -> Dict:
        return self.to_dict()


@dataclass
class _HedgePair:
    """One hedged request: the client-facing record + its shadow copy."""
    rec: object                      # primary RequestRecord (in _primaries)
    hrec: object                     # hedge RequestRecord
    ppeer: int
    hpeer: int
    palive: bool = True              # copy still placed on a live peer
    halive: bool = True


@dataclass
class _Orphan:
    """A logical request awaiting (re-)placement after its peer failed."""
    rec: object                      # logical RequestRecord
    next_attempt_ms: float = 0.0
