"""Exchange strategies for codistillation (Section 3 implementation options).

Prediction exchange (coordinated sampling) is handled directly inside the step
function via the stacked-logits codist loss. This module implements the pieces
that carry *state across steps*:

  * CheckpointExchange — every T steps each group publishes its parameters;
    between exchanges every group trains against the (stale) replica set and
    pays n-1 extra forward passes per step (Anil et al.'s variant).
  * PipelinedPredictions — beyond-paper: distill against the *previous*
    exchange step's peer logits, removing the per-step sync point (the paper
    argues predictions drift slowly — Section 3 — so 1-step staleness is benign;
    we make that an explicit first-class scheduling mode and validate it).

Both are pure-functional: state in, state out, usable inside pjit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CodistConfig

PyTree = Any


class CheckpointExchangeState(NamedTuple):
    """Stale replica buffer: stacked params of ALL n models as of the last
    exchange; every group holds the full set (replicated over pod)."""
    stale_params: PyTree
    last_exchange_step: jax.Array  # int32 scalar


def init_checkpoint_exchange(stacked_params: PyTree) -> CheckpointExchangeState:
    return CheckpointExchangeState(
        stale_params=jax.tree.map(jnp.array, stacked_params),
        last_exchange_step=jnp.zeros((), jnp.int32),
    )


def maybe_exchange_checkpoints(cfg: CodistConfig,
                               state: CheckpointExchangeState,
                               stacked_params: PyTree,
                               step: jax.Array) -> CheckpointExchangeState:
    """Publish fresh params every ``cfg.period`` steps (lax.cond so both sides
    lower; on real hardware the true branch is the cross-pod all-gather)."""
    do = (step % cfg.period) == 0

    def fresh(_):
        return CheckpointExchangeState(
            stale_params=jax.tree.map(lambda x: x, stacked_params),
            last_exchange_step=jnp.asarray(step, jnp.int32),
        )

    def keep(_):
        return state

    return jax.lax.cond(do, fresh, keep, operand=None)


class PipelinedState(NamedTuple):
    """Previous-step stacked logits used as distillation targets."""
    peer_logits: jax.Array   # (n, B, S, V) — or compressed wire pytree
    valid: jax.Array         # bool scalar: False until first exchange done


def init_pipelined(n: int, logits_shape: Tuple[int, ...],
                   dtype=jnp.float32) -> PipelinedState:
    return PipelinedState(
        peer_logits=jnp.zeros((n, *logits_shape), dtype),
        valid=jnp.zeros((), jnp.bool_),
    )


def pipelined_targets(state: PipelinedState,
                      live_logits: jax.Array) -> jax.Array:
    """Targets = previous step's logits when available, else live (first step)."""
    return jnp.where(state.valid, state.peer_logits,
                     jax.lax.stop_gradient(live_logits))


def update_pipelined(state: PipelinedState,
                     live_logits: jax.Array) -> PipelinedState:
    return PipelinedState(
        peer_logits=jax.lax.stop_gradient(live_logits).astype(state.peer_logits.dtype),
        valid=jnp.ones((), jnp.bool_),
    )


# ----------------------------------------------------------------------------
# step scheduling: which steps carry a distillation term / an exchange
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class StepPlan:
    """Host-side plan for step k (static — selects which jitted fn to call)."""
    distill: bool    # include the distillation term this step
    exchange: bool   # communication happens this step

    @staticmethod
    def for_step(cfg: CodistConfig, step: int) -> "StepPlan":
        if cfg.n_models < 2:
            return StepPlan(False, False)
        if step < cfg.burn_in_steps:
            return StepPlan(False, False)
        on = (step % cfg.period) == 0
        if cfg.mode == "checkpoints":
            # distill EVERY step against the stale replicas; exchange every T
            return StepPlan(True, on)
        # predictions: distill only on exchange steps (Section 3)
        return StepPlan(on, on)
