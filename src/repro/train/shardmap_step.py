"""Explicit-collective codistillation step (shard_map over the pod axis).

The pure-pjit codist step lets XLA place the cross-pod exchange — fine for
raw logits, but compiler-chosen placement defeats producer-side COMPRESSION
(XLA may move the raw logits and compress afterwards). This step pins the
schedule by construction:

  * manual over ``"pod"``: each pod computes its model's forward, task loss
    and the COMPRESSED wire locally (``"data"``/``"model"`` stay automatic, so
    FSDP/TP inside the pod is unchanged);
  * ``jax.lax.all_gather(wire, "pod")`` is the ONLY cross-pod communication —
    by construction the links carry exactly the compressed representation
    (top-k values+indices / bf16 / a token subsample), fulfilling the paper's
    Section-3 accounting on TPU topology;
  * ``stop_gradient`` on the received wire keeps the backward pass pod-local.

This is the beyond-paper deliverable: the paper exchanges full fp32
predictions; LM vocabularies make that as heavy as gradient sync, and this
step restores the 100-1000x win the paper reported for small prediction
vectors.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import CodistConfig, TrainConfig
from repro.core import codistillation as cd
from repro.optim import make_optimizer
from repro.train.state import CodistState
from repro.train.steps import make_schedules, _grads_with_metrics

PyTree = Any


def _lead_spec(tree: PyTree, axis: str) -> PyTree:
    return jax.tree.map(
        lambda x: P(*([axis] + [None] * (x.ndim - 1))), tree)


def make_codist_shardmap_step(model, codist: CodistConfig, tc: TrainConfig,
                              mesh) -> Callable:
    """Prediction-exchange codist step with an explicit compressed exchange.

    State/batch layouts are identical to ``make_codist_step`` (stacked leading
    n axis over "pod"), so shardings and the host loop are unchanged.
    """
    lr_fn, wd_fn, ls_fn, alpha_fn = make_schedules(tc, codist)
    _, opt_update = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                   b1=tc.adam_b1, b2=tc.adam_b2,
                                   dtype=tc.opt_dtype)
    n = codist.n_models
    auto_axes = frozenset(a for a in mesh.axis_names if a != "pod")

    def step(state: CodistState, batch_all: Dict) -> Tuple[CodistState, Dict]:
        def loss_fn(stacked, b):
            def per_pod(params_1, batch_1):
                params = jax.tree.map(lambda x: x[0], params_1)
                batch = jax.tree.map(lambda x: x[0], batch_1)
                logits, aux = model.forward(params, batch, remat=tc.remat)
                task = cd.cross_entropy(logits, batch["labels"],
                                        ls_fn(state.step), batch.get("mask"),
                                        fused=tc.fused_losses)
                # local compression, explicit cross-pod gather of the wire
                wire = cd.compress_targets(
                    codist, jax.lax.stop_gradient(logits))
                wires_all = jax.tree.map(
                    lambda x: jax.lax.all_gather(x, "pod"), wire)
                idx = jax.lax.axis_index("pod")
                dist = jnp.zeros((), jnp.float32)
                for j in range(n):
                    wire_j = jax.tree.map(lambda x: x[j], wires_all)
                    d = cd.distill_vs_compressed(codist, logits, wire_j,
                                                 batch.get("mask"),
                                                 fused=tc.fused_losses)
                    dist = dist + jnp.where(idx == j, 0.0, d)
                dist = dist / (n - 1)
                total = task + alpha_fn(state.step) * dist + aux
                out = jnp.stack([total, task, dist, aux])
                return out[None]  # (1, 4): pod-sharded metrics row

            per_pod_mapped = compat.shard_map(
                per_pod, mesh=mesh,
                in_specs=(_lead_spec(stacked, "pod"), _lead_spec(b, "pod")),
                out_specs=P("pod", None),
                check_vma=False,
                axis_names={"pod"},
            )
            rows = per_pod_mapped(stacked, b)        # (n, 4)
            total = jnp.mean(rows[:, 0])
            metrics = {"loss": total,
                       "task_loss": jnp.mean(rows[:, 1]),
                       "distill_loss": jnp.mean(rows[:, 2]),
                       "aux_loss": jnp.mean(rows[:, 3]),
                       "task_loss_per_model": rows[:, 1],
                       "distill_loss_per_model": rows[:, 2],
                       "alpha": alpha_fn(state.step)}
            return total, metrics

        mb_batch = batch_all
        if tc.microbatch > 1:
            mb_batch = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batch_all)
        grads, metrics = _grads_with_metrics(loss_fn, state.params, mb_batch,
                                             tc.microbatch,
                                             jnp.dtype(tc.accum_dtype))
        params, opt = opt_update(state.params, grads, state.opt,
                                 lr_fn(state.step), wd_fn(state.step))
        metrics.update(lr=lr_fn(state.step), wd=wd_fn(state.step))
        return CodistState(params, opt, state.step + 1, state.stale,
                           state.peer), metrics

    return step
