"""Sweep launcher: expand -> run -> aggregate a paper-grid spec.

    PYTHONPATH=src python -m repro.launch.sweep \\
        --spec experiments/specs/paper_grid_small.yaml \\
        [--out results/sweeps] [--resume] [--max-cells N] [--steps N] \\
        [--list] [--aggregate-only] [--no-aggregate] [--trace] [--metrics] \\
        [--alerts] [--rules RULES.json]

Cells persist individually under ``<out>/<spec.name>/`` as they complete
(``<cell_id>.jsonl`` history + ``<cell_id>.json`` summary), so a killed
sweep resumes with ``--resume`` (completed cells are validated and
skipped — rerunning a finished sweep with ``--resume`` is a no-op, which
CI asserts). Aggregation runs after every sweep (and standalone via
``--aggregate-only``), writing ``SWEEP_<name>.json`` + ``SWEEP_<name>.md``
with the per-cell codist-vs-allreduce gaps. See docs/experiments.md.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.sweep",
        description="Run a declarative paper-grid sweep spec.")
    ap.add_argument("--spec", required=True,
                    help="path to a .yaml/.json SweepSpec")
    ap.add_argument("--out", default="results/sweeps",
                    help="results root; cells land in <out>/<spec.name>/")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose persisted result validates")
    ap.add_argument("--max-cells", type=int, default=0,
                    help="run only the first N cells of the expansion")
    ap.add_argument("--steps", type=int, default=0,
                    help="override the spec's per-cell step count")
    ap.add_argument("--list", action="store_true",
                    help="print the expanded cell ids and exit")
    ap.add_argument("--aggregate-only", action="store_true",
                    help="skip running; aggregate existing results")
    ap.add_argument("--no-aggregate", action="store_true",
                    help="run cells but skip the aggregation pass")
    ap.add_argument("--trace", action="store_true",
                    help="write a per-cell Perfetto trace next to each "
                         "result (<cell_id>.trace.json; docs/observability.md)")
    ap.add_argument("--metrics", action="store_true",
                    help="write a per-cell repro.obs metrics dump next to "
                         "each result (<cell_id>.metrics.json)")
    ap.add_argument("--alerts", action="store_true",
                    help="evaluate Watchtower rules per cell "
                         "(<cell_id>.alerts.jsonl) plus a sweep-level "
                         "codist-vs-baseline loss-gap watch (alerts.jsonl); "
                         "deterministic per seed (docs/observability.md)")
    ap.add_argument("--rules", default="",
                    help="JSON rules file overriding the built-in rule pack "
                         "(requires --alerts)")
    args = ap.parse_args(argv)
    if args.rules and not args.alerts:
        ap.error("--rules requires --alerts")

    from repro.experiments import (aggregate_and_write, load_spec, run_sweep,
                                   sweep_dir_for)

    spec = load_spec(args.spec)
    cells = spec.cells()
    if args.list:
        for c in cells:
            print(c.cell_id)
        print(f"# {len(cells)} cells ({spec.name})")
        return 0

    failed = 0
    if not args.aggregate_only:
        results = run_sweep(spec, args.out, resume=args.resume,
                            max_cells=args.max_cells or None,
                            steps=args.steps or None,
                            trace=args.trace, metrics=args.metrics,
                            alerts=args.alerts,
                            rules_path=args.rules or None)
        failed = sum(1 for r in results if r.status == "failed")

    if not args.no_aggregate:
        doc, json_path, md_path = aggregate_and_write(spec, args.out)
        print(f"aggregated {doc['n_cells']} cells -> {json_path}, {md_path}")
        for row in doc["grid"]:
            if row["gap_vs_allreduce"] is not None:
                print(f"  gap[{row['mode']} b{row['batch']} {row['lr']} "
                      f"{row['alpha']} n{row['peers']}] = "
                      f"{row['gap_vs_allreduce']:+.4f}")
        if not doc["n_cells"]:
            print(f"warning: no completed cells under "
                  f"{sweep_dir_for(spec.name, args.out)}", file=sys.stderr)

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
