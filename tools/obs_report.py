#!/usr/bin/env python3
"""Render repro.obs artifacts into a self-contained postmortem dashboard.

    python tools/obs_report.py --out dash.html \
        [--trace t.json] [--metrics m.json] [--alerts a.jsonl] \
        [--report r.json] [--postmortems DIR] [--format html|md] \
        [--title TITLE]

Pulls together whatever subset of artifacts a run produced — Perfetto
trace, metrics registry dump, Watchtower alert JSONL, gated report JSON,
flight-recorder postmortem bundles — into ONE dependency-free document:

  * run summary table (report JSON scalars, or ``report/*`` gauges);
  * latency / histogram percentiles from the metrics dump;
  * the alert log as a table (fire/resolve transitions, severities);
  * HTML only: inline-SVG timelines of every trace counter series with
    alert transitions (solid rules) and fault/chaos instants (dashed)
    annotated at their simulated timestamps;
  * postmortem bundle index (reason, ts, ring depth).

Determinism contract (CI-gated): the output is a pure function of the
input files — sorted iteration everywhere, no wall-clock stamps — so two
renders of the same artifacts are byte-identical. Stdlib only.
"""
from __future__ import annotations

import argparse
import glob
import html
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

SVG_W, SVG_H, SVG_PAD = 640, 120, 30

_SEV_COLOR = {"info": "#2b6cb0", "warning": "#b7791f", "critical": "#c53030"}


# ----------------------------------------------------------------------------
# artifact loading
# ----------------------------------------------------------------------------

def _load(path: Optional[str]) -> Optional[Dict]:
    if not path:
        return None
    with open(path) as f:
        return json.load(f)


def _load_alerts(path: Optional[str]) -> Tuple[Optional[Dict], List[Dict]]:
    """(header, events) from a Watchtower JSONL."""
    if not path:
        return None, []
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    if lines and lines[0].get("kind") == "alerts":
        return lines[0], lines[1:]
    return None, lines


def _load_postmortems(dirpath: Optional[str]) -> List[Tuple[str, Dict]]:
    if not dirpath:
        return []
    out = []
    for path in sorted(glob.glob(os.path.join(dirpath, "postmortem_*.json"))):
        with open(path) as f:
            out.append((os.path.basename(path), json.load(f)))
    return out


# ----------------------------------------------------------------------------
# section builders (format-agnostic rows)
# ----------------------------------------------------------------------------

def _fmt(v: Any) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def summary_rows(report: Optional[Dict],
                 metrics: Optional[Dict]) -> List[Tuple[str, str]]:
    """Flat scalars from the gated report, else the report/* gauges the
    fleet mirrors into the registry."""
    if report:
        return [(k, _fmt(v)) for k, v in sorted(report.items())
                if isinstance(v, (int, float, str, bool))]
    if metrics:
        return [(k.split("/", 1)[1], _fmt(v))
                for k, v in sorted(metrics.get("gauges", {}).items())
                if k.startswith("report/")]
    return []


def histogram_rows(metrics: Optional[Dict]) -> List[List[str]]:
    rows = []
    for name, h in sorted((metrics or {}).get("histograms", {}).items()):
        rows.append([name] + [_fmt(h.get(k, 0))
                              for k in ("count", "mean", "p50", "p90",
                                        "p99", "max")])
    return rows


def alert_rows(events: List[Dict]) -> List[List[str]]:
    rows = []
    for ev in events:
        rows.append([_fmt(ev.get("ts")), ev.get("rule", "?"),
                     ev.get("state", "?"), ev.get("severity", "?"),
                     ev.get("metric", "?"),
                     _fmt(ev.get("value", "")),
                     f"{ev.get('op', '?')} {_fmt(ev.get('threshold', ''))}"])
    return rows


def counter_series(trace: Optional[Dict]) -> Dict[str, List[Tuple[int, float]]]:
    """``name/series`` -> [(ts, value)] from the trace's C events."""
    series: Dict[str, List[Tuple[int, float]]] = {}
    for ev in (trace or {}).get("traceEvents", []):
        if ev.get("ph") != "C":
            continue
        for key, val in sorted((ev.get("args") or {}).items()):
            if isinstance(val, (int, float)) and not isinstance(val, bool):
                series.setdefault(f"{ev.get('name', '?')}/{key}", []).append(
                    (ev.get("ts", 0), float(val)))
    return {k: sorted(v) for k, v in sorted(series.items())}


def fault_instants(trace: Optional[Dict]) -> List[Tuple[int, str]]:
    """(ts, label) for chaos/fault instant markers in the trace."""
    out = []
    for ev in (trace or {}).get("traceEvents", []):
        if ev.get("ph") in ("i", "n") and (
                ev.get("cat") in ("chaos", "fault")
                or ev.get("name") in ("preempt", "fail", "die")):
            out.append((ev.get("ts", 0), ev.get("name", "?")))
    return sorted(out)


# ----------------------------------------------------------------------------
# SVG timeline (html format only)
# ----------------------------------------------------------------------------

def _svg_timeline(name: str, points: List[Tuple[int, float]],
                  alerts: List[Dict], faults: List[Tuple[int, str]]) -> str:
    if len(points) < 2:
        return ""
    xs = [p[0] for p in points]
    ys = [p[1] for p in points]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    xspan = (x1 - x0) or 1
    yspan = (y1 - y0) or 1.0

    def X(t: float) -> float:
        return SVG_PAD + (t - x0) / xspan * (SVG_W - 2 * SVG_PAD)

    def Y(v: float) -> float:
        return (SVG_H - SVG_PAD
                - (v - y0) / yspan * (SVG_H - 2 * SVG_PAD))

    pts = " ".join(f"{X(t):.1f},{Y(v):.1f}" for t, v in points)
    parts = [f'<svg viewBox="0 0 {SVG_W} {SVG_H}" width="{SVG_W}" '
             f'height="{SVG_H}" role="img">',
             f'<title>{html.escape(name)}</title>',
             f'<rect width="{SVG_W}" height="{SVG_H}" fill="#fafafa"/>',
             f'<polyline points="{pts}" fill="none" stroke="#2b6cb0" '
             'stroke-width="1.5"/>']
    for ts, label in faults:
        if x0 <= ts <= x1:
            x = X(ts)
            parts.append(f'<line x1="{x:.1f}" y1="{SVG_PAD}" x2="{x:.1f}" '
                         f'y2="{SVG_H - SVG_PAD}" stroke="#718096" '
                         'stroke-dasharray="3,3" stroke-width="1">'
                         f'<title>fault {html.escape(label)} @ {ts}</title>'
                         '</line>')
    for ev in alerts:
        ts = ev.get("ts", 0)
        if x0 <= ts <= x1:
            x = X(ts)
            color = _SEV_COLOR.get(ev.get("severity", ""), "#c53030")
            dash = "" if ev.get("state") == "firing" else \
                ' stroke-dasharray="6,2"'
            parts.append(
                f'<line x1="{x:.1f}" y1="{SVG_PAD}" x2="{x:.1f}" '
                f'y2="{SVG_H - SVG_PAD}" stroke="{color}" '
                f'stroke-width="1.5"{dash}>'
                f'<title>{html.escape(ev.get("rule", "?"))} '
                f'{html.escape(ev.get("state", "?"))} @ {ts}</title></line>')
    parts.append(f'<text x="{SVG_PAD}" y="12" font-size="11" '
                 f'fill="#4a5568">{html.escape(name)}  '
                 f'[{_fmt(y0)} .. {_fmt(y1)}]</text>')
    parts.append("</svg>")
    return "".join(parts)


# ----------------------------------------------------------------------------
# renderers
# ----------------------------------------------------------------------------

def _md_table(headers: List[str], rows: List[List[str]]) -> List[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for row in rows:
        out.append("| " + " | ".join(str(c) for c in row) + " |")
    return out


def render_md(title: str, report, metrics, alerts_head, alerts, trace,
              bundles) -> str:
    lines = [f"# {title}", ""]
    if alerts_head:
        lines += [f"Clock: `{alerts_head.get('clock', '?')}` "
                  f"(unit {_fmt(alerts_head.get('unit_us', '?'))} µs), "
                  f"{alerts_head.get('n_rules', '?')} rules evaluated.", ""]
    srows = summary_rows(report, metrics)
    if srows:
        lines += ["## Run summary", ""]
        lines += _md_table(["metric", "value"], [list(r) for r in srows])
        lines.append("")
    hrows = histogram_rows(metrics)
    if hrows:
        lines += ["## Latency / distributions", ""]
        lines += _md_table(["histogram", "count", "mean", "p50", "p90",
                            "p99", "max"], hrows)
        lines.append("")
    lines += ["## Alerts", ""]
    if alerts:
        lines += _md_table(["ts", "rule", "state", "severity", "metric",
                            "value", "bound"], alert_rows(alerts))
    else:
        lines.append("No alert transitions recorded.")
    lines.append("")
    faults = fault_instants(trace)
    if faults:
        lines += ["## Fault / chaos events", ""]
        lines += _md_table(["ts", "event"],
                           [[str(t), n] for t, n in faults])
        lines.append("")
    if bundles:
        lines += ["## Postmortem bundles", ""]
        lines += _md_table(
            ["file", "reason", "ts", "ring events", "events seen"],
            [[name, b.get("reason", "?"), _fmt(b.get("ts", "?")),
              str(len(b.get("events", []))), _fmt(b.get("n_events_seen", 0))]
             for name, b in bundles])
        lines.append("")
    return "\n".join(lines).rstrip() + "\n"


def _html_table(headers: List[str], rows: List[List[str]]) -> str:
    head = "".join(f"<th>{html.escape(h)}</th>" for h in headers)
    body = "".join(
        "<tr>" + "".join(f"<td>{html.escape(str(c))}</td>" for c in row)
        + "</tr>" for row in rows)
    return f"<table><thead><tr>{head}</tr></thead><tbody>{body}</tbody></table>"


def render_html(title: str, report, metrics, alerts_head, alerts, trace,
                bundles) -> str:
    parts = [
        "<!DOCTYPE html>",
        '<html lang="en"><head><meta charset="utf-8">',
        f"<title>{html.escape(title)}</title>",
        "<style>",
        "body{font:14px/1.5 system-ui,sans-serif;margin:2em auto;"
        "max-width:72em;color:#1a202c;padding:0 1em}",
        "table{border-collapse:collapse;margin:0.5em 0}",
        "th,td{border:1px solid #cbd5e0;padding:0.25em 0.6em;"
        "text-align:left;font-variant-numeric:tabular-nums}",
        "th{background:#edf2f7}",
        "h1,h2{border-bottom:1px solid #e2e8f0;padding-bottom:0.2em}",
        ".firing{color:#c53030;font-weight:600}",
        ".resolved{color:#2f855a}",
        "svg{display:block;margin:0.75em 0;border:1px solid #e2e8f0}",
        "</style></head><body>",
        f"<h1>{html.escape(title)}</h1>",
    ]
    if alerts_head:
        parts.append(
            f"<p>Clock: <code>{html.escape(str(alerts_head.get('clock')))}"
            f"</code> (unit {_fmt(alerts_head.get('unit_us', '?'))} µs), "
            f"{alerts_head.get('n_rules', '?')} rules evaluated.</p>")
    srows = summary_rows(report, metrics)
    if srows:
        parts.append("<h2>Run summary</h2>")
        parts.append(_html_table(["metric", "value"], [list(r) for r in srows]))
    hrows = histogram_rows(metrics)
    if hrows:
        parts.append("<h2>Latency / distributions</h2>")
        parts.append(_html_table(["histogram", "count", "mean", "p50",
                                  "p90", "p99", "max"], hrows))
    parts.append("<h2>Alerts</h2>")
    if alerts:
        head = ["ts", "rule", "state", "severity", "metric", "value",
                "bound"]
        body = "".join(
            "<tr>"
            f"<td>{ev.get('ts')}</td>"
            f"<td>{html.escape(ev.get('rule', '?'))}</td>"
            f"<td class=\"{html.escape(ev.get('state', ''))}\">"
            f"{html.escape(ev.get('state', '?'))}</td>"
            f"<td>{html.escape(ev.get('severity', '?'))}</td>"
            f"<td>{html.escape(ev.get('metric', '?'))}</td>"
            f"<td>{html.escape(_fmt(ev.get('value', '')))}</td>"
            f"<td>{html.escape(ev.get('op', '?'))} "
            f"{html.escape(_fmt(ev.get('threshold', '')))}</td></tr>"
            for ev in alerts)
        parts.append(
            "<table><thead><tr>"
            + "".join(f"<th>{h}</th>" for h in head)
            + f"</tr></thead><tbody>{body}</tbody></table>")
    else:
        parts.append("<p>No alert transitions recorded.</p>")
    series = counter_series(trace)
    if series:
        faults = fault_instants(trace)
        parts.append("<h2>Timelines</h2>")
        parts.append("<p>Trace counters over simulated time; solid rules "
                     "mark alert firings (dashed colored: resolutions), "
                     "dashed gray rules mark injected faults.</p>")
        for name, points in series.items():
            svg = _svg_timeline(name, points, alerts, faults)
            if svg:
                parts.append(svg)
    if bundles:
        parts.append("<h2>Postmortem bundles</h2>")
        parts.append(_html_table(
            ["file", "reason", "ts", "ring events", "events seen"],
            [[name, b.get("reason", "?"), _fmt(b.get("ts", "?")),
              str(len(b.get("events", []))), _fmt(b.get("n_events_seen", 0))]
             for name, b in bundles]))
    parts.append("</body></html>")
    return "\n".join(parts) + "\n"


# ----------------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python tools/obs_report.py",
        description="Render repro.obs artifacts into one dashboard "
                    "(HTML or markdown, no dependencies).")
    ap.add_argument("--trace", default="", help="Perfetto trace JSON")
    ap.add_argument("--metrics", default="", help="metrics registry dump")
    ap.add_argument("--alerts", default="", help="Watchtower alert JSONL")
    ap.add_argument("--report", default="", help="gated report JSON")
    ap.add_argument("--postmortems", default="",
                    help="directory of flight-recorder bundles")
    ap.add_argument("--out", required=True, help="output file")
    ap.add_argument("--format", choices=("html", "md"), default="",
                    help="default: inferred from --out extension")
    ap.add_argument("--title", default="repro.obs run report")
    args = ap.parse_args(argv)

    fmt = args.format or ("md" if args.out.endswith((".md", ".markdown"))
                          else "html")
    try:
        report = _load(args.report)
        metrics = _load(args.metrics)
        trace = _load(args.trace)
        alerts_head, alerts = _load_alerts(args.alerts)
        bundles = _load_postmortems(args.postmortems)
    except (OSError, json.JSONDecodeError) as e:
        print(f"obs_report: cannot read inputs: {e}", file=sys.stderr)
        return 2
    if not any([report, metrics, trace, alerts_head, alerts, bundles]):
        print("obs_report: no inputs given (pass at least one of --trace/"
              "--metrics/--alerts/--report/--postmortems)", file=sys.stderr)
        return 2

    render = render_md if fmt == "md" else render_html
    text = render(args.title, report, metrics, alerts_head, alerts, trace,
                  bundles)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    tmp = args.out + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, args.out)
    n_alerts = len(alerts)
    print(f"obs_report: wrote {args.out} ({fmt}, {n_alerts} alert "
          f"transitions, {len(bundles)} postmortems)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
