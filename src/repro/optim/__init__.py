"""Optimizers with *scheduled decoupled weight decay* (the paper's knob)."""
from repro.optim.optimizers import (  # noqa: F401
    OptState,
    adamw_init,
    adamw_update,
    make_optimizer,
    sgdm_init,
    sgdm_update,
)
