"""Serving-fleet benchmark: p50/p99 latency, tokens/sec and SLO attainment
per workload scenario, through the full continuous-batching stack (paged KV
pool, admission control, peer router).

One row per (scenario, router) cell on a tiny LM. ``us_per_call`` is WALL
time per generated token (informational on CPU interpret mode — gated only
through the wide ``--min-us`` floor); everything in ``derived`` is computed
on the SIMULATED clock and is bit-deterministic for the committed seed:
``comm_bytes`` (KV-pool bytes written + router weight-refresh bytes — the
serving side's deterministic traffic accounting) is matched EXACTLY by
``tools/bench_compare.py``, so a scheduling / allocation / workload change
that silently alters fleet behavior fails CI the same way a train-side
comm change does.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax

from repro.serve.fleet import FleetConfig, FleetRouter, generate_workload

from benchmarks.common import tiny_lm_cfg

SEED = 17
CELLS = [
    # (scenario, router policy, peers)
    ("steady", "round_robin", 2),
    ("bursty", "least_loaded", 2),
    ("diurnal", "ensemble", 2),
]


def run(quick: bool = False) -> List[Dict]:
    from repro.models import build_model
    cfg = tiny_lm_cfg()
    model = build_model(cfg)
    peer_params = [model.init(jax.random.key(SEED + i)) for i in range(2)]
    n_requests = 12 if quick else 48
    rows: List[Dict] = []
    for scenario, policy, peers in CELLS:
        wl = generate_workload(scenario, n_requests, cfg.padded_vocab,
                               seed=SEED, max_prompt=16, max_new=6)
        fc = FleetConfig(max_slots=4, block_size=4, num_blocks=64,
                         max_blocks_per_slot=8)
        router = FleetRouter(model, peer_params[:peers], config=fc,
                             policy=policy, canary_every=4)
        t0 = time.perf_counter()
        rep = router.run(wl, slo_ms=50.0)
        wall_s = time.perf_counter() - t0
        us_per_tok = wall_s * 1e6 / max(1, rep.generated_tokens)
        comm = rep.kv_bytes_written + rep.refresh_bytes
        rows.append({
            "name": f"serving/{scenario}_{policy}",
            "us_per_call": us_per_tok,
            "derived": (f"p99_ttft_ms={rep.p99_ttft_ms:.3f},"
                        f"slo={rep.slo_attainment:.3f},"
                        f"sim_tok_s={rep.sim_tokens_per_s:.1f},"
                        f"completed={rep.completed},"
                        f"digest={rep.stream_digest[:12]},"
                        f"comm_bytes={comm}"),
        })
    return rows
