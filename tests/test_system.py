"""End-to-end behaviour tests reproducing the paper's core claims at CPU
scale: codistillation matches independent/all_reduce training, acts as a
regularizer, and the exchange modes behave per Section 3."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.data import MarkovLM, make_lm_batch
from repro.models import build_model
from repro.train import stack_batches, train_allreduce, train_codist


def tiny_cfg():
    return replace(get_reduced("qwen1.5-0.5b"), num_layers=2, d_model=64,
                   d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=2,
                   head_dim=32)


TASK = MarkovLM(vocab=64, seed=0)


def coord_batches(n, b=8, s=32):
    def fn(step):
        return stack_batches([make_lm_batch(TASK, b, s, step, None, seed=0)
                              for _ in range(n)])
    return fn


def indep_batches(n, b=8, s=32):
    def fn(step):
        return stack_batches([make_lm_batch(TASK, b, s, step, g, seed=0)
                              for g in range(n)])
    return fn


TC = TrainConfig(lr=3e-3, total_steps=40, warmup_steps=5, optimizer="adamw",
                 lr_schedule="cosine", weight_decay=1e-4, seed=0)


class TestTrainingParity:
    def test_codist_loss_decreases(self):
        model = build_model(tiny_cfg())
        codist = CodistConfig(n_models=2)
        _, hist = train_codist(model, codist, TC, coord_batches(2),
                               log_every=5)
        first = hist.records[0]["task_loss"]
        last = hist.records[-1]["task_loss"]
        assert last < first * 0.85

    def test_codist_comparable_to_allreduce(self):
        """2-way codist (batch B each) ends within 10% of all_reduce (2B) —
        the paper's headline claim, at smoke scale."""
        model = build_model(tiny_cfg())
        codist = CodistConfig(n_models=2)
        _, hist_c = train_codist(model, codist, TC, coord_batches(2, b=8),
                                 log_every=5)

        def it():
            s = 0
            while True:
                yield make_lm_batch(TASK, 16, 32, s, None, seed=0)
                s += 1
        _, hist_a = train_allreduce(model, TC, it(), log_every=5)
        lc = hist_c.records[-1]["task_loss"]
        la = hist_a.records[-1]["task_loss"]
        assert abs(lc - la) / la < 0.10, (lc, la)

    def test_distill_term_pulls_models_together(self):
        """With alpha>0 the two models' predictions converge (distill loss
        shrinks relative to the alpha=0 control)."""
        model = build_model(tiny_cfg())
        on = CodistConfig(n_models=2, alpha0=1.0)
        off = CodistConfig(n_models=2, alpha0=0.0)
        _, h_on = train_codist(model, on, TC, coord_batches(2), log_every=39)
        _, h_off = train_codist(model, off, TC, coord_batches(2), log_every=39)
        assert h_on.records[-1]["distill_loss"] < \
            h_off.records[-1]["distill_loss"]

    def test_regularization_effect_param_distance(self):
        """Fig. 7: codistilled params stay closer to init than independent
        training (same data, same steps)."""
        model = build_model(tiny_cfg())
        on = CodistConfig(n_models=2, alpha0=4.0)
        off = CodistConfig(n_models=2, alpha0=0.0)
        _, h_on = train_codist(model, on, TC, coord_batches(2), log_every=10,
                               track_param_distance=True)
        _, h_off = train_codist(model, off, TC, coord_batches(2),
                                log_every=10, track_param_distance=True)
        assert h_on.records[-1]["param_distance"] < \
            h_off.records[-1]["param_distance"]


class TestExchangeModes:
    def test_period_skips_distill_term(self):
        model = build_model(tiny_cfg())
        codist = CodistConfig(n_models=2, period=5)
        _, hist = train_codist(model, codist, TC, coord_batches(2),
                               log_every=1)
        alphas = hist.series("alpha")
        # only every 5th step carries the distillation term
        assert alphas[0] > 0 and alphas[1] == 0.0 and alphas[5] > 0

    def test_checkpoint_mode_trains(self):
        model = build_model(tiny_cfg())
        codist = CodistConfig(n_models=2, mode="checkpoints", period=10)
        _, hist = train_codist(model, codist, TC, indep_batches(2),
                               log_every=10)
        assert hist.records[-1]["task_loss"] < hist.records[0]["task_loss"]
        assert hist.records[-1]["comm_events"] == 4  # 40 steps / period 10

    def test_pipelined_mode_trains(self):
        model = build_model(tiny_cfg())
        codist = CodistConfig(n_models=2, pipelined=True,
                              compression="subsample", subsample=8)
        _, hist = train_codist(model, codist, TC, coord_batches(2),
                               log_every=10)
        assert hist.records[-1]["task_loss"] < hist.records[0]["task_loss"]

    def test_compressed_topk_trains(self):
        model = build_model(tiny_cfg())
        codist = CodistConfig(n_models=2, compression="topk", topk=16)
        _, hist = train_codist(model, codist, TC, coord_batches(2),
                               log_every=10)
        assert hist.records[-1]["task_loss"] < hist.records[0]["task_loss"]


class TestCheckpointIO:
    def test_state_roundtrip(self, tmp_path):
        from repro.checkpoint import load_pytree, save_pytree
        from repro.optim import make_optimizer
        from repro.train import init_codist_state
        model = build_model(tiny_cfg())
        opt_init, _ = make_optimizer("adamw")
        state = init_codist_state(model, jax.random.key(0), 2, opt_init)
        path = str(tmp_path / "ckpt")
        save_pytree(path, state)
        restored = load_pytree(path, state)
        a = jax.tree.leaves(state.params)[0]
        b = jax.tree.leaves(restored.params)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_save_is_atomic_no_temp_residue(self, tmp_path):
        """save writes via temp + os.replace: only the final names exist
        afterwards, and re-saving over a snapshot never exposes a partial
        file (the chaos recovery path loads these mid-'crash')."""
        import os

        from repro.checkpoint import save_pytree
        tree = {"w": np.arange(6, dtype=np.float32)}
        path = str(tmp_path / "snap")
        save_pytree(path, tree, meta={"step": 1})
        save_pytree(path, {"w": np.ones(6, np.float32)}, meta={"step": 2})
        names = sorted(os.listdir(tmp_path))
        assert names == ["snap.npz", "snap.tree.json"], names  # no .tmp files

    def test_corrupt_snapshot_raises_clear_error(self, tmp_path):
        """A truncated/garbage payload must raise an actionable ValueError,
        not restore garbage weights into a serving peer."""
        from repro.checkpoint import load_pytree, save_pytree
        from repro.checkpoint.io import load_snapshot_params, save_snapshot
        tree = {"w": np.arange(6, dtype=np.float32),
                "b": np.zeros(3, np.float32)}
        path = str(tmp_path / "snap")
        save_pytree(path, tree)
        with open(path + ".npz", "wb") as f:
            f.write(b"not a zipfile")
        with pytest.raises(ValueError, match="corrupt or unreadable"):
            load_pytree(path, tree)
        # truncation to a prefix of the real bytes must also be caught
        save_pytree(path, tree)
        raw = open(path + ".npz", "rb").read()
        with open(path + ".npz", "wb") as f:
            f.write(raw[: len(raw) // 2])
        with pytest.raises(ValueError, match="corrupt or unreadable"):
            load_pytree(path, tree)
        # and the peer-snapshot path used by fleet refresh/recovery
        save_snapshot(str(tmp_path), 0, tree, meta={"step": 3})
        snap = str(tmp_path / "peer0.npz")
        with open(snap, "wb") as f:
            f.write(b"\x00" * 16)
        with pytest.raises(ValueError, match="delete it"):
            load_snapshot_params(str(tmp_path), 0, tree)


class TestCoordinatedSampling:
    def test_same_key_same_batch(self):
        b1 = make_lm_batch(TASK, 4, 16, step=3, group=None, seed=0)
        b2 = make_lm_batch(TASK, 4, 16, step=3, group=None, seed=0)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_groups_differ_without_coordination(self):
        b1 = make_lm_batch(TASK, 4, 16, step=3, group=0, seed=0)
        b2 = make_lm_batch(TASK, 4, 16, step=3, group=1, seed=0)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))

    def test_labels_are_next_tokens(self):
        b = make_lm_batch(TASK, 2, 16, step=0, seed=0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))
