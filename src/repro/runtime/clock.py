"""Virtual time and seeded fault injection for the async peer runtime.

The runtime never reads the wall clock: every peer advances a **simulated**
clock by a per-step duration drawn from a seeded :class:`FaultSchedule`, so a
run is a pure function of ``(configs, seed)`` and is replayable bit-for-bit.

The schedule is **unit-agnostic**: a "step" is whatever the consumer's clock
ticks in — a training step for the async runtime, a decode tick for the
serving fleet's chaos driver (``repro.serve.fleet.chaos``) — and a
"duration" is a dimensionless multiple of the peer's base tick cost.
``duration()`` gives the full seconds-per-step (base speed x episode
multiplier); ``slowdown()`` gives the same number as a pure multiplier for
consumers whose tick cost is set elsewhere (the fleet's deterministic
per-tick cost model). The schedule models the failure modes that motivate
codistillation's weak synchronization (Anil et al., arXiv:1804.03235;
"Revisiting Distributed Synchronous SGD", arXiv:1604.00981):

  * **speed heterogeneity** — each peer has a base seconds-per-step drawn
    once (lognormal around 1.0, ``speed_sigma``) or given explicitly;
  * **straggler episodes** — designated peers run ``straggler_factor`` x
    slower for contiguous episodes covering ``straggler_frac`` of steps;
  * **preemption** — a peer is absent for a fixed span of simulated time
    after a given local step (the barrier baseline stalls everyone);
  * **permanent failure** — a peer dies at a local step; with checkpointing
    enabled the scheduler revives it from its last snapshot after
    ``recover_after`` simulated seconds (elastic membership);
  * **elastic join** — a fresh peer enters mid-training at a simulated time
    and burns in before its distillation loss activates.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class FaultConfig:
    """Seeded description of the virtual cluster and its fault schedule."""
    n_peers: int = 2
    seed: int = 0
    # per-peer base seconds-per-step; () => 1.0 each, jittered by speed_sigma
    speeds: Tuple[float, ...] = ()
    speed_sigma: float = 0.0
    # straggler episodes: each listed peer spends ~straggler_frac of its steps
    # in episodes of straggler_len steps running straggler_factor x slower
    straggler_peers: Tuple[int, ...] = ()
    straggler_factor: float = 4.0
    straggler_frac: float = 0.2
    straggler_len: int = 5
    # (peer, local_step, pause_sim_seconds): absent for `pause` after `step`
    preemptions: Tuple[Tuple[int, int, float], ...] = ()
    # (peer, local_step): dies permanently when reaching `step`
    failures: Tuple[Tuple[int, int], ...] = ()
    # (peer_index, sim_time): fresh peer joins the cluster at `sim_time`;
    # peer_index must be >= n_peers (it extends the membership)
    joins: Tuple[Tuple[int, float], ...] = ()

    def __post_init__(self):
        join_ids = [p for p, _ in self.joins]
        if any(p < self.n_peers for p in join_ids):
            raise ValueError(
                f"join peer indices {join_ids} must be >= n_peers="
                f"{self.n_peers}: a join EXTENDS the membership, it cannot "
                "replace an incumbent")
        if len(join_ids) != len(set(join_ids)):
            raise ValueError(f"duplicate join peer indices: {join_ids}")

    @property
    def n_total(self) -> int:
        """Initial peers plus elastic joiners: the cluster's max membership."""
        return max([self.n_peers] + [p + 1 for p, _ in self.joins])


class FaultSchedule:
    """Deterministic realization of a :class:`FaultConfig` over a horizon.

    All randomness is drawn once at construction from
    ``np.random.default_rng(cfg.seed)`` — two schedules built from equal
    configs are identical, which `tests/test_runtime.py` pins.
    """

    def __init__(self, cfg: FaultConfig, total_steps: int):
        self.cfg = cfg
        self.total_steps = total_steps
        rng = np.random.default_rng(cfg.seed)
        n = cfg.n_total
        if cfg.speeds:
            base = list(cfg.speeds) + [1.0] * (n - len(cfg.speeds))
            self.speeds = np.asarray(base[:n], np.float64)
        elif cfg.speed_sigma > 0:
            self.speeds = np.exp(rng.normal(0.0, cfg.speed_sigma, n))
        else:
            self.speeds = np.ones(n, np.float64)
        # straggler multiplier per (peer, step), 1.0 outside episodes
        self.mult = np.ones((n, total_steps), np.float64)
        for p in cfg.straggler_peers:
            want = int(round(cfg.straggler_frac * total_steps))
            covered = 0
            guard = 0
            while covered < want and guard < 10 * total_steps:
                guard += 1
                s = int(rng.integers(0, max(1, total_steps)))
                e = min(total_steps, s + cfg.straggler_len)
                seg = self.mult[p, s:e]
                covered += int(np.sum(seg == 1.0))
                seg[:] = cfg.straggler_factor
        self.preempt: Dict[Tuple[int, int], float] = {
            (p, s): float(pause) for p, s, pause in cfg.preemptions}
        self.fail_at: Dict[int, int] = {p: s for p, s in cfg.failures}
        self.joins: Tuple[Tuple[int, float], ...] = tuple(
            sorted(cfg.joins, key=lambda j: j[1]))

    def duration(self, peer: int, step: int) -> float:
        """Simulated seconds peer `peer` spends on its local step `step`."""
        mult = self.mult[peer, step] if step < self.total_steps else 1.0
        return float(self.speeds[peer] * mult)

    def slowdown(self, peer: int, step: int) -> float:
        """``duration`` as a dimensionless multiplier of the peer's base tick
        cost — for consumers (the serving fleet) whose per-tick cost model
        lives elsewhere. Identical to ``duration`` because the base speed is
        itself a multiple of the unit tick."""
        return self.duration(peer, step)

    def pause_after(self, peer: int, step: int) -> float:
        """Preemption pause (simulated time units) following local step
        `step` — the consumer scales it into its own clock's units."""
        return self.preempt.get((peer, step), 0.0)

    def fails_at(self, peer: int) -> Optional[int]:
        return self.fail_at.get(peer)


@dataclass
class VirtualClock:
    """Per-peer ready times over one shared simulated timeline."""
    now: float = 0.0
    ready_at: Dict[int, float] = field(default_factory=dict)

    def add_peer(self, peer: int, at: Optional[float] = None) -> None:
        self.ready_at[peer] = self.now if at is None else at

    def remove_peer(self, peer: int) -> None:
        self.ready_at.pop(peer, None)

    def next_ready(self) -> Tuple[float, Tuple[int, ...]]:
        """Advance to the earliest ready time; return it plus every peer
        ready within float tolerance of it (ties step together, which is what
        makes equal-speed clusters reproduce the synchronous schedule)."""
        if not self.ready_at:
            raise RuntimeError("no peers on the clock")
        t = min(self.ready_at.values())
        self.now = max(self.now, t)
        ready = tuple(sorted(p for p, r in self.ready_at.items()
                             if r <= t + 1e-9))
        return t, ready

    def advance(self, peer: int, by: float) -> None:
        self.ready_at[peer] = self.now + by


# ----------------------------------------------------------------------------
# CLI fault spec:  "straggler=1*4@0.2,preempt=1@3+5,fail=1@30,hetero=0.3"
# ----------------------------------------------------------------------------

def _num(text: str, kind, what: str, clause: str):
    """Parse one numeric field with an actionable error message."""
    try:
        return kind(text)
    except (TypeError, ValueError):
        raise ValueError(
            f"fault clause {clause!r}: {what} must be a"
            f"{'n integer' if kind is int else ' number'}, got {text!r}"
        ) from None


def _peer(text: str, n_peers: int, clause: str) -> int:
    p = _num(text, int, "peer index", clause)
    if p < 0:
        raise ValueError(f"fault clause {clause!r}: peer index {p} is "
                         "negative")
    if p >= n_peers:
        raise ValueError(f"fault clause {clause!r}: peer index {p} is out of "
                         f"range for n_peers={n_peers} (valid: 0.."
                         f"{n_peers - 1})")
    return p


def parse_faults(spec: str, n_peers: int, seed: int = 0) -> FaultConfig:
    """Parse the ``--faults`` flag into a :class:`FaultConfig`.

    Clauses (comma-separated; "none" or "" => no faults):
      straggler=P*F@FRAC   peer P runs F x slower for FRAC of its steps
      preempt=P@S+PAUSE    peer P pauses PAUSE sim-seconds after local step S
      fail=P@S             peer P dies permanently at local step S
      speeds=A:B:...       explicit per-peer base seconds-per-step
      hetero=SIGMA         lognormal per-peer speed jitter

    Malformed specs raise ``ValueError`` with the offending clause named:
    negative durations/steps, out-of-range or duplicated peers (overlapping
    windows on one peer), non-positive factors/speeds, unknown clause kinds.
    """
    kw: Dict = dict(n_peers=n_peers, seed=seed)
    stragglers, preempts, fails = [], [], []
    factors, fracs = [], []
    for clause in filter(None, (spec or "").split(",")):
        if clause == "none":
            continue
        key, _, val = clause.partition("=")
        if key == "straggler":
            head, _, fr = val.partition("@")
            p, _, f = head.partition("*")
            peer = _peer(p, n_peers, clause)
            if peer in stragglers:
                raise ValueError(
                    f"fault clause {clause!r}: peer {peer} already has a "
                    "straggler clause (episodes would silently overlap)")
            factor = _num(f, float, "slowdown factor", clause) if f else 4.0
            frac = _num(fr, float, "step fraction", clause) if fr else 0.2
            if factor <= 0:
                raise ValueError(f"fault clause {clause!r}: slowdown factor "
                                 f"{factor} must be > 0")
            if not 0.0 < frac <= 1.0:
                raise ValueError(f"fault clause {clause!r}: step fraction "
                                 f"{frac} must be in (0, 1]")
            stragglers.append(peer)
            factors.append(factor)
            fracs.append(frac)
        elif key == "preempt":
            p, _, rest = val.partition("@")
            s, _, pause = rest.partition("+")
            peer = _peer(p, n_peers, clause)
            step = _num(s, int, "step", clause)
            dur = _num(pause, float, "pause duration", clause) if pause else 5.0
            if step < 0:
                raise ValueError(f"fault clause {clause!r}: step {step} is "
                                 "negative")
            if dur <= 0:
                raise ValueError(f"fault clause {clause!r}: pause duration "
                                 f"{dur} must be > 0")
            if any(q == peer and t == step for q, t, _ in preempts):
                raise ValueError(
                    f"fault clause {clause!r}: peer {peer} already has a "
                    f"preemption at step {step} (overlapping windows on one "
                    "peer)")
            preempts.append((peer, step, dur))
        elif key == "fail":
            p, _, s = val.partition("@")
            peer = _peer(p, n_peers, clause)
            step = _num(s, int, "step", clause)
            if step < 0:
                raise ValueError(f"fault clause {clause!r}: step {step} is "
                                 "negative")
            if any(q == peer for q, _ in fails):
                raise ValueError(f"fault clause {clause!r}: peer {peer} "
                                 "already has a failure clause (it can only "
                                 "die once)")
            fails.append((peer, step))
        elif key == "speeds":
            speeds = tuple(_num(x, float, "speed", clause)
                           for x in val.split(":"))
            if any(sp <= 0 for sp in speeds):
                raise ValueError(f"fault clause {clause!r}: speeds must all "
                                 "be > 0")
            kw["speeds"] = speeds
        elif key == "hetero":
            sigma = _num(val, float, "sigma", clause)
            if sigma < 0:
                raise ValueError(f"fault clause {clause!r}: sigma {sigma} is "
                                 "negative")
            kw["speed_sigma"] = sigma
        else:
            raise ValueError(f"unknown fault clause {clause!r} (known: "
                             "straggler, preempt, fail, speeds, hetero)")
    # FaultConfig carries ONE global factor/frac for all straggler peers —
    # refuse conflicting per-peer values rather than silently overriding
    if len(set(factors)) > 1 or len(set(fracs)) > 1:
        raise ValueError(
            f"straggler clauses disagree on factor/frac ({factors}/{fracs}); "
            "FaultConfig supports one global straggler_factor/straggler_frac")
    return FaultConfig(straggler_peers=tuple(stragglers),
                       straggler_factor=factors[0] if factors else 4.0,
                       straggler_frac=fracs[0] if fracs else 0.2,
                       preemptions=tuple(preempts), failures=tuple(fails),
                       **kw)
