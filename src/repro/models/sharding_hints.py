"""Optional activation-sharding constraints (MaxText-style logical axes).

Model code is mesh-agnostic; the launcher opts in by calling
``set_activation_sharding(batch_axes, tp_axis)`` before tracing. When active,
``hint(x, kind)`` applies ``with_sharding_constraint`` to steer SPMD away from
pathological resharding (e.g. all-gathering the full fp32 logits tensor in the
lm-head backward). When inactive (unit tests, single device) it is a no-op.

Kinds: 'btd' (batch, seq, d_model), 'btv' (batch, seq, vocab->tp).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def set_activation_sharding(batch_axes: Optional[Tuple[str, ...]],
                            tp_axis: Optional[str],
                            tp_size: int = 0, mesh=None) -> None:
    _state.batch_axes = batch_axes
    _state.tp_axis = tp_axis
    _state.tp_size = tp_size
    _state.mesh = mesh


def clear_activation_sharding() -> None:
    _state.batch_axes = None
    _state.tp_axis = None
    _state.tp_size = 0
    _state.mesh = None


def current_mesh():
    return getattr(_state, "mesh", None)


@contextmanager
def activation_sharding(batch_axes, tp_axis, tp_size: int = 0, mesh=None):
    set_activation_sharding(batch_axes, tp_axis, tp_size, mesh)
    try:
        yield
    finally:
        clear_activation_sharding()


def _active() -> bool:
    return getattr(_state, "batch_axes", None) is not None or \
        getattr(_state, "tp_axis", None) is not None


def tensor_parallel_active() -> bool:
    """True while tracing under an activation-sharding context with a tensor
    -parallel axis (the lm-head/vocab dimension may be sharded)."""
    return getattr(_state, "tp_axis", None) is not None


def hint(x: jax.Array, kind: str) -> jax.Array:
    if not _active():
        return x
    batch_axes = getattr(_state, "batch_axes", None)
    tp = getattr(_state, "tp_axis", None)
    tp_size = getattr(_state, "tp_size", 0) or 1
    b = batch_axes if batch_axes else None
    if kind == "btd":
        spec = P(b, None, None)
    elif kind == "btd_carry":
        # residual stream between scanned blocks: shard d_model over tp
        # (Megatron sequence-parallel analogue) so the per-layer activations
        # saved for the backward pass cost 1/tp of HBM. XLA re-gathers at the
        # next layer's first matmul and reduce-scatters after the last.
        d = x.shape[-1]
        spec = P(b, None, tp if (d % tp_size == 0 and d >= tp_size) else None)
    elif kind == "btv":
        spec = P(b, None, tp)
    elif kind == "wire":
        # codistillation exchange payload, stacked over the model/pod axis:
        # (n, B, ...) — pin the stacked axis to "pod" so the cross-pod
        # collective moves THIS (compressed) tensor, not the raw logits.
        spec = P("pod", b, *([None] * (x.ndim - 2)))
        if len(spec) != x.ndim:
            return x
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            return x
    elif kind == "scores":
        # attention scores (B, H, S, T): shard heads over tp when divisible;
        # otherwise fall back to sequence parallelism over the query axis —
        # avoids the partitioner's "involuntary full rematerialization" (a
        # replicated multi-GB gather) for GQA head counts like 56 on tp=16.
        h, s = x.shape[-3], x.shape[-2]
        if h % tp_size == 0 and h >= tp_size:
            spec = P(b, tp, None, None)
        elif s % tp_size == 0 and s >= tp_size:
            spec = P(b, None, tp, None)
        else:
            return x
    else:
        return x
    if len(spec) != x.ndim:
        # stacked codist models: leading axis is pod-sharded by the param/batch
        # shardings already; pad with None on the left
        spec = P(*([None] * (x.ndim - len(spec)) + list(spec)))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
