"""Serving launcher: the continuous-batching fleet over codistilled peers.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --peers 2 --scenario bursty --requests 32 --slo-ms 50 \
        --router least_loaded

Runs a seeded open-loop workload (see ``repro.serve.fleet.workload``'s
scenario catalog) through N peer engines and prints the SLO report
(simulated-time latencies: bit-deterministic for a given seed). ``--report``
writes the full JSON report; ``--snapshot-dir`` points the router's
staleness-bounded weight refresh at ``checkpoint/io.py`` peer snapshots
(e.g. from ``--mode codist-async --checkpoint-every``). The legacy
single-engine batched-generate path lives behind ``--single``.

Chaos serving (docs/chaos.md): ``--faults`` takes the SAME spec syntax as
``repro.launch.train`` (``straggler=1*4@0.2,preempt=1@40+400,fail=1@60``;
pauses in simulated ms here) and injects it on the fleet's decode-tick
clock. Defenses are on by default when faults are injected — disable with
``--no-defend`` for the undefended baseline, add ``--hedge`` for hedged
dispatch, and flip ``--degraded-admission off`` to keep full queue bounds
under reduced capacity. ``--recover-after-ms`` revives failed peers from
their snapshots.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced, list_archs
from repro.models import build_model
from repro.runtime.clock import parse_faults
from repro.serve import Engine, resolve_cache_dtype
from repro.serve.fleet import (POLICIES, SCENARIOS, ChaosConfig, FleetConfig,
                               FleetDefense, FleetRouter, generate_workload)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-dtype", default="auto",
                    help="KV/state cache dtype: auto (bf16 on TPU, fp32 in "
                         "interpret mode), bf16, fp16, fp32, or a quantized "
                         "paged-pool dtype — int8, fp8/float8_e4m3fn "
                         "(fleet mode only; per-row fp32 scales, dequantized "
                         "inside the decode kernel)")
    ap.add_argument("--fused-attention", default="auto",
                    choices=("auto", "on", "off"),
                    help="decode attention path: the fused paged-attention "
                         "kernel (auto/on; Mosaic on TPU, interpret on CPU) "
                         "or the jnp gather+dense-softmax oracle (off)")
    ap.add_argument("--max-new", type=int, default=16)
    # ---- fleet mode ----
    ap.add_argument("--peers", type=int, default=2,
                    help="codistilled replicas behind the router")
    ap.add_argument("--scenario", default="steady", choices=list(SCENARIOS))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="TTFT SLO (simulated ms)")
    ap.add_argument("--router", default="round_robin", choices=list(POLICIES))
    ap.add_argument("--canary-every", type=int, default=0,
                    help="duplicate every k-th request to the next peer and "
                         "track distill_pair divergence (0: off)")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots per peer")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=128)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--snapshot-dir", default="",
                    help="poll checkpoint/io.py peer snapshots for "
                         "staleness-bounded weight refresh")
    ap.add_argument("--refresh-every-ms", type=float, default=0.0)
    ap.add_argument("--staleness-bound", type=int, default=0)
    # ---- speculative decoding (docs/serving.md) ----
    ap.add_argument("--speculative", action="store_true",
                    help="peer-speculative decoding: a codistilled partner "
                         "drafts k tokens, the target verifies them in one "
                         "batched forward — bit-identical to plain decode "
                         "at temperature 0 (sets --router speculative)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens proposed per speculative round")
    ap.add_argument("--draft-peer", default="ring",
                    help="'ring' pairs every peer with its neighbor (all "
                         "peers serve); an integer dedicates that peer to "
                         "drafting (excluded from the serving rotation)")
    ap.add_argument("--identical-peers", action="store_true",
                    help="init every peer from the SAME key — the "
                         "converged-codistillation limit (accept rate 1.0; "
                         "used by the spec-decode CI smoke)")
    # ---- chaos (docs/chaos.md) ----
    ap.add_argument("--faults", default="none",
                    help="seeded fault spec on the decode-tick clock, same "
                         "syntax as repro.launch.train (pauses in sim ms): "
                         "straggler=P*F@FRAC,preempt=P@T+PAUSE,fail=P@T,"
                         "hetero=SIGMA")
    ap.add_argument("--fault-horizon", type=int, default=4096,
                    help="fault-schedule realization horizon (decode ticks)")
    ap.add_argument("--recover-after-ms", type=float, default=0.0,
                    help="revive failed peers from their snapshot after this "
                         "much simulated time (0: stay dead)")
    ap.add_argument("--no-defend", action="store_true",
                    help="inject faults WITHOUT router defenses (the "
                         "undefended baseline)")
    ap.add_argument("--hedge", action="store_true",
                    help="hedged dispatch: run the slowest-decile requests "
                         "on two peers, first winner cancels the other")
    ap.add_argument("--degraded-admission", default="on",
                    choices=("on", "off"),
                    help="scale queue bounds with available capacity so a "
                         "shrunken fleet sheds at the edge")
    ap.add_argument("--report", default="", help="write the JSON report here")
    # ---- observability (docs/observability.md) ----
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto trace of the run here "
                         "(simulated-ms clock; bit-identical per seed)")
    ap.add_argument("--metrics", default="",
                    help="write the repro.obs metrics registry (counters/"
                         "gauges/histograms) as JSON here")
    ap.add_argument("--alerts", default="",
                    help="evaluate Watchtower alert rules over the live "
                         "metrics on the decode-tick clock and write the "
                         "alert JSONL here (bit-identical per seed)")
    ap.add_argument("--rules", default="",
                    help="JSON alert-rules file for --alerts (default: the "
                         "built-in rule pack, SLO taken from --slo-ms)")
    ap.add_argument("--flight-recorder", default="",
                    help="keep a bounded ring of recent trace events and "
                         "dump postmortem bundles into this directory on "
                         "every fired alert or injected fault "
                         "(requires --alerts)")
    # ---- legacy single-engine mode ----
    ap.add_argument("--single", action="store_true",
                    help="legacy path: one Engine.generate batch, no fleet")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    cache_dtype = resolve_cache_dtype(args.cache_dtype)

    if args.single:
        from repro.kernels.paged_cache import is_quantized_dtype
        if is_quantized_dtype(cache_dtype):
            ap.error(f"--cache-dtype {args.cache_dtype} is a quantized "
                     "paged-pool dtype: fleet mode only (drop --single)")
        if args.trace or args.metrics or args.alerts or args.flight_recorder:
            ap.error("--trace/--metrics/--alerts/--flight-recorder "
                     "instrument the fleet's simulated clock: fleet mode "
                     "only (drop --single)")
        return _single(args, cfg, model, cache_dtype)
    if cfg.is_encdec or cfg.num_patches or not hasattr(model, "decode"):
        import sys
        print(f"--arch {args.arch} is not token-only LM serving "
              "(enc-dec / VLM / vision): the fleet's workload generator "
              "drives text prompts only — use --single for the legacy "
              "batched-generate path", file=sys.stderr)
        sys.exit(2)

    if args.speculative:
        args.router = "speculative"
    spec = None
    if args.router == "speculative":
        from repro.serve.fleet import SpecConfig
        if args.draft_peer == "ring":
            draft_peer = None
        else:
            try:
                draft_peer = int(args.draft_peer)
            except ValueError:
                ap.error(f"--draft-peer {args.draft_peer!r}: expected "
                         "'ring' or a peer index")
            if not 0 <= draft_peer < args.peers:
                ap.error(f"--draft-peer {draft_peer} out of range for "
                         f"--peers {args.peers}")
        spec = SpecConfig(k=args.draft_k, draft_peer=draft_peer)
    if args.identical_peers:
        peer_params = [model.init(jax.random.key(args.seed))] * args.peers
    else:
        peer_params = [model.init(jax.random.key(args.seed + i))
                       for i in range(args.peers)]
    fc = FleetConfig(max_slots=args.slots, block_size=args.block_size,
                     num_blocks=args.num_blocks,
                     max_blocks_per_slot=max(
                         1, -(-(args.max_prompt + args.max_new)
                              // args.block_size)),
                     fused_attention={"auto": None, "on": True,
                                      "off": False}[args.fused_attention])
    chaos = defense = None
    if args.faults and args.faults != "none":
        chaos = ChaosConfig(
            parse_faults(args.faults, args.peers, seed=args.seed),
            horizon_ticks=args.fault_horizon,
            recover_after_ms=args.recover_after_ms)
    if (chaos is not None and not args.no_defend) or args.hedge:
        defense = FleetDefense(
            hedging=args.hedge,
            degraded_admission=(args.degraded_admission == "on"))
    if args.rules and not args.alerts:
        ap.error("--rules requires --alerts")
    if args.flight_recorder and not args.alerts:
        ap.error("--flight-recorder requires --alerts (bundles dump on "
                 "fired alerts and injected faults)")
    tracer = metrics = watch = recorder = None
    if args.trace or args.metrics or args.alerts:
        from repro.obs import MetricsRegistry, for_sim_ms
        # the flight recorder rides the tracer's event stream, so it
        # implies an internal tracer even without --trace; likewise
        # alerting implies an internal registry even without --metrics —
        # neither internal artifact is written to disk
        tracer = (for_sim_ms() if (args.trace or args.flight_recorder)
                  else None)
        metrics = (MetricsRegistry() if (args.metrics or args.alerts)
                   else None)
    if args.alerts:
        from repro.obs import (FlightRecorder, Watchtower, default_rules,
                               load_rules)
        rules = (load_rules(args.rules) if args.rules
                 else default_rules(slo_ms=args.slo_ms))
        watch = Watchtower(metrics, rules, unit_us=1000.0, clock="sim_ms")
        if args.flight_recorder:
            recorder = FlightRecorder(args.flight_recorder, metrics=metrics)
            tracer.recorder = recorder
            watch.on_alert(recorder.on_alert)
            watch.on_fault(recorder.on_fault)
    router = FleetRouter(model, peer_params, config=fc, policy=args.router,
                         cache_dtype=cache_dtype,
                         canary_every=args.canary_every,
                         snapshot_dir=args.snapshot_dir or None,
                         refresh_every_ms=args.refresh_every_ms,
                         staleness_bound=args.staleness_bound,
                         chaos=chaos, defense=defense,
                         tracer=tracer, metrics=metrics, watch=watch,
                         spec=spec)
    if recorder is not None:
        # postmortems carry the offending ids: live request/queue state per
        # peer at dump time (all simulated-clock state — deterministic)
        recorder.context_fn = lambda: {
            "peers": [
                {"peer": i, "dead": e.dead,
                 "now_ms": round(e.now_ms, 6),
                 "live_rids": sorted(sl.record.request.rid
                                     for sl in e.slots.values()),
                 "queued": len(e.waiting)}
                for i, e in enumerate(router.engines)]}
    if args.snapshot_dir:
        n = router.refresh_now()
        print(f"initial weight refresh: {n}/{args.peers} peers from "
              f"{args.snapshot_dir}")
    wl = generate_workload(args.scenario, args.requests, cfg.padded_vocab,
                           seed=args.seed, max_prompt=args.max_prompt,
                           max_new=args.max_new)
    t0 = time.time()
    rep = router.run(wl, slo_ms=args.slo_ms)
    wall = time.time() - t0
    print(f"arch={args.arch} scenario={args.scenario} router={args.router} "
          f"peers={args.peers} requests={args.requests} seed={args.seed}")
    print(f"completed={rep.completed} rejected={rep.rejected} "
          f"generated_tokens={rep.generated_tokens}")
    print(f"TTFT p50/p99 = {rep.p50_ttft_ms:.1f}/{rep.p99_ttft_ms:.1f} ms "
          f"(sim)  e2e p50/p99 = {rep.p50_e2e_ms:.1f}/{rep.p99_e2e_ms:.1f} ms")
    print(f"SLO({rep.slo_ms:.0f}ms TTFT) attainment = "
          f"{rep.slo_attainment:.3f}  sim tok/s = {rep.sim_tokens_per_s:.1f}"
          f"  wall tok/s = {rep.generated_tokens / max(wall, 1e-9):.1f}")
    print(f"pool peak util = {rep.peak_pool_utilization:.2f}  "
          f"kv_bytes = {rep.kv_bytes_written}  refreshes = {rep.refreshes} "
          f"(dropped stale: {rep.refreshes_dropped_stale})")
    if rep.canary.get("count"):
        print(f"canary: n={rep.canary['count']} "
              f"mean_mse={rep.canary['mean_mse']:.4f} "
              f"token_agreement={rep.canary['token_agreement']:.3f}")
    if spec is not None:
        print(f"speculative: k={spec.k} accept_rate="
              f"{rep.spec_accept_rate:.3f} rounds={rep.spec_rounds} "
              f"drafted/accepted = "
              f"{rep.spec_drafted_tokens}/{rep.spec_accepted_tokens}  "
              f"fallback_ticks={rep.spec_fallback_ticks}")
    if chaos is not None or defense is not None:
        print(f"chaos: defended={'no' if defense is None else 'yes'} "
              f"goodput tok/s = {rep.goodput_tokens_per_s:.1f}  "
              f"lost/dup tokens = {rep.lost_tokens}/{rep.duplicated_tokens}")
        print(f"  migrations={rep.migrations} "
              f"(failed: {rep.migration_failures})  hedges={rep.hedges} "
              f"(wins: {rep.hedge_wins})  preemptions={rep.preemptions}  "
              f"died/recovered={rep.peers_died}/{rep.peers_recovered}")
    print(f"stream digest = {rep.stream_digest}")
    if args.report:
        with open(args.report, "w") as f:
            f.write(rep.to_json() + "\n")
        print(f"wrote {args.report}")
    if tracer is not None and args.trace:
        tracer.save(args.trace)
        print(f"wrote {args.trace} ({tracer.n_events} trace events)")
    if metrics is not None and args.metrics:
        metrics.save(args.metrics)
        print(f"wrote {args.metrics}")
    if watch is not None:
        watch.save(args.alerts)
        s = watch.summary()
        print(f"wrote {args.alerts} ({s['n_events']} alert events; "
              f"still firing: {', '.join(s['firing']) or 'none'})")
    if recorder is not None:
        print(f"flight recorder: {len(recorder.dumped)} postmortem "
              f"bundle(s) in {args.flight_recorder}")


def _single(args, cfg, model, cache_dtype) -> None:
    params = model.init(jax.random.key(args.seed))
    engine = Engine(model, params, cache_dtype=cache_dtype)
    key = jax.random.key(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.padded_vocab)}
    if cfg.num_patches:
        batch["patches"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model))
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_audio_frames, cfg.d_model))
    t0 = time.time()
    result = engine.generate(batch, args.max_new, args.temperature, args.seed)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {dt / args.max_new * 1e3:.1f} ms/step)")
    print("first sequence:", result.tokens[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
