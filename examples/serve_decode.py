"""Serve a small model with batched requests: prefill once, decode in a
batched loop — exercising the KV-cache (dense), recurrent-state (rwkv) and
hybrid cache paths through the same Engine.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import Engine

for arch in ("qwen1.5-0.5b", "rwkv6-1.6b", "jamba-v0.1-52b"):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = Engine(model, params)
    b, prompt_len, new = 4, 32, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1),
                                          (b, prompt_len), 0,
                                          cfg.padded_vocab)}
    t0 = time.time()
    out = engine.generate(batch, max_new_tokens=new, temperature=0.8, seed=0)
    dt = time.time() - t0
    assert out.tokens.shape == (b, prompt_len + new)
    print(f"{arch:16s} {b} seqs x {new} new tokens in {dt:5.1f}s "
          f"({b * new / dt:6.1f} tok/s) sample: "
          f"{out.tokens[0, prompt_len:prompt_len + 8].tolist()}")
print("PASS")
