"""Deterministic metrics: counters, gauges, and fixed-bucket histograms
with exact quantiles.

This is the ONE implementation of percentile/quantile math in the repo —
the fleet's TTFT/e2e p50/p99, the chaos router's slowest-quantile hedging
threshold, and the runtime's staleness statistics all go through
:class:`Histogram`, replacing the ad-hoc ``np.percentile``/``np.quantile``
call sites that had drifted across modules. Quantiles are **exact** (linear
interpolation over the full retained sample, numerically identical to
``np.percentile``'s default method — the retained-sample sizes here are
simulation-scale, thousands not billions); the fixed buckets exist for the
exported distribution shape, not as an approximation of the quantiles.

Everything is a pure function of the observation stream, so a registry
export for a seeded run is bit-identical across reruns — metrics files are
CI-gateable artifacts exactly like traces and SLO reports.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.obs.fsio import atomic_write_text

METRICS_SCHEMA_VERSION = 1

# bounded per-gauge history retained for windowed alert rules (min/max over
# the last N sets). 64 samples cover every default rule window with room to
# spare while keeping the per-gauge footprint constant.
GAUGE_WINDOW = 64

Number = Union[int, float]

# default fixed bucket upper bounds for latency-like values (ms): roughly
# log-spaced, wide enough for both decode-tick costs and e2e latencies
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
    1000.0, 2000.0, 5000.0, 10000.0)


class Counter:
    """Monotonically accumulating value (int-exact when fed ints)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Number = 0

    def inc(self, amount: Number = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter increment {amount} is negative")
        self.value += amount

    def to_dict(self) -> Number:
        return self.value


class Gauge:
    """Last-set value, plus a bounded window of recent sets.

    The export (``to_dict``) is still just the last value — the gated
    metrics artifacts did not move — but alert rules windowing over a
    gauge (burn-rate, drift) need more than the final sample, so the last
    ``GAUGE_WINDOW`` sets are retained deterministically.
    """

    __slots__ = ("value", "_hist")

    def __init__(self) -> None:
        self.value: Number = 0
        self._hist: Deque[float] = deque(maxlen=GAUGE_WINDOW)

    def set(self, value: Number) -> None:
        self.value = value
        self._hist.append(float(value))

    def window(self, n: int = GAUGE_WINDOW) -> List[float]:
        """The last ``min(n, GAUGE_WINDOW)`` set values, oldest first."""
        if n <= 0:
            raise ValueError(f"gauge window size {n} must be positive")
        return list(self._hist)[-n:]

    def window_min(self, n: int = GAUGE_WINDOW) -> float:
        w = self.window(n)
        return min(w) if w else 0.0

    def window_max(self, n: int = GAUGE_WINDOW) -> float:
        w = self.window(n)
        return max(w) if w else 0.0

    def to_dict(self) -> Number:
        return self.value


class Histogram:
    """Fixed-bucket histogram that also retains the exact sample.

    ``percentile(q)`` (q in [0, 100]) and ``quantile(q)`` (q in [0, 1])
    reproduce ``np.percentile`` / ``np.quantile`` bit-for-bit on the
    observation stream — the call sites this class replaced used those
    directly, and the bit-identical CI gates (SLO reports, bench rows)
    must not move.
    """

    __slots__ = ("buckets", "bucket_counts", "values", "_sum", "name")

    def __init__(self, buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                 name: str = ""):
        if list(buckets) != sorted(buckets):
            raise ValueError(f"bucket bounds must be sorted: {buckets}")
        self.buckets = tuple(float(b) for b in buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +overflow
        self.values: List[float] = []
        self._sum = 0.0
        self.name = name

    def observe(self, value: Number) -> None:
        v = float(value)
        self.values.append(v)
        self._sum += v
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return self._sum

    def _require_samples(self, what: str) -> None:
        if not self.values:
            label = self.name or "histogram"
            raise ValueError(
                f"{what} of empty histogram {label!r}: no observations were "
                f"recorded — guard the call with `if h.count` or observe a "
                f"sample first")

    def percentile(self, q: float) -> float:
        """Exact percentile (q in [0, 100]); raises a ``ValueError`` naming
        the metric on an empty histogram (a quantile of nothing is a bug at
        the call site, not a zero)."""
        self._require_samples(f"percentile({q:g})")
        return float(np.percentile(np.asarray(self.values), q))

    def quantile(self, q: float) -> float:
        """Exact quantile (q in [0, 1]) over the float64 sample — the
        hedging-threshold convention it replaced. Raises ``ValueError``
        naming the metric when empty."""
        self._require_samples(f"quantile({q:g})")
        return float(np.quantile(np.asarray(self.values, np.float64), q))

    def to_dict(self) -> Dict:
        empty = not self.values
        d: Dict = {
            "count": self.count,
            "sum": self._sum,
            "min": min(self.values) if self.values else 0.0,
            "max": max(self.values) if self.values else 0.0,
            "p50": 0.0 if empty else self.percentile(50),
            "p90": 0.0 if empty else self.percentile(90),
            "p99": 0.0 if empty else self.percentile(99),
            "buckets": {},
        }
        for i, b in enumerate(self.buckets):
            d["buckets"][f"le_{b:g}"] = self.bucket_counts[i]
        d["buckets"]["le_inf"] = self.bucket_counts[-1]
        return d


class MetricsRegistry:
    """Named counters/gauges/histograms with a deterministic export.

    Get-or-create accessors: ``registry.counter("fleet/decode_tokens")``
    returns the same object every call. Names are free-form; the repo's
    convention is ``<subsystem>/<metric>`` (docs/observability.md lists
    what each subsystem emits).
    """

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter()
        return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge()
        return self._gauges[name]

    def histogram(self, name: str,
                  buckets: Optional[Tuple[float, ...]] = None) -> Histogram:
        if name not in self._histograms:
            self._histograms[name] = Histogram(buckets or DEFAULT_BUCKETS,
                                               name=name)
        return self._histograms[name]

    def peek(self, name: str):
        """Non-creating lookup: the named counter/gauge/histogram, or
        ``None``. Alert rules use this so watching a metric that a run
        never emits does not materialize an empty stream in the export."""
        if name in self._counters:
            return self._counters[name]
        if name in self._gauges:
            return self._gauges[name]
        if name in self._histograms:
            return self._histograms[name]
        return None

    def to_dict(self) -> Dict:
        return {
            "schema_version": METRICS_SCHEMA_VERSION,
            "counters": {k: c.to_dict()
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.to_dict()
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self._histograms.items())},
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=1)

    def save(self, path: str) -> None:
        atomic_write_text(path, self.to_json() + "\n")
