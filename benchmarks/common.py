"""Shared helpers for the benchmark suite (one module per paper artifact).

Every benchmark prints ``name,us_per_call,derived`` CSV rows (us_per_call is
the wall time of the benchmark's core computation; ``derived`` carries the
paper-relevant quantity: a ratio, an accuracy, a loss gap...).
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.data import MarkovLM, make_lm_batch
from repro.models import build_model
from repro.train import stack_batches


def tiny_lm_cfg(vocab=64, d=64, layers=2):
    return replace(get_reduced("qwen1.5-0.5b"), num_layers=layers, d_model=d,
                   d_ff=2 * d, vocab_size=vocab, num_heads=2, num_kv_heads=2,
                   head_dim=32)


def lm_setup(vocab=64, seed=0):
    cfg = tiny_lm_cfg(vocab)
    return build_model(cfg), MarkovLM(vocab=vocab, seed=seed)


def coord_batches(task, n, b, s, seed=0):
    def fn(step):
        return stack_batches([make_lm_batch(task, b, s, step, None, seed=seed)
                              for _ in range(n)])
    return fn


def indep_batches(task, n, b, s, seed=0):
    def fn(step):
        return stack_batches([make_lm_batch(task, b, s, step, g, seed=seed)
                              for g in range(n)])
    return fn


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kw):
    def _sync(o):
        leaves = [x for x in jax.tree.leaves(o)
                  if isinstance(x, jax.Array)]
        if leaves:
            jax.block_until_ready(leaves[0])

    for _ in range(warmup):
        _sync(fn(*args, **kw))
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn(*args, **kw)
    _sync(out)
    return out, (time.perf_counter() - t0) / iters * 1e6


def emit(rows: List[Dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},{r['derived']}")
