from repro.data.synthetic import (  # noqa: F401
    MarkovLM,
    classification_batch,
    lm_batch_iterator,
    make_lm_batch,
)
from repro.data.multiview import MultiViewTask, multiview_batch  # noqa: F401
