"""Synthetic multi-view task for the Section-5.1 n-way codistillation study.

The paper constructs multi-view structure by freezing a pretrained bottleneck
and splitting its channels into 8 views. We reproduce the *structure* directly:
each sample has ``n_views`` feature groups; EVERY view alone is predictive of
the label (view v ~ N(mu_v[y], noise)), but the per-view class centroids are
independent — so models restricted to different views learn genuinely distinct
features, which is exactly the multi-view hypothesis's premise.

Three scenarios map onto the paper's Fig. 6 groups:
  * "enforced views"  — model i sees only view (i mod n_views) throughout
                        training (the 'pretrained, frozen' group);
  * "shared view"     — all models see the same single view ('random init'
                        group: no diversity available);
  * "all views"       — upper bound (the unsplit pretrained model).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MultiViewTask:
    """Views are noisy random PROJECTIONS of one shared class-conditioned
    latent (the analogue of channel splits of a frozen pretrained bottleneck):
    each view alone is partially predictive, views are mutually correlated
    through the latent, and only their union approaches the Bayes rate."""
    n_views: int = 8
    view_dim: int = 8
    latent_dim: int = 24
    num_classes: int = 10
    latent_noise: float = 1.0
    noise: float = 1.0           # per-view observation noise
    seed: int = 0

    @property
    def dim(self) -> int:
        return self.n_views * self.view_dim

    def _gen(self):
        key = jax.random.key(self.seed)
        kc, kp = jax.random.split(key)
        centroids = jax.random.normal(
            kc, (self.num_classes, self.latent_dim)) * 1.5
        # (n_views, latent_dim, view_dim) random projections
        proj = jax.random.normal(
            kp, (self.n_views, self.latent_dim, self.view_dim))
        proj = proj / jnp.linalg.norm(proj, axis=1, keepdims=True)
        return centroids, proj

    def sample(self, key: jax.Array, batch: int) -> Dict[str, jax.Array]:
        centroids, proj = self._gen()
        ky, kz, kx = jax.random.split(key, 3)
        labels = jax.random.randint(ky, (batch,), 0, self.num_classes)
        z = centroids[labels] + self.latent_noise * jax.random.normal(
            kz, (batch, self.latent_dim))
        views = jnp.einsum("bl,vld->vbd", z, proj)          # (V, B, view_dim)
        views = views + self.noise * jax.random.normal(kx, views.shape)
        feats = jnp.moveaxis(views, 0, 1).reshape(batch, self.dim)
        return {"features": feats, "labels": labels}

    def view_mask(self, view: int) -> jax.Array:
        """(dim,) 0/1 mask exposing only one view — multiplied into features."""
        m = jnp.zeros((self.dim,))
        return m.at[view * self.view_dim:(view + 1) * self.view_dim].set(1.0)


def multiview_batch(task: MultiViewTask, batch: int, step: int,
                    seed: int = 0) -> Dict[str, jax.Array]:
    key = jax.random.fold_in(jax.random.key(seed), step)
    return task.sample(key, batch)
