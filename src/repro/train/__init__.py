from repro.train.engine import (  # noqa: F401
    AllReduce,
    AsyncPrediction,
    CheckpointExchange,
    ExchangeStrategy,
    PipelinedPredictions,
    PredictionExchange,
    STRATEGIES,
    ShardMapCompressed,
    StepBundle,
    build_train_step,
    make_codist_eval_step,
    make_eval_step,
    make_schedules,
    refresh_stale,
    resolve_strategy,
)
from repro.train.loop import (  # noqa: F401
    History,
    stack_batches,
    train,
    train_allreduce,
    train_codist,
)
from repro.train.state import (  # noqa: F401
    CodistState,
    TrainState,
    init_codist_state,
    init_peer_state,
    init_train_state,
)
# Deprecated step factories (repro.train.steps) resolve lazily so merely
# importing repro.train stays warning-free; touching one emits the steps
# module's DeprecationWarning.
_DEPRECATED_STEPS = ("make_allreduce_step", "make_codist_checkpoint_step",
                     "make_codist_pipelined_step", "make_codist_step")


def __getattr__(name: str):
    if name in _DEPRECATED_STEPS:
        from repro.train import steps
        return getattr(steps, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
