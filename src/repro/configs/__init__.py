"""Architecture config registry.

``get_config(arch_id)`` / ``get_reduced(arch_id)`` resolve the ids used by
``--arch`` on every launcher. The ten assigned architectures plus the paper's
own workloads are all registered here.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

# arch-id -> module path (module must export CONFIG and reduced())
_REGISTRY: Dict[str, str] = {
    # --- assigned pool (10) ---
    "deepseek-67b": "repro.configs.deepseek_67b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "arctic-480b": "repro.configs.arctic_480b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    # --- paper's own workloads ---
    "transformer-big": "repro.configs.transformer_big",
    "resnet50": "repro.configs.resnet50",
    "wrn28x10": "repro.configs.wrn28_10",
}

ASSIGNED_ARCHS: List[str] = [
    "deepseek-67b", "qwen2-7b", "internvl2-76b", "qwen1.5-0.5b", "arctic-480b",
    "jamba-v0.1-52b", "grok-1-314b", "qwen1.5-4b", "whisper-tiny", "rwkv6-1.6b",
]


def list_archs() -> List[str]:
    return list(_REGISTRY)


def get_config(arch: str):
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch]).CONFIG


def get_reduced(arch: str):
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch]).reduced()


from repro.configs.base import (  # noqa: E402,F401  (re-exports)
    CodistConfig,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
    TrainConfig,
    reduced,
)
