"""Figure 1: accuracy-communication trade-off.

Two sources, cross-validated:
  (a) the Section-3 analytical model with the paper's exact ResNet50 numbers
      (b_model=8e8 bits, b_pred=3.2e4, B=256) — reproduces the headline
      "~1000x fewer bits at T=5";
  (b) the compiled multi-pod dry-run HLO: cross-pod collective bytes per step
      for the codistillation step vs the all_reduce baseline step, parsed
      from replica groups (the TPU-native measurement of the same claim).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.core import comm_model as cm


def analytic_rows() -> List[Dict]:
    n = cm.paper_resnet50_numbers()
    rows = [{"name": "fig1/allreduce_bits", "derived": n["all_reduce"]}]
    for t in (1, 5, 10, 100):
        rows.append({"name": f"fig1/pred_T{t}_ratio",
                     "derived": round(n[f"pred_T{t}_ratio"], 1)})
    for t in (625, 1250, 2500, 5000):
        rows.append({"name": f"fig1/ckpt_T{t}_ratio",
                     "derived": round(n[f"ckpt_T{t}_ratio"], 1)})
    return rows


def hlo_rows(dryrun_dir: str = "results/dryrun") -> List[Dict]:
    """Cross-pod bytes: codist vs allreduce from the multi-pod dry-run."""
    rows: List[Dict] = []
    path_c = os.path.join(dryrun_dir, "dryrun_multi_auto.json")
    path_a = os.path.join(dryrun_dir, "dryrun_multi_allreduce.json")
    coll: Dict[str, Dict[str, float]] = {}
    for path, tag in ((path_c, "codist"), (path_a, "allreduce")):
        if not os.path.exists(path):
            continue
        for r in json.load(open(path)):
            if r.get("status") != "ok" or r.get("shape") != "train_4k":
                continue
            mode = r.get("mode", tag)
            key = r["arch"]
            coll.setdefault(key, {})[mode] = \
                r["cost_corrected"]["cross_pod_bytes"] \
                if r.get("cost_corrected") else \
                r["collectives"]["cross_pod_bytes"]
    for arch, d in sorted(coll.items()):
        for mode, b in sorted(d.items()):
            rows.append({"name": f"fig1/hlo_crosspod_{arch}_{mode}",
                         "derived": f"{b:.3e}"})
        if "codist" in d and "allreduce" in d and d["codist"] > 0:
            rows.append({"name": f"fig1/hlo_ratio_{arch}",
                         "derived": round(d["allreduce"] / d["codist"], 2)})
    return rows


def run(quick: bool = False) -> List[Dict]:
    return analytic_rows() + hlo_rows()
