"""Fused paged-attention decode Pallas kernel: block tables straight into
flash-attention-style streaming softmax.

The jnp decode path in ``repro.serve.fleet.model_exec`` makes two full
passes over every slot's context: ``paged_gather`` materializes a dense
``(S, MB*BS, KVh, hd)`` copy of the pool, then the scores/softmax read it
all again. This kernel consumes the block table directly, so that gather
temporary never exists and each live KV block is read exactly once:

  grid (S, KVh, MB), KV blocks innermost. Program (s, k, m) DMAs pool
  block ``table[s, m]`` (scalar-prefetched, like ``paged_cache`` — dead
  entries alias the all-zero null block 0) and folds it into the canonical
  online-softmax state (running max ``m``, denominator ``l``, accumulator
  ``acc`` — the same machinery as ``kernels/flash_attention``), carried in
  VMEM scratch across the innermost grid steps. Blocks at or past
  ``n_live[s]`` are skipped entirely (``pl.when``), positions past the
  slot's own length are masked to ``NEG`` in-tile (per-slot vector
  positions: every slot decodes at its OWN absolute position), and GQA maps
  the ``G = H // KVh`` query heads of group ``k`` onto KV head ``k`` via
  the BlockSpec index maps.

Quantized pools (int8 / fp8, see ``paged_cache.quantize_rows``) carry one
fp32 scale per stored token row alongside the pool; the kernel dequantizes
inside the inner loop (``k * scale[row]`` on the VMEM-resident tile), so
quantization shrinks HBM traffic without a dequantized copy ever hitting
HBM.

Interpret mode on CPU, Mosaic on TPU (``auto_interpret``), with the jnp
oracle ``paged_attention_decode_ref`` pinned against the kernel in
tests/test_paged_attention.py (<=1e-4 at fp32 cache dtype; see
docs/serving.md for the quantized tolerances).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(table_ref, len_ref, nlive_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, acc_ref, *,
                   scale: float, block_size: int, n_m: int):
    _decode_body(None, None, table_ref, len_ref, nlive_ref, q_ref, k_ref,
                 v_ref, o_ref, m_ref, l_ref, acc_ref, scale=scale,
                 block_size=block_size, n_m=n_m)


def _decode_kernel_quant(table_ref, len_ref, nlive_ref, q_ref, k_ref, v_ref,
                         ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                         scale: float, block_size: int, n_m: int):
    _decode_body(ks_ref, vs_ref, table_ref, len_ref, nlive_ref, q_ref, k_ref,
                 v_ref, o_ref, m_ref, l_ref, acc_ref, scale=scale,
                 block_size=block_size, n_m=n_m)


def _decode_body(ks_ref, vs_ref, table_ref, len_ref, nlive_ref, q_ref, k_ref,
                 v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_size: int, n_m: int):
    si, mi = pl.program_id(0), pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mi < nlive_ref[si])
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (BS, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if ks_ref is not None:                            # dequant in-loop
            k = k * ks_ref[...].T                         # (BS, 1) scales
            v = v * vs_ref[...].T
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, BS)
        pos = (jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
               + mi * block_size)
        s = jnp.where(pos <= len_ref[si], s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(mi == n_m - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_decode(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, table: jax.Array,
                           lengths: jax.Array,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """One-token decode for every slot, straight off the block pool.

    q (S, H, hd): the new token's (rope'd) query per slot; k_pool / v_pool
    (NB, BS, KVh, hd): the pools AFTER this step's scatter (the new token's
    KV is in its block); table (S, MB) int32; lengths (S,) int32 = each
    slot's pre-step context length == the new token's absolute position
    (valid keys are positions <= lengths[s]); k_scale / v_scale (NB, BS)
    fp32 per-row dequant scales for quantized pools (both or neither).
    Returns (S, H, hd) attention outputs in q's dtype.
    """
    if interpret is None:
        from repro.kernels.ops import auto_interpret
        interpret = auto_interpret()
    s, h, hd = q.shape
    nb, bs, kvh, _ = k_pool.shape
    mb = table.shape[1]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "pass both scales or neither"
    n_live = (lengths.astype(jnp.int32) + bs) // bs   # blocks incl. new token

    pool_spec = pl.BlockSpec((1, bs, 1, hd),
                             lambda si, ki, mi, t, le, nl: (t[si, mi], 0, ki, 0))
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda si, ki, mi, t, le, nl: (si, ki, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    operands = [q.reshape(s, kvh, g, hd), k_pool, v_pool]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, bs), lambda si, ki, mi, t, le, nl: (t[si, mi], 0))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
        kernel = _decode_kernel_quant
    else:
        kernel = _decode_kernel
    out = pl.pallas_call(
        functools.partial(kernel, scale=hd ** -0.5, block_size=bs, n_m=mb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(s, kvh, mb),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda si, ki, mi, t, le, nl: (si, ki, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), n_live,
      *operands)
    return out.reshape(s, h, hd)


def paged_attention_decode_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, table: jax.Array,
                               lengths: jax.Array,
                               k_scale: Optional[jax.Array] = None,
                               v_scale: Optional[jax.Array] = None
                               ) -> jax.Array:
    """jnp oracle: gather the dense context, dense fp32 masked softmax."""
    from repro.kernels.paged_cache import paged_gather_ref
    s, h, hd = q.shape
    _, bs, kvh, _ = k_pool.shape
    g = h // kvh
    n_live = (lengths.astype(jnp.int32) + bs) // bs
    k = paged_gather_ref(k_pool.astype(jnp.float32), table, n_live)
    v = paged_gather_ref(v_pool.astype(jnp.float32), table, n_live)
    if k_scale is not None:
        ks = paged_gather_ref(k_scale[..., None, None].astype(jnp.float32),
                              table, n_live)          # (S, MB*BS, 1, 1)
        vs = paged_gather_ref(v_scale[..., None, None].astype(jnp.float32),
                              table, n_live)
        k, v = k * ks, v * vs
    qf = q.reshape(s, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("skgd,stkd->skgt", qf, k) * hd ** -0.5
    pos = jnp.arange(k.shape[1])
    valid = pos[None, :] <= lengths[:, None]          # (S, T)
    scores = jnp.where(valid[:, None, None, :], scores, NEG)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("skgt,stkd->skgd", w, v)
    return out.reshape(s, h, hd).astype(q.dtype)
