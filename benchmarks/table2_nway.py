"""Table 2: n-way codistillation at EQUAL updates per model can help on some
workloads (IWSLT in the paper). Here: the multi-view synthetic task where
gains are expected (each model gets its own view), n in {1,2,4,8}."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import CodistConfig, TrainConfig
from repro.models.mlp import MLP, MLPConfig
from repro.train import train_codist

from benchmarks.common import timed
from benchmarks.fig6_multiview import TASK, _batches, _eval_acc


def run(quick: bool = False) -> List[Dict]:
    steps = 80 if quick else 250
    model = MLP(MLPConfig(in_dim=TASK.dim, hidden=(128, 128),
                          num_classes=TASK.num_classes))
    tc = TrainConfig(lr=3e-3, total_steps=steps, warmup_steps=5,
                     optimizer="adamw", lr_schedule="cosine", seed=0)
    rows: List[Dict] = []
    accs = {}
    for n in (1, 2, 4, 8):
        codist = CodistConfig(n_models=n, alpha0=2.0 if n > 1 else 0.0,
                              distill_loss="kl")
        (state, _), us = timed(
            lambda n=n, cd=codist: train_codist(model, cd, tc,
                                                _batches(n, "enforced"),
                                                log_every=steps - 1),
            warmup=0, iters=1)
        acc = _eval_acc(model, state, n, "enforced")
        accs[n] = acc
        rows.append({"name": f"table2/enforced_views_n{n}",
                     "us_per_call": us, "derived": round(acc, 4)})
    rows.append({"name": "table2/nway_improves_with_views",
                 "derived": int(accs[8] > accs[1])})
    return rows
