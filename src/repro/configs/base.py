"""Config dataclasses for models, codistillation, meshes and input shapes.

Every assigned architecture gets a module ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact assigned full-scale config) and ``reduced()`` (a smoke-test
variant: <=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    # period (in layers) at which FFN blocks are MoE; 1 => every layer.
    layer_period: int = 1
    # Arctic-style dense FFN residual running in parallel with the experts.
    dense_residual: bool = False
    # weight of the auxiliary load-balance loss (Switch-style)
    load_balance_weight: float = 0.01
    # router jitter for training (disabled in eval/decode)
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM parameters (used by hybrid archs)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) parameters."""
    head_dim: int = 64
    # low-rank sizes for the data-dependent decay / token-shift mixers
    decay_lora: int = 64
    mix_lora: int = 32


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio | conv
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"  # silu => SwiGLU, gelu => plain GeLU MLP
    # attention variant: 0 => full causal; >0 => sliding window of that size
    sliding_window: int = 0
    # hybrid (jamba): one attention layer every `attn_layer_period` layers (rest Mamba);
    # 0 => all layers are attention (or all SSM for family=="ssm").
    attn_layer_period: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    num_audio_frames: int = 1500  # whisper stub frontend output length
    # --- vlm ---
    num_patches: int = 0  # >0 => vision-prefix stub of this many patch embeddings
    # --- numerics / misc ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    vocab_pad_multiple: int = 256
    max_position: int = 1 << 20
    source: str = ""  # citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for decoder layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_layer_period > 0:
            # jamba: layer (period-1), (2*period-1)... are attention; rest mamba
            return "attn" if (i % self.attn_layer_period) == (self.attn_layer_period - 1) else "ssm"
        return "attn"

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.layer_period) == (self.moe.layer_period - 1)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), used by the comm model."""
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        n = 0
        n += v * d  # token embedding
        if not self.tie_embeddings:
            n += v * d  # lm head
        def attn_params() -> int:
            p = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (self.num_heads * hd) * d
            if self.qkv_bias:
                p += (self.num_heads + 2 * self.num_kv_heads) * hd
            return p
        def dense_ffn(dff: int) -> int:
            mult = 3 if self.act in ("silu", "geglu") else 2
            return mult * d * dff
        def moe_ffn() -> int:
            m = self.moe
            p = m.num_experts * dense_ffn(self.d_ff) + d * m.num_experts
            if m.dense_residual:
                p += dense_ffn(self.d_ff)
            return p
        def ssm_params() -> int:
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            dt_rank = s.dt_rank or -(-d // 16)
            p = d * 2 * d_in            # in_proj
            p += d_in * s.d_conv        # depthwise conv
            p += d_in * (dt_rank + 2 * s.d_state)  # x_proj
            p += dt_rank * d_in + d_in  # dt_proj
            p += d_in * s.d_state + d_in  # A_log, D
            p += d_in * d               # out_proj
            return p
        def rwkv_params() -> int:
            r = self.rwkv or RWKVConfig()
            p = 4 * d * d + d * d       # r,k,v,o + gate
            p += r.decay_lora * d * 2 + d  # decay lora + base
            p += 5 * (d * r.mix_lora + r.mix_lora * d)  # token-shift mixers
            p += 2 * d * self.d_ff      # channel mix (k,v) -- rwkv ffn
            p += d * d                  # channel mix receptance
            return p
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if self.family == "ssm":
                n += rwkv_params() if self.rwkv is not None else ssm_params()
            elif kind == "ssm":
                n += ssm_params()
            else:
                n += attn_params()
            if self.family != "ssm" or self.rwkv is None:
                n += moe_ffn() if self.is_moe_layer(i) else dense_ffn(self.d_ff)
            n += 2 * d  # norms
        enc_d = self.d_model
        for _ in range(self.encoder_layers):
            n += attn_params() + dense_ffn(self.d_ff) + 2 * enc_d
            n += attn_params()  # decoder cross-attention (approx bookkeeping)
        return n


@dataclass(frozen=True)
class CodistConfig:
    """Algorithm 1 + Section 3 implementation options."""
    n_models: int = 2
    # 'predictions' (coordinated sampling, logits all-gather) or 'checkpoints'
    mode: str = "predictions"
    # communicate every T steps; off-steps drop the distillation term (predictions)
    # or reuse the stale replica (checkpoints).
    period: int = 1
    # distillation loss D: 'mse' (paper's experiments), 'kl', or 'ce'
    distill_loss: str = "mse"
    # penalty coefficient schedule: alpha^k = alpha0 * growth^(epoch k)
    alpha0: float = 1.0
    alpha_growth: float = 1.0  # paper: 1.0 vision, 1.1/epoch NMT
    steps_per_epoch: int = 1
    # warm-up steps before the distillation term switches on (Anil et al. burn-in)
    burn_in_steps: int = 0
    # ---- beyond-paper exchange compression ----
    # 'none' | 'topk' | 'bf16' | 'subsample'
    compression: str = "none"
    topk: int = 64
    subsample: int = 0  # tokens per sequence used for the distill term
    # beyond-paper: use previous step's peer logits (removes the sync point)
    pipelined: bool = False


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 1e-3
    lr_schedule: str = "cosine"  # 'step' | 'cosine' | 'constant'
    warmup_steps: int = 100
    total_steps: int = 1000
    step_milestones: Tuple[float, ...] = (0.5, 0.75, 0.9)  # fractions of total
    step_decay: float = 0.1
    weight_decay: float = 1e-4
    # paper: decay WD at LR milestones (5e-4 -> 1e-5 -> 0) to counter codist regularization
    weight_decay_schedule: Tuple[float, ...] = ()
    label_smoothing: float = 0.0
    label_smoothing_decay: bool = False
    optimizer: str = "sgdm"  # 'sgdm' | 'adamw'
    momentum: float = 0.9
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    grad_clip: float = 0.0
    seed: int = 0
    microbatch: int = 0  # 0 => no gradient accumulation
    remat: bool = False
    opt_dtype: str = "float32"    # optimizer moment buffers
    accum_dtype: str = "float32"  # microbatch gradient accumulators
    # Dispatch every training-step loss (task CE + distill D) through the
    # custom-VJP Pallas kernels in repro.kernels.ops instead of the jnp
    # paths that materialize (T, V) fp32 temporaries. None => auto: on for
    # TPU (Mosaic), off on CPU — where forcing True runs the kernels in
    # interpret mode via auto_interpret() (slow; validation only).
    fused_losses: Optional[bool] = None


def reduced(cfg: ModelConfig, **overrides: Any) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    kw: dict[str, Any] = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 256),
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        max_position=65536,
        dtype="float32",
    )
    hd = 32
    heads = max(2, min(4, cfg.num_heads))
    kv = heads if cfg.num_kv_heads >= cfg.num_heads else max(1, heads // 2)
    kw.update(num_heads=heads, num_kv_heads=kv, head_dim=hd)
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, num_experts=min(4, cfg.moe.num_experts))
    if cfg.attn_layer_period:
        kw["attn_layer_period"] = 2
        kw["num_layers"] = 2  # 1 ssm + 1 attn
    if cfg.rwkv is not None:
        kw["rwkv"] = replace(cfg.rwkv, head_dim=32, decay_lora=16, mix_lora=8)
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["num_audio_frames"] = 64
    if cfg.num_patches:
        kw["num_patches"] = 16
    if cfg.sliding_window:
        kw["sliding_window"] = min(cfg.sliding_window, 64)
    kw.update(overrides)
    return replace(cfg, **kw)
