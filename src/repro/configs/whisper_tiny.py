"""whisper-tiny [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

4L (enc) + 4L (dec), d_model=384 6H (kv=6) d_ff=1536 vocab=51865 (padded to 51968).
The mel-spectrogram + conv feature extractor is a STUB per the carve-out:
``input_specs()`` provides precomputed frame embeddings (B, 1500, 384).

Shape-coverage note: skips long_500k (quadratic enc-dec attention, 448-position
decoder class); see DESIGN.md.
"""
from repro.configs.base import ModelConfig, reduced as _reduced

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    encoder_layers=4,
    num_audio_frames=1500,
    source="Whisper tiny [arXiv:2212.04356]",
)


def reduced():
    return _reduced(CONFIG)
