"""Paper-grid experiment harness (docs/experiments.md).

A :class:`SweepSpec` declares the paper's grid — {batch} x {LR schedule}
x {exchange mode} x {alpha schedule} x {peers} x {seeds} — ``run_sweep``
executes it through the unified engine / async runtime with crash-safe
per-cell persistence, and ``aggregate`` reduces the results into the
paper-style tables CI gates on.
"""
from repro.experiments.aggregate import (  # noqa: F401
    QUALITY_FACTORS,
    aggregate,
    aggregate_and_write,
    comm_to_quality,
    load_summaries,
    render_markdown,
    write_outputs,
)
from repro.experiments.runner import (  # noqa: F401
    CellResult,
    cell_paths,
    load_summary,
    run_cell,
    run_sweep,
    summary_is_valid,
    sweep_dir_for,
)
from repro.experiments.spec import (  # noqa: F401
    ASYNC_MODES,
    AlphaPoint,
    Cell,
    KNOWN_MODES,
    LRPoint,
    NONE_ALPHA,
    SYNC_MODES,
    TINY_OVERRIDES,
    SweepSpec,
    cell_to_dict,
    load_spec,
    spec_from_dict,
    spec_to_dict,
)
