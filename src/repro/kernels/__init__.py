"""Pallas TPU kernels for the compute hot spots, validated in interpret mode.

  fused_ce        — streaming cross-entropy over vocab tiles (no (T,V) temps)
  distill_loss    — streaming codistillation D(y, y') (mse / kl)
  flash_attention — online-softmax GQA attention (causal / sliding window)

Each has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in
``ops.py`` (auto interpret on CPU, Mosaic on TPU).
"""
from repro.kernels.ops import (  # noqa: F401
    attention,
    auto_interpret,
    cross_entropy_tokens,
    distill_loss_tokens,
)
