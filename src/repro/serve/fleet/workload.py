"""Seeded open-loop request generator: Poisson arrivals over scenario rate
curves, mixed prompt/output-length distributions.

Open-loop means arrivals do not wait for completions (the production regime
that stresses admission control); everything is driven by one
``np.random.Generator(PCG64(seed))`` so a (scenario, seed, n) triple always
yields the byte-identical request list — the determinism the CI serve-smoke
and the fleet benchmark rows gate on. Time-varying rates (bursty / diurnal)
are sampled by Lewis-Shedler thinning against the scenario's peak rate, which
stays exact and replayable for any bounded rate curve.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    """One generation request (tokens are sampled per-request from the seeded
    stream, so the workload is self-contained — no dataset dependency)."""
    rid: int
    arrival_ms: float
    prompt: Tuple[int, ...]     # prompt token ids
    max_new: int

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_tokens(self) -> int:
        """Worst-case context (prompt + full output) — the slot reservation
        size, and the 'work size' the hedging policy ranks requests by."""
        return self.prompt_len + self.max_new

    def continuation(self, emitted: Tuple[int, ...], at_ms: float) -> "Request":
        """The request that resumes THIS one on another peer after migration:
        already-emitted output tokens become prompt context (they were
        already delivered to the client — at-most-once emission), and only
        the remainder of the output budget is decoded. Same ``rid``: the
        client sees one logical request."""
        assert len(emitted) < self.max_new, (self.rid, len(emitted))
        return Request(self.rid, at_ms, self.prompt + tuple(emitted),
                       self.max_new - len(emitted))


@dataclass(frozen=True)
class LengthMix:
    """Discrete mixture over [lo, hi] ranges (uniform within a range)."""
    ranges: Tuple[Tuple[int, int], ...]
    weights: Tuple[float, ...]

    def sample(self, rng: np.random.Generator) -> int:
        i = int(rng.choice(len(self.ranges), p=np.asarray(self.weights)
                           / sum(self.weights)))
        lo, hi = self.ranges[i]
        return int(rng.integers(lo, hi + 1))


@dataclass(frozen=True)
class Scenario:
    """Arrival-rate curve + length mixes. ``rate_rps(t_s)`` must be bounded
    by ``peak_rps`` (thinning envelope)."""
    name: str
    peak_rps: float
    rate_fn: Callable[[float], float]        # simulated seconds -> req/s
    prompt_mix: LengthMix
    output_mix: LengthMix
    description: str = ""


def _steady(rps: float) -> Callable[[float], float]:
    return lambda t: rps


def _bursty(base: float, burst: float, period_s: float,
            duty: float) -> Callable[[float], float]:
    """On/off bursts: ``burst`` rps for the first ``duty`` fraction of each
    period, ``base`` rps otherwise."""
    def rate(t: float) -> float:
        return burst if (t % period_s) < duty * period_s else base
    return rate


def _diurnal(base: float, amp: float, period_s: float) -> Callable[[float], float]:
    """Sinusoidal day curve: base * (1 + amp * sin)."""
    def rate(t: float) -> float:
        return base * (1.0 + amp * math.sin(2.0 * math.pi * t / period_s))
    return rate


_SHORT_PROMPTS = LengthMix(((4, 12), (16, 28)), (0.7, 0.3))
_MIXED_PROMPTS = LengthMix(((4, 10), (12, 24), (28, 40)), (0.5, 0.35, 0.15))
_SHORT_OUT = LengthMix(((2, 6), (8, 12)), (0.6, 0.4))
_MIXED_OUT = LengthMix(((2, 5), (6, 14)), (0.5, 0.5))

# the scenario catalog (docs/serving.md): reduced-model scale — lengths are
# tokens into the reduced-config caches, rates are simulated req/s
SCENARIOS: Dict[str, Scenario] = {
    "steady": Scenario(
        "steady", peak_rps=40.0, rate_fn=_steady(40.0),
        prompt_mix=_SHORT_PROMPTS, output_mix=_SHORT_OUT,
        description="constant-rate Poisson arrivals, short chat shapes"),
    "bursty": Scenario(
        "bursty", peak_rps=120.0, rate_fn=_bursty(10.0, 120.0, 2.0, 0.25),
        prompt_mix=_MIXED_PROMPTS, output_mix=_MIXED_OUT,
        description="12x on/off bursts every 2s (queueing + admission "
                    "control stress)"),
    "diurnal": Scenario(
        "diurnal", peak_rps=80.0, rate_fn=_diurnal(40.0, 0.9, 8.0),
        prompt_mix=_MIXED_PROMPTS, output_mix=_SHORT_OUT,
        description="sinusoidal day curve (slow swing between near-idle "
                    "and ~2x mean load)"),
}


@dataclass
class Workload:
    scenario: str
    seed: int
    requests: List[Request] = field(default_factory=list)

    @property
    def total_prompt_tokens(self) -> int:
        return sum(r.prompt_len for r in self.requests)

    @property
    def total_output_tokens(self) -> int:
        return sum(r.max_new for r in self.requests)


def generate_workload(scenario: str, n_requests: int, vocab: int,
                      seed: int = 0,
                      max_prompt: Optional[int] = None,
                      max_new: Optional[int] = None) -> Workload:
    """Draw ``n_requests`` from the scenario's arrival process.

    ``max_prompt`` / ``max_new`` clamp lengths (the fleet's slot capacity is
    finite); clamping is part of the seeded stream, so it is deterministic.
    """
    if scenario not in SCENARIOS:
        raise KeyError(f"unknown scenario {scenario!r}; "
                       f"known: {', '.join(SCENARIOS)}")
    sc = SCENARIOS[scenario]
    rng = np.random.default_rng(np.random.PCG64(seed))
    out = Workload(scenario, seed)
    t = 0.0  # simulated seconds
    for rid in range(n_requests):
        # Lewis-Shedler thinning against the peak-rate envelope
        while True:
            t += rng.exponential(1.0 / sc.peak_rps)
            if rng.uniform() * sc.peak_rps <= sc.rate_fn(t):
                break
        p_len = sc.prompt_mix.sample(rng)
        o_len = sc.output_mix.sample(rng)
        if max_prompt is not None:
            p_len = min(p_len, max_prompt)
        if max_new is not None:
            o_len = min(o_len, max_new)
        prompt = tuple(int(x) for x in rng.integers(0, vocab, size=p_len))
        out.requests.append(Request(rid, t * 1e3, prompt, max(1, o_len)))
    return out
