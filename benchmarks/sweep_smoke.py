"""Sweep-harness smoke: the paper-grid pipeline end-to-end on a 2-cell grid
(one all-reduce baseline + one codist cell; the allreduce cell's alpha and
peers axes collapse in expansion).

Runs expand -> run -> resume (must be a no-op) -> aggregate on an inline
:class:`~repro.experiments.SweepSpec` in a temp directory, and emits the
aggregate's headline numbers as benchmark rows so the committed
``BENCH_throughput.json`` trajectory (and the CI regression gate over it)
covers the experiment subsystem too:

    sweep/cells_total          cells the grid expanded to (and ran)
    sweep/resume_noop          1 iff the resume pass re-ran nothing
    sweep/codist_gap_const     codist-vs-allreduce final-loss gap
    sweep/baseline_comm_bytes  all-reduce cumulative comm (deterministic)
    sweep/codist_comm_bytes    codist cumulative comm (deterministic)

Every row reports ``us_per_call=0`` and a DETERMINISTIC ``derived``: the
sweep's wall time is dominated by per-cell jit compilation, which varies
several-fold run-to-run, so it is printed to stderr rather than landing in
the committed baseline (where it would churn every re-bless and feed the
``bench_compare`` timing gate pure noise). The comm_bytes rows ARE gated
(exactly).
"""
from __future__ import annotations

import sys
import tempfile
import time
from typing import Dict, List

def run(quick: bool = False) -> List[Dict]:
    from repro.experiments import (AlphaPoint, SweepSpec, TINY_OVERRIDES,
                                   aggregate, run_sweep, sweep_dir_for)

    spec = SweepSpec(
        name="sweep_smoke", seq_len=8, steps=3 if quick else 10,
        batch_sizes=(2,), modes=("allreduce", "codist"),
        alpha_schedules=(AlphaPoint("const"),), peers=(2,),
        model_overrides=TINY_OVERRIDES)

    def quiet(_msg):
        pass

    rows: List[Dict] = []
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        results = run_sweep(spec, td, log=quiet)
        run_s = time.perf_counter() - t0
        bad = [r for r in results if r.status == "failed"]
        if bad:
            # surface the failure as a benchmark ERROR row (exit 1 from
            # benchmarks.run) instead of emitting '-' rows the regression
            # gate would skip
            raise RuntimeError(
                f"{len(bad)} sweep cell(s) failed: "
                + "; ".join(f"{r.cell.cell_id}: {r.error}" for r in bad))
        resumed = run_sweep(spec, td, resume=True, log=quiet)
        noop = int(all(r.status == "skipped" for r in resumed))
        doc = aggregate(sweep_dir_for(spec.name, td), spec.name,
                        {c.cell_id for c in spec.cells()})

        print(f"# sweep_smoke: {len(results)} cells in {run_s:.1f}s",
              file=sys.stderr)
        by_mode = {r["mode"]: r for r in doc["grid"]}
        ran = sum(1 for r in results if r.status == "ran")
        rows.append({"name": "sweep/cells_total", "us_per_call": 0.0,
                     "derived": f"{len(results)}_cells_ran_{ran}"})
        rows.append({"name": "sweep/resume_noop", "us_per_call": 0.0,
                     "derived": str(noop)})
        gap = by_mode.get("codist", {}).get("gap_vs_allreduce")
        rows.append({"name": "sweep/codist_gap_const", "us_per_call": 0.0,
                     "derived": "-" if gap is None else f"{gap:.4f}"})
        for mode, label in (("allreduce", "baseline"), ("codist", "codist")):
            comm = by_mode.get(mode, {}).get("comm_bytes_mean")
            rows.append({
                "name": f"sweep/{label}_comm_bytes", "us_per_call": 0.0,
                "derived": ("-" if comm is None
                            else f"comm_bytes={comm:.0f}")})
    return rows
