"""Section 3's staleness-tolerance claim: "predictions change more slowly
than model parameters during training, so codistillation should be reasonably
tolerant to staleness".

Three measurements:
  (a) checkpoint-exchange codistillation across T in {1, 5, 25, 100}: final
      task loss should degrade only mildly with staleness;
  (b) the claim's premise, measured directly: after a parameter update,
      relative change of predictions vs relative change of parameters —
      ||Δf(x)||/||f(x)|| divided by ||Δθ||/||θ|| should be well under 1
      late in training (predictions move slower than parameters);
  (c) staleness actually MEASURED, not assumed: the async runtime's mailbox
      timestamps every prediction payload, so a cluster with heterogeneous
      peer speeds reports the realized receiver-step minus sender-step
      distribution and how much a staleness bound drops.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import CodistConfig, TrainConfig
from repro.data import make_lm_batch
from repro.train import train_codist

from benchmarks.common import indep_batches, lm_setup, timed


def run(quick: bool = False) -> List[Dict]:
    model, task = lm_setup()
    steps = 60 if quick else 150
    tc = TrainConfig(lr=3e-3, total_steps=steps, warmup_steps=5,
                     optimizer="adamw", lr_schedule="cosine", seed=0)
    rows: List[Dict] = []

    # (a) staleness sweep over the checkpoint-exchange period
    losses = {}
    for t in (1, 5, 25, 100):
        codist = CodistConfig(n_models=2, mode="checkpoints", period=t)
        (_, hist), us = timed(
            lambda cd=codist: train_codist(model, cd, tc,
                                           indep_batches(task, 2, 8, 32),
                                           log_every=steps - 1),
            warmup=0, iters=1)
        losses[t] = hist.records[-1]["task_loss"]
        rows.append({"name": f"staleness/ckpt_T{t}_loss", "us_per_call": us,
                     "derived": round(losses[t], 4)})
    worst = max(losses.values())
    best = min(losses.values())
    rows.append({"name": "staleness/degradation_frac",
                 "derived": round((worst - best) / best, 4)})
    rows.append({"name": "staleness/tolerant_to_T100",
                 "derived": int((losses[100] - losses[1]) / losses[1] < 0.15)})

    # (b) predictions-drift vs parameter-drift ratio along a codist run,
    # driven through the strategy-engine API (build_train_step + plan
    # dispatch)
    from repro.optim import make_optimizer
    from repro.train import build_train_step, resolve_strategy
    codist = CodistConfig(n_models=2)
    strategy = resolve_strategy(codist)
    bundle = build_train_step(model, codist=codist, tc=tc, strategy=strategy)
    opt_init, _ = make_optimizer("adamw")
    state = strategy.init_state(model, tc, jax.random.key(0), opt_init)
    probe = make_lm_batch(task, 8, 32, 999, None, seed=3)

    def norm(t):
        return float(jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                                  for x in jax.tree.leaves(t))))

    def predictions(params):
        return model.forward(jax.tree.map(lambda x: x[0], params), probe)[0]

    ratios = []
    batches = indep_batches(task, 2, 8, 32)
    for k in range(steps):
        prev_params = state.params
        prev_pred = predictions(prev_params)
        state, _, _ = bundle.apply(state, batches(k), k)
        if k in (steps // 2, steps - 1):
            d_theta = norm(jax.tree.map(lambda a, b: a - b, state.params,
                                        prev_params)) / norm(prev_params)
            new_pred = predictions(state.params)
            d_pred = norm(new_pred - prev_pred) / norm(prev_pred)
            ratios.append(d_pred / max(d_theta, 1e-12))
            rows.append({"name": f"staleness/pred_vs_param_drift_step{k}",
                         "derived": round(ratios[-1], 4)})
    # Honest finding: at smoke scale (2-layer LM, <200 steps) predictions
    # move FASTER than parameters in relative norm (ratio > 1) — the paper's
    # premise is a late-training/overparameterized-regime statement. The
    # tolerance RESULT above still holds (T=100 degrades <15%), which is the
    # operationally relevant claim.
    rows.append({"name": "staleness/drift_ratio_final",
                 "derived": round(ratios[-1], 4)})

    # (c) staleness measured by the async runtime's mailbox under
    # heterogeneous peer speeds (peer 1 is 1.7x slower every step): the
    # realized receiver-step - sender-step distribution, and what a bound
    # of 2 steps actually drops
    from repro.runtime import AsyncScheduler, FaultConfig
    tc_async = TrainConfig(lr=3e-3, total_steps=30 if quick else 60,
                           warmup_steps=5, optimizer="adamw", seed=0)

    def async_batches(step):
        return make_lm_batch(task, 8, 32, step, None, seed=0)

    hetero = FaultConfig(n_peers=2, seed=0, speeds=(1.0, 1.7))
    for bound in (None, 2):
        rep, us = timed(
            lambda b=bound: AsyncScheduler(
                model, tc_async, codist, async_batches, hetero,
                staleness_bound=b, log_every=tc_async.total_steps - 1).run(),
            warmup=0, iters=1)
        tag = "unbounded" if bound is None else f"S{bound}"
        rows.append({"name": f"staleness/measured_mean_{tag}",
                     "us_per_call": us,
                     "derived": round(rep.staleness["staleness_mean"], 4)})
        rows.append({"name": f"staleness/measured_max_{tag}",
                     "derived": rep.staleness["staleness_max"]})
        rows.append({"name": f"staleness/payloads_dropped_{tag}",
                     "derived": rep.staleness["payloads_dropped"]})
    return rows
