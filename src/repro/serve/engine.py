"""Batched serving engine: prefill + decode with jitted step functions.

Serves a single model (codistillation is a *training* mechanism — one of its
selling points, Section 6.6, is that only one model is needed at inference).
Supports greedy and temperature sampling, batched requests of equal prompt
length, and — via ``prompt_lens`` — ragged batches of MIXED prompt lengths:
rows are prefilled in exact-length groups (no pad token ever enters a cache
or a recurrent state) and then decoded together with per-row cache positions.
Ragged batched generation is token-identical to per-request generation at
temperature 0 — the invariant the continuous-batching fleet
(``repro.serve.fleet``) is built on.

The fleet layer scales this engine out: many engines (one per codistilled
peer) behind a router, each running a continuous batcher over a paged KV
pool instead of the dense per-call cache used here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def default_cache_dtype():
    """bf16 KV/state caches on TPU (halves HBM for the dominant serving
    tensor); fp32 in interpret/CPU mode where bf16 emulation is slow and
    tests want reference numerics."""
    return jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32


def resolve_cache_dtype(name: Optional[str]):
    """CLI spelling -> dtype; None/'auto' defers to the backend default.

    Quantized spellings (``int8``, ``fp8``/``float8_e4m3fn``) resolve to
    paged-pool storage dtypes — only the fleet engine serves them (the
    dense ``Engine`` cache is never quantized); fp8 needs a jax with
    ``jnp.float8_e4m3fn``.
    """
    if name is None or name == "auto":
        return default_cache_dtype()
    table = {"bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
             "fp32": jnp.float32, "float32": jnp.float32,
             "fp16": jnp.float16, "float16": jnp.float16,
             "int8": jnp.int8}
    if hasattr(jnp, "float8_e4m3fn"):
        table["fp8"] = table["float8_e4m3fn"] = jnp.float8_e4m3fn
    if name not in table:
        raise ValueError(f"unknown cache dtype {name!r}; "
                         f"valid names: auto, {', '.join(table)}")
    return table[name]


@dataclass
class GenerationResult:
    tokens: jax.Array        # (B, prompt+generated)
    prompt_len: int
    logprobs: Optional[jax.Array] = None
    # ragged batches: per-row true prompt lengths (tokens[r, :prompt_lens[r]]
    # is the prompt, tokens[r, prompt_len:] the generated continuation)
    prompt_lens: Optional[List[int]] = None


class Engine:
    def __init__(self, model, params: PyTree, cache_dtype=None):
        from repro.kernels.paged_cache import is_quantized_dtype
        self.model = model
        self.params = params
        self.cache_dtype = (default_cache_dtype() if cache_dtype is None
                            else cache_dtype)
        if is_quantized_dtype(self.cache_dtype):
            raise ValueError(
                f"cache_dtype {jnp.dtype(self.cache_dtype).name} is a "
                "quantized paged-pool dtype: only the fleet engine "
                "(repro.serve.fleet) serves quantized KV — the dense "
                "Engine cache supports bf16/fp16/fp32")
        self._prefill = jax.jit(self._prefill_impl, static_argnums=(2,))
        self._decode = jax.jit(self._decode_impl)

    # -- jitted internals ----------------------------------------------------
    def _prefill_impl(self, params, batch, cap):
        return self.model.prefill(params, batch, cap,
                                  cache_dtype=self.cache_dtype)

    def _decode_impl(self, params, cache, tokens, pos):
        return self.model.decode(params, cache, tokens, pos)

    # -- public API ------------------------------------------------------------
    def generate(self, batch: Dict, max_new_tokens: int,
                 temperature: float = 0.0, seed: int = 0,
                 prompt_lens: Optional[List[int]] = None) -> GenerationResult:
        """batch: model inputs incl. 'tokens' (B, prompt_len) prompts.

        ``prompt_lens``: per-row true lengths for a RIGHT-PADDED mixed-length
        batch — row r's prompt is ``tokens[r, :prompt_lens[r]]``; pad columns
        are ignored entirely (grouped exact-length prefill + per-row decode
        positions), so output tokens match per-request generation.
        """
        if prompt_lens is not None:
            return self._generate_ragged(batch, max_new_tokens, temperature,
                                         seed, prompt_lens)
        prompt = batch["tokens"]
        b, prompt_len = prompt.shape
        # VLM: the patch prefix occupies cache slots before the prompt
        prefix = getattr(self.model.cfg, "num_patches", 0) or 0
        if "patches" not in batch:
            prefix = 0
        cap = prefix + prompt_len + max_new_tokens
        logits, cache = self._prefill(self.params, batch, cap)
        key = jax.random.key(seed)
        out_tokens = [prompt]
        tok = self._select(logits[:, -1], temperature, key)
        out_tokens.append(tok)
        for i in range(1, max_new_tokens):
            pos = jnp.asarray(prefix + prompt_len + i - 1, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok, pos)
            key, sub = jax.random.split(key)
            tok = self._select(logits[:, -1], temperature, sub)
            out_tokens.append(tok)
        return GenerationResult(jnp.concatenate(out_tokens, axis=1), prompt_len)

    def _generate_ragged(self, batch: Dict, max_new_tokens: int,
                         temperature: float, seed: int,
                         prompt_lens: List[int]) -> GenerationResult:
        assert "patches" not in batch and "frames" not in batch, \
            "ragged batching supports token-only LM inputs"
        assert getattr(self.model.cfg, "sliding_window", 0) <= 0, \
            "ragged batching needs a full-length cache (no ring buffer)"
        prompt = batch["tokens"]
        b, max_len = prompt.shape
        lens = [int(x) for x in prompt_lens]
        assert len(lens) == b and all(1 <= l <= max_len for l in lens), \
            (lens, prompt.shape)
        cap = max_len + max_new_tokens

        # group rows by true length: each group prefills its EXACT-length
        # slice (pads never enter attention caches or recurrent states)
        groups: Dict[int, List[int]] = {}
        for r, l in enumerate(lens):
            groups.setdefault(l, []).append(r)
        order: List[int] = []
        caches, first_logits = [], []
        for l in sorted(groups):
            rows = groups[l]
            order.extend(rows)
            pb = {"tokens": prompt[jnp.asarray(rows), :l]}
            logits, cache = self._prefill(self.params, pb, cap)
            caches.append(cache)
            first_logits.append(logits[:, -1])
        # merge the group caches along the batch axis, back to row order
        inv = jnp.argsort(jnp.asarray(order))
        cache = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=1)[:, inv], *caches)
        logits_last = jnp.concatenate(first_logits, axis=0)[inv]

        key = jax.random.key(seed)
        tok = self._select(logits_last, temperature, key)
        gen = [tok]
        lens_arr = jnp.asarray(lens, jnp.int32)
        for i in range(1, max_new_tokens):
            pos = lens_arr + (i - 1)  # per-row absolute position of `tok`
            logits, cache = self._decode(self.params, cache, tok, pos)
            key, sub = jax.random.split(key)
            tok = self._select(logits[:, -1], temperature, sub)
            gen.append(tok)
        tokens = jnp.concatenate([prompt] + gen, axis=1)
        return GenerationResult(tokens, max_len, prompt_lens=lens)

    @staticmethod
    def _select(logits: jax.Array, temperature: float, key) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature)[:, None].astype(jnp.int32)
