"""Table 1 / Fig 2(c): codistillation scales across batch size per model —
doubling the per-model batch, doubling the LR, and halving the updates lands
at a similar loss (the Goyal linear-scaling rule under codistillation)."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import CodistConfig, TrainConfig
from repro.train import train_codist

from benchmarks.common import coord_batches, lm_setup, timed


def run(quick: bool = False) -> List[Dict]:
    model, task = lm_setup()
    rows: List[Dict] = []
    base_steps = 40 if quick else 120
    base_lr = 1e-3
    base_b = 4
    for scale in (1, 2, 4):
        b = base_b * scale
        steps = max(8, base_steps // scale)
        tc = TrainConfig(lr=base_lr * scale, total_steps=steps,
                         warmup_steps=max(2, steps // 10),
                         optimizer="adamw", lr_schedule="cosine", seed=0)
        codist = CodistConfig(n_models=2)
        (_, hist), us = timed(
            lambda: train_codist(model, codist, tc,
                                 coord_batches(task, 2, b, 32),
                                 log_every=max(1, steps - 1)),
            warmup=0, iters=1)
        rows.append({"name": f"table1/codist_2x{b}_steps{steps}",
                     "us_per_call": us,
                     "derived": round(hist.records[-1]["task_loss"], 4)})
    losses = [float(r["derived"]) for r in rows]
    spread = (max(losses) - min(losses)) / max(losses)
    rows.append({"name": "table1/loss_spread_frac",
                 "derived": round(spread, 4)})
    return rows
