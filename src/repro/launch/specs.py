"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the batch pytree for the train/prefill
kinds; decode additionally needs the cache specs (``cache_specs``). VLM/audio
stubs provide precomputed patch/frame embeddings of the right shape — the one
carve-out to "no stubs" per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct
PyTree = Any


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      n_stack: int = 0, microbatch: int = 1) -> Dict[str, SDS]:
    """Batch specs for a train step.

    n_stack>0 prepends the codist model axis (the global batch is SPLIT
    across the n models — the paper's '2-way codist with batch B per model vs
    all_reduce with 2B'); microbatch>1 inserts a (k, B/k) gradient-
    accumulation axis after it.
    """
    b, s = shape.global_batch, shape.seq_len
    if n_stack:
        assert b % n_stack == 0
        b = b // n_stack
    if microbatch > 1:
        assert b % microbatch == 0
        b = b // microbatch
    act = jnp.dtype(cfg.dtype)

    def st(*dims, dtype=jnp.int32):
        if microbatch > 1:
            dims = (microbatch, *dims)
        if n_stack:
            dims = (n_stack, *dims)
        return SDS(dims, dtype)

    batch: Dict[str, SDS] = {}
    if cfg.is_encdec:
        if cfg.num_audio_frames > 0:
            batch["frames"] = st(b, cfg.num_audio_frames, cfg.d_model,
                                 dtype=act)
        else:
            batch["src_tokens"] = st(b, s)
        batch["tokens"] = st(b, s)
        batch["labels"] = st(b, s)
        batch["mask"] = st(b, s, dtype=jnp.float32)
        return batch
    if cfg.num_patches > 0:
        text = s - cfg.num_patches
        batch["patches"] = st(b, cfg.num_patches, cfg.d_model, dtype=act)
        batch["tokens"] = st(b, text)
        batch["labels"] = st(b, text)
        batch["mask"] = st(b, text, dtype=jnp.float32)
        return batch
    batch["tokens"] = st(b, s)
    batch["labels"] = st(b, s)
    batch["mask"] = st(b, s, dtype=jnp.float32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    batch = train_batch_specs(cfg, shape)
    batch.pop("labels", None)
    batch.pop("mask", None)
    return batch


def decode_token_specs(shape: InputShape) -> SDS:
    return SDS((shape.global_batch, 1), jnp.int32)


def cache_specs(model, cfg: ModelConfig, shape: InputShape,
                cache_dtype=jnp.bfloat16) -> PyTree:
    """abstract cache pytree for a decode step with capacity = seq_len."""
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 cache_dtype))


def params_specs(model) -> PyTree:
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def stacked_params_specs(model, n: int) -> PyTree:
    def init_stacked():
        keys = jax.random.split(jax.random.key(0), n)
        return jax.vmap(model.init)(keys)
    return jax.eval_shape(init_stacked)
