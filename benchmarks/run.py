"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,fig7]
                                            [--json PATH]

Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally writes
the rows as a JSON document (the committed ``BENCH_throughput.json`` perf
trajectory is ``--only throughput,fault,sweep_smoke,serving,serving_chaos
--quick --json BENCH_throughput.json``; ``tools/bench_compare.py`` gates CI
runs against
it — see docs/experiments.md). Unknown ``--only`` names exit 2 with the
registered list.
Mapping to the paper:
    fig1        communication trade-off (analytic + compiled-HLO cross-pod bytes)
    fig2        regularization-schedule necessity (constant vs decayed WD)
    table1      batch-size linear scaling under codistillation
    fig6        multi-view n-way study (enforced / shared / all views)
    fig7        parameter-distance regularization effect
    table2      n-way gains at equal updates (view-diverse task)
    fig17       n-way with a fixed total update budget degrades
    fault       codist vs all-reduce barrier under seeded fault injection
    sweep_smoke paper-grid sweep harness end-to-end (run/resume/aggregate)
    serving     continuous-batching fleet: latency/SLO per workload scenario
    serving_chaos  fleet under fault injection: defended vs undefended SLO
    throughput  step-variant microbench + kernel interpret timings
    roofline    §Roofline summary from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import difflib
import json
import platform
import sys
import time
import traceback

from benchmarks.common import emit

# single registry shared with tooling: name -> module exporting run(quick)
REGISTRY = {
    "fig1": "benchmarks.fig1_comm",
    "fig2": "benchmarks.fig2_regschedule",
    "table1": "benchmarks.table1_scaling",
    "fig6": "benchmarks.fig6_multiview",
    "fig7": "benchmarks.fig7_reg",
    "table2": "benchmarks.table2_nway",
    "fig17": "benchmarks.fig17_nway_fixed",
    "staleness": "benchmarks.staleness",
    "fault": "benchmarks.fault_tolerance",
    "sweep_smoke": "benchmarks.sweep_smoke",
    "serving": "benchmarks.serving",
    "serving_chaos": "benchmarks.serving_chaos",
    "comm": "benchmarks.comm_sweep",
    "throughput": "benchmarks.throughput",
    "roofline": "benchmarks.roofline_table",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced step counts for CI")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--json", default="",
                    help="also write all rows to this JSON file")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))
    unknown = only - set(REGISTRY)
    if unknown:
        # an unknown --only used to silently run NOTHING and exit 0
        print(f"unknown benchmark(s): {', '.join(sorted(unknown))}",
              file=sys.stderr)
        for name in sorted(unknown):
            close = difflib.get_close_matches(name, REGISTRY, n=1)
            if close:
                print(f"did you mean: {close[0]} (for {name!r})?",
                      file=sys.stderr)
        print(f"registered: {', '.join(REGISTRY)}", file=sys.stderr)
        sys.exit(2)

    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for name, modpath in REGISTRY.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            import importlib
            mod = importlib.import_module(modpath)
            rows = mod.run(quick=args.quick)
            emit(rows)
            all_rows.extend(rows)
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception as e:
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.json:
        import jax
        doc = {
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "python_version": platform.python_version(),
            "quick": bool(args.quick),
            "rows": [{"name": r["name"],
                      "us_per_call": round(float(r.get("us_per_call", 0)), 1),
                      "derived": str(r["derived"])} for r in all_rows],
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"# wrote {args.json} ({len(all_rows)} rows)", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
