"""Combined CE + distillation Pallas kernel: one read of each logits tile.

The codistillation hot path (Algorithm 1, prediction mode) evaluates BOTH the
task cross-entropy and the distillation loss D(y, y') on the same student
logits every step. Run as two separate kernels that is two full HBM sweeps of
the (T, V) logits — at Qwen-scale vocab (152k) the logits are the dominant
HBM object, so the second sweep roughly doubles the loss cost. This kernel
fuses them: each (block_t, block_v) student tile and target tile is read
EXACTLY ONCE and all per-token outputs stream out of VMEM accumulators:

  nll     = logZ_s - x[label]                (task CE)
  smooth  = logZ_s - mean_v(x)               (label-smoothing term)
  dist    = mse: mean_v (s - t)^2            (paper A.3)
            kl:  KL(softmax(t) || softmax(s))  (Anil-style)

For ``kl`` the student-side online logsumexp is shared between the CE and the
KL — the five-accumulator KL form degenerates to just three extra registers
(m_t, s_t, U) on top of the CE accumulators.

The matching backward kernels emit (dstudent, dtarget) in one pass from the
saved (T,)-sized residuals (logZ_s and, for kl, logZ_t and E = E_p[lt - ls]):

  dstudent = (g_nll + g_smooth) softmax(s) - g_nll onehot - g_smooth / V
             + g_dist * (mse: 2(s-t)/V | kl: softmax(s) - softmax(t))
  dtarget  = g_dist * (mse: -2(s-t)/V  | kl: softmax(t)((t-s) - E))

Padded vocab columns must hold -1e30 in BOTH operands (never win a max, zero
MSE diff, zero softmax mass); ``v_real`` excludes them from the /V means.
``ops.py`` wraps these in the ``fused_ce_distill`` custom-VJP entry point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_ce import NEG, pl_scratch
from repro.kernels.fused_ce import ce_accumulate as _ce_accumulate
from repro.kernels.fused_ce import ce_grad_term as _ce_grad_term
from repro.kernels.fused_ce import tile_spec as _tile_spec
from repro.kernels.fused_ce import tok_spec as _tok_spec


def _combined_mse_kernel(labels_ref, s_logits_ref, t_logits_ref,
                         nll_ref, smooth_ref, dist_ref, logzs_ref,
                         m_ref, s_ref, tr_ref, xs_ref, acc_ref, *,
                         block_v: int, n_v: int, v_real: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        for r in (s_ref, tr_ref, xs_ref, acc_ref):
            r[...] = jnp.zeros_like(r)

    x = s_logits_ref[...].astype(jnp.float32)
    t = t_logits_ref[...].astype(jnp.float32)
    _ce_accumulate(x, labels_ref[...], j, m_ref, s_ref, tr_ref, xs_ref,
                   block_v=block_v, v_real=v_real)
    # padded cols hold the -1e30 sentinel whose bf16<->f32 round trip is not
    # exact — mask them out rather than relying on a zero diff
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + j * block_v
    d = jnp.where(cols < v_real, x - t, 0.0)
    acc_ref[...] = acc_ref[...] + jnp.sum(d * d, axis=-1)

    @pl.when(j == n_v - 1)
    def _fin():
        logz = m_ref[...] + jnp.log(s_ref[...])
        logzs_ref[...] = logz
        nll_ref[...] = logz - tr_ref[...]
        smooth_ref[...] = logz - xs_ref[...] / v_real
        dist_ref[...] = acc_ref[...] / v_real


def _combined_kl_kernel(labels_ref, s_logits_ref, t_logits_ref,
                        nll_ref, smooth_ref, dist_ref, logzs_ref, logzt_ref,
                        e_ref, m_ref, s_ref, tr_ref, xs_ref, mt_ref, st_ref,
                        u_ref, *, block_v: int, n_v: int, v_real: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        mt_ref[...] = jnp.full_like(mt_ref, NEG)
        for r in (s_ref, tr_ref, xs_ref, st_ref, u_ref):
            r[...] = jnp.zeros_like(r)

    x = s_logits_ref[...].astype(jnp.float32)
    lt = t_logits_ref[...].astype(jnp.float32)
    # student-side accumulators serve the CE *and* the KL's logZ_s
    _ce_accumulate(x, labels_ref[...], j, m_ref, s_ref, tr_ref, xs_ref,
                   block_v=block_v, v_real=v_real)
    # target-side online logsumexp + rescaled cross term
    mt_prev = mt_ref[...]
    mt_new = jnp.maximum(mt_prev, jnp.max(lt, axis=-1))
    alpha_t = jnp.exp(mt_prev - mt_new)
    w = jnp.exp(lt - mt_new[:, None])
    st_ref[...] = st_ref[...] * alpha_t + jnp.sum(w, axis=-1)
    u_ref[...] = u_ref[...] * alpha_t + jnp.sum(w * (lt - x), axis=-1)
    mt_ref[...] = mt_new

    @pl.when(j == n_v - 1)
    def _fin():
        logzs = m_ref[...] + jnp.log(s_ref[...])
        logzt = mt_ref[...] + jnp.log(st_ref[...])
        e = u_ref[...] / st_ref[...]
        logzs_ref[...] = logzs
        logzt_ref[...] = logzt
        e_ref[...] = e
        nll_ref[...] = logzs - tr_ref[...]
        smooth_ref[...] = logzs - xs_ref[...] / v_real
        dist_ref[...] = e - logzt + logzs


@functools.partial(jax.jit, static_argnames=("mode", "block_t", "block_v",
                                             "v_real", "interpret"))
def fused_ce_distill_parts(logits: jax.Array, target_logits: jax.Array,
                           labels: jax.Array, mode: str = "mse",
                           block_t: int = 256, block_v: int = 512,
                           v_real: int = 0, interpret: bool = False):
    """One-sweep CE + distill forward. (T, V) x2, (T,) labels.

    Returns per-token ``(nll, smooth, dist)`` plus residuals: ``(logzs,)``
    for mse, ``(logzs, logzt, e)`` for kl.
    """
    t, v = logits.shape
    assert logits.shape == target_logits.shape
    v_real = v_real or v
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    n_t, n_v = t // block_t, v // block_v
    sds = jax.ShapeDtypeStruct((t,), jnp.float32)
    if mode == "mse":
        kernel = functools.partial(_combined_mse_kernel, block_v=block_v,
                                   n_v=n_v, v_real=v_real)
        n_out, n_scratch = 4, 5
    elif mode == "kl":
        kernel = functools.partial(_combined_kl_kernel, block_v=block_v,
                                   n_v=n_v, v_real=v_real)
        n_out, n_scratch = 6, 7
    else:
        raise ValueError(mode)
    outs = pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[_tok_spec(block_t), _tile_spec(block_t, block_v),
                  _tile_spec(block_t, block_v)],
        out_specs=[_tok_spec(block_t) for _ in range(n_out)],
        out_shape=[sds] * n_out,
        scratch_shapes=[pl_scratch((block_t,)) for _ in range(n_scratch)],
        interpret=interpret,
    )(labels, logits, target_logits)
    return outs[:3], outs[3:]


# ----------------------------------------------------------------------------
# backward: (dstudent, dtarget) in one fused pass
# ----------------------------------------------------------------------------

def _combined_mse_grad_kernel(labels_ref, logzs_ref, gn_ref, gs_ref, gd_ref,
                              s_logits_ref, t_logits_ref, ds_ref, dt_ref, *,
                              block_v: int, v_real: int):
    j = pl.program_id(1)
    x = s_logits_ref[...].astype(jnp.float32)
    t = t_logits_ref[...].astype(jnp.float32)
    ce, _ = _ce_grad_term(x, labels_ref[...], logzs_ref[...], gn_ref[...],
                          gs_ref[...], j, block_v=block_v, v_real=v_real)
    # same cols<v_real guard as the forward: the pad sentinel's dtype
    # round-trip makes x-t nonzero (or inf for narrow dtypes) on padded cols
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + j * block_v
    d = jnp.where(cols < v_real, x - t, 0.0)
    dd = gd_ref[...][:, None] * (2.0 / v_real) * d
    ds_ref[...] = (ce + dd).astype(ds_ref.dtype)
    dt_ref[...] = (-dd).astype(dt_ref.dtype)


def _combined_kl_grad_kernel(labels_ref, logzs_ref, logzt_ref, e_ref, gn_ref,
                             gs_ref, gd_ref, s_logits_ref, t_logits_ref,
                             ds_ref, dt_ref, *, block_v: int, v_real: int):
    j = pl.program_id(1)
    x = s_logits_ref[...].astype(jnp.float32)
    lt = t_logits_ref[...].astype(jnp.float32)
    ce, q = _ce_grad_term(x, labels_ref[...], logzs_ref[...], gn_ref[...],
                          gs_ref[...], j, block_v=block_v, v_real=v_real)
    p = jnp.exp(lt - logzt_ref[...][:, None])
    gd = gd_ref[...][:, None]
    ds_ref[...] = (ce + gd * (q - p)).astype(ds_ref.dtype)
    dt_ref[...] = (gd * p * ((lt - x) - e_ref[...][:, None])).astype(
        dt_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "block_t", "block_v",
                                             "v_real", "interpret"))
def fused_ce_distill_grad(logits: jax.Array, target_logits: jax.Array,
                          labels: jax.Array, residuals, g_nll: jax.Array,
                          g_smooth: jax.Array, g_dist: jax.Array,
                          mode: str = "mse", block_t: int = 256,
                          block_v: int = 512, v_real: int = 0,
                          interpret: bool = False):
    """(dlogits, dtarget) for the combined loss, one read of each tile."""
    t, v = logits.shape
    v_real = v_real or v
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    if mode == "mse":
        kernel = functools.partial(_combined_mse_grad_kernel, block_v=block_v,
                                   v_real=v_real)
    elif mode == "kl":
        kernel = functools.partial(_combined_kl_grad_kernel, block_v=block_v,
                                   v_real=v_real)
    else:
        raise ValueError(mode)
    tok_ins = [_tok_spec(block_t)] * (1 + len(residuals) + 3)
    return pl.pallas_call(
        kernel,
        grid=(t // block_t, v // block_v),
        in_specs=tok_ins + [_tile_spec(block_t, block_v),
                            _tile_spec(block_t, block_v)],
        out_specs=[_tile_spec(block_t, block_v),
                   _tile_spec(block_t, block_v)],
        out_shape=[jax.ShapeDtypeStruct((t, v), logits.dtype),
                   jax.ShapeDtypeStruct((t, v), target_logits.dtype)],
        interpret=interpret,
    )(labels, *residuals, g_nll, g_smooth, g_dist, logits, target_logits)
