"""DEPRECATED step factories — thin aliases over ``repro.train.engine``.

The per-mechanism factories that used to live here (each re-implementing the
schedule/optimizer/microbatch plumbing) are now single ``build_train_step``
invocations with the matching ``ExchangeStrategy``. New code should use the
engine directly:

    from repro.train.engine import build_train_step, resolve_strategy

These aliases keep the historical call signatures working for external
callers, the distributed tests, and the benchmark suite. Shared helpers
(``make_schedules``, ``_grads_with_metrics``, the eval factories,
``refresh_stale``) are re-exported from the engine, which is their home now.
"""
from __future__ import annotations

import warnings

from typing import Any, Callable, Dict, Optional, Tuple

from repro.configs.base import CodistConfig, TrainConfig
from repro.train.engine import (  # noqa: F401  (re-exported shared helpers)
    AllReduce,
    CheckpointExchange,
    PipelinedPredictions,
    PredictionExchange,
    _grads_metrics_aux,
    _grads_with_metrics,
    _stacked_forward,
    _task_forward,
    build_train_step,
    make_codist_eval_step,
    make_eval_step,
    make_schedules,
    refresh_stale,
)
from repro.train.state import init_peer_state  # noqa: F401 (moved to state)

warnings.warn(
    "repro.train.steps is deprecated: build steps with "
    "repro.train.engine.build_train_step + an ExchangeStrategy "
    "(see docs/exchange_strategies.md)",
    DeprecationWarning, stacklevel=2)

PyTree = Any


def make_allreduce_step(model, tc: TrainConfig,
                        trainable: Optional[PyTree] = None) -> Callable:
    """DEPRECATED: ``build_train_step(model, tc, None, AllReduce())``."""
    return build_train_step(model, tc, None, AllReduce(),
                            trainable).variants["on"]


def make_codist_step(model, codist: CodistConfig, tc: TrainConfig,
                     distill: bool, trainable: Optional[PyTree] = None
                     ) -> Callable:
    """DEPRECATED: prediction-exchange codistillation step (Algorithm 1,
    coordinated sampling). ``distill=False`` selects the off-step variant
    that omits the distillation term (and the cross-pod collective)."""
    bundle = build_train_step(model, tc, codist, PredictionExchange(codist),
                              trainable)
    return bundle.variants["on" if distill else "off"]


def make_codist_checkpoint_step(model, codist: CodistConfig, tc: TrainConfig,
                                trainable: Optional[PyTree] = None
                                ) -> Callable:
    """DEPRECATED: checkpoint-exchange codistillation (Anil et al.)."""
    return build_train_step(model, tc, codist, CheckpointExchange(codist),
                            trainable).variants["on"]


def make_codist_pipelined_step(model, codist: CodistConfig, tc: TrainConfig,
                               trainable: Optional[PyTree] = None
                               ) -> Callable:
    """DEPRECATED: pipelined prediction exchange (previous-step targets)."""
    return build_train_step(model, tc, codist, PipelinedPredictions(codist),
                            trainable).variants["on"]
