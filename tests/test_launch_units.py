"""Unit tests for the launch substrate: HLO collective parsing, sharding
rules, roofline math, comm-cost integration — no device mesh needed."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import hlo_analysis as ha
from repro.launch import roofline as rl


class TestHloParser:
    def test_shape_bytes(self):
        assert ha._shape_bytes("bf16[16,4096]{1,0}") == 16 * 4096 * 2
        assert ha._shape_bytes("f32[8]") == 32
        assert ha._shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
        assert ha._shape_bytes("pred[]") == 1
        assert ha._shape_bytes("token[]") == 0

    def test_explicit_replica_groups(self):
        line = ('  %ag = bf16[8,16]{1,0} all-gather(bf16[2,16]{1,0} %p), '
                'channel_id=1, replica_groups={{0,1},{2,3}}, dimensions={0}')
        s = ha.parse_collectives(line, devices_per_pod=2)
        assert len(s.ops) == 1
        assert s.ops[0].kind == "all-gather"
        assert s.ops[0].operand_bytes == 2 * 16 * 2
        assert not s.ops[0].cross_pod  # {0,1} and {2,3} stay within pods

    def test_cross_pod_groups(self):
        line = ('  %ar = f32[4]{0} all-reduce(f32[4]{0} %p), channel_id=2, '
                'replica_groups={{0,2},{1,3}}, to_apply=%add')
        s = ha.parse_collectives(line, devices_per_pod=2)
        assert s.ops[0].cross_pod  # 0 and 2 are in different pods

    def test_iota_replica_groups(self):
        # [2,2]<=[4]: groups [[0,1],[2,3]] — intra-pod at dpp=2
        line = ('  %ag = f32[4]{0} all-gather(f32[2]{0} %p), channel_id=3, '
                'replica_groups=[2,2]<=[4], dimensions={0}')
        s = ha.parse_collectives(line, devices_per_pod=2)
        assert not s.ops[0].cross_pod
        # transposed iota: [2,2]<=[2,2]T(1,0): groups [[0,2],[1,3]] — cross
        line2 = line.replace("[2,2]<=[4]", "[2,2]<=[2,2]T(1,0)")
        s2 = ha.parse_collectives(line2, devices_per_pod=2)
        assert s2.ops[0].cross_pod

    def test_collective_permute_pairs(self):
        line = ('  %cp = f32[8]{0} collective-permute(f32[8]{0} %p), '
                'channel_id=4, source_target_pairs={{0,2},{2,0}}')
        s = ha.parse_collectives(line, devices_per_pod=2)
        assert s.ops[0].cross_pod
        assert s.cross_pod_bytes == 32

    def test_summary_accounting(self):
        text = "\n".join([
            '  %a = f32[4]{0} all-reduce(f32[4]{0} %p), replica_groups={{0,1}}',
            '  %b = f32[8]{0} all-gather(f32[2]{0} %q), replica_groups={{0,2}}',
        ])
        s = ha.parse_collectives(text, devices_per_pod=2)
        assert s.total_bytes == 16 + 8
        assert s.cross_pod_bytes == 8
        assert s.intra_pod_bytes == 16
        assert s.counts() == {"all-reduce": 1, "all-gather": 1}


class TestRoofline:
    def test_terms_and_bottleneck(self):
        shape = INPUT_SHAPES["train_4k"]
        cfg = get_config("qwen1.5-0.5b")
        r = rl.build_report("qwen1.5-0.5b", shape, "16x16", 256,
                            hlo_flops=1.97e14, hlo_bytes=8.19e11,
                            collective_bytes=5e10, cross_pod_bytes=0.0,
                            cfg=cfg)
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(1.0)
        assert r.collective_s == pytest.approx(1.0)
        r2 = rl.build_report("x", shape, "m", 256, 1e12, 8.19e12, 1e9, 0, cfg)
        assert r2.bottleneck == "memory"

    def test_model_flops_kinds(self):
        cfg = get_config("qwen1.5-0.5b")
        n = rl.active_params(cfg)
        tr = rl.model_flops(cfg, INPUT_SHAPES["train_4k"])
        pf = rl.model_flops(cfg, INPUT_SHAPES["prefill_32k"])
        dc = rl.model_flops(cfg, INPUT_SHAPES["decode_32k"])
        assert tr == pytest.approx(6 * n * 256 * 4096)
        assert pf == pytest.approx(2 * n * 32 * 32768)
        assert dc == pytest.approx(2 * n * 128)

    def test_moe_active_params_much_smaller(self):
        cfg = get_config("arctic-480b")
        assert rl.active_params(cfg) < 0.1 * cfg.param_count()


class TestShardingRules:
    @pytest.fixture(scope="class")
    def mesh(self):
        # AbstractMesh avoids touching real devices
        from repro.launch.mesh import abstract_mesh
        return abstract_mesh((16, 16), ("data", "model"))

    def test_attention_head_fallback_replicates(self, mesh):
        from repro.launch.sharding import param_spec
        # 28 heads not divisible by 16 -> head dim must NOT slide to head_dim
        spec = param_spec("layers/sub0/mix/wq", (28, 3584, 28, 128), mesh,
                          scanned=True)
        assert spec[2] is None and spec[3] is None
        assert spec[1] == "data"
        # 64 heads divide -> sharded over model
        spec2 = param_spec("layers/sub0/mix/wq", (95, 8192, 64, 128), mesh,
                           scanned=True)
        assert spec2[2] == "model"

    def test_ffn_slide_fallback(self, mesh):
        from repro.launch.sharding import param_spec
        # whisper d_ff=1536 divisible; d_model=384 divisible
        spec = param_spec("dec_layers/ffn/w_up", (4, 384, 1536), mesh,
                          scanned=True)
        assert spec == jax.sharding.PartitionSpec(None, "data", "model")

    def test_expert_axis_option(self, mesh):
        from repro.launch.sharding import param_spec
        spec = param_spec("layers/sub0/ffn/w_gate", (35, 128, 7168, 4864),
                          mesh, scanned=True, moe_expert_axis="data")
        assert spec[1] == "data" and spec[3] == "model" and spec[2] is None

    def test_scan_axis_never_sharded(self, mesh):
        from repro.launch.sharding import param_spec
        spec = param_spec("layers/sub0/ffn/w_up", (96, 8192, 22016), mesh,
                          scanned=True)
        assert spec[0] is None

    def test_stacked_codist_axis(self):
        from repro.launch.mesh import abstract_mesh
        from repro.launch.sharding import param_spec
        mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
        spec = param_spec("layers/sub0/ffn/w_up", (2, 24, 1024, 2816), mesh,
                          stacked=True, scanned=True)
        assert spec[0] == "pod" and spec[1] is None

    def test_two_d_ffn_decode(self):
        from repro.launch.mesh import abstract_mesh
        from repro.launch.sharding import param_spec
        mesh = abstract_mesh((16, 16), ("data", "model"))
        spec = param_spec("layers/sub0/ffn/w_up", (28, 3584, 18944), mesh,
                          scanned=True, two_d_ffn=True)
        assert spec[2] == ("data", "model")
        # attention untouched by the 2d-ffn variant
        spec2 = param_spec("layers/sub0/mix/wo", (28, 3584, 3584), mesh,
                           scanned=True, two_d_ffn=True)
        assert spec2[1] == "model" and spec2[2] == "data"


class TestHierarchicalTopK:
    def test_exact_vs_lax(self):
        import numpy as np
        from repro.core.codistillation import _hierarchical_topk
        x = jax.random.normal(jax.random.key(3), (5, 2048))
        for k in (1, 16, 100):
            v1, i1 = jax.lax.top_k(x, k)
            v2, i2 = _hierarchical_topk(x, k, segments=16)
            np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
            np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    def test_fallback_small_vocab(self):
        from repro.core.codistillation import _hierarchical_topk
        x = jax.random.normal(jax.random.key(0), (3, 100))
        v, i = _hierarchical_topk(x, 50, segments=16)  # 100/16 < 50 -> fallback
        assert v.shape == (3, 50)
