"""Watchtower + flight-recorder tests: rule parsing names the offending
clause, fire/resolve hysteresis, burn-rate and EWMA-drift semantics, alert
JSONL bit-determinism across seeded chaos runs, flight-recorder ring/dump
bounds, and the overhead-off guarantee (alerting enabled leaves the gated
fleet report byte-identical).
"""
import json
import os
import sys
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.obs import (ALERTS_SCHEMA_VERSION, POSTMORTEM_SCHEMA_VERSION,
                       FlightRecorder, MetricsRegistry, Rule, Watchtower,
                       default_rules, for_sim_ms, load_rules, parse_rules)
from repro.runtime import FaultConfig
from repro.serve.fleet import (ChaosConfig, FleetConfig, FleetDefense,
                               FleetRouter, Request)

TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
sys.path.insert(0, TOOLS)
import ci_bitcheck  # noqa: E402
import trace_check  # noqa: E402


def _rule(**kw):
    base = dict(name="r", metric="m", kind="threshold", op=">", value=1.0)
    base.update(kw)
    return parse_rules([base])[0]


# ----------------------------------------------------------------------------
# rule parsing: malformed specs name the offending clause
# ----------------------------------------------------------------------------

class TestRuleParsing:
    def test_unknown_key_named(self):
        with pytest.raises(ValueError, match=r"'windoww'"):
            _rule(windoww=4)

    def test_missing_required_key_named(self):
        with pytest.raises(ValueError, match="missing required key 'op'"):
            parse_rules([{"name": "x", "metric": "m", "kind": "threshold",
                          "value": 1.0}])

    def test_bad_name_rejected(self):
        # dots would break ci_bitcheck's dotted-path --expect clauses
        with pytest.raises(ValueError, match=r"'bad\.dot'"):
            _rule(name="bad.dot")

    def test_bad_kind_op_signal_severity(self):
        with pytest.raises(ValueError, match="kind 'spline'"):
            _rule(kind="spline")
        with pytest.raises(ValueError, match="op '~'"):
            _rule(op="~")
        with pytest.raises(ValueError, match="signal 'p17'"):
            _rule(signal="p17")
        with pytest.raises(ValueError, match="severity 'mild'"):
            _rule(severity="mild")

    def test_int_and_unit_interval_bounds(self):
        with pytest.raises(ValueError, match="window 0"):
            _rule(window=0)
        with pytest.raises(ValueError, match="fire_after"):
            _rule(fire_after=-1)
        with pytest.raises(ValueError, match="alpha"):
            _rule(alpha=1.5)
        with pytest.raises(ValueError, match="budget"):
            _rule(budget=0.0)

    def test_duplicate_names_rejected(self):
        spec = dict(name="dup", metric="m", kind="threshold", op=">",
                    value=1.0)
        with pytest.raises(ValueError, match="duplicate"):
            parse_rules([spec, dict(spec)])

    def test_load_rules_both_forms(self, tmp_path):
        specs = [dict(name="a", metric="m", kind="threshold", op=">",
                      value=1.0)]
        bare = tmp_path / "bare.json"
        bare.write_text(json.dumps(specs))
        wrapped = tmp_path / "wrapped.json"
        wrapped.write_text(json.dumps({"rules": specs}))
        assert load_rules(str(bare)) == load_rules(str(wrapped))
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"rule": specs}))
        with pytest.raises(ValueError, match="'rules' key"):
            load_rules(str(bad))

    def test_default_pack_parses_and_covers_the_catalog(self):
        names = {r.name for r in default_rules(slo_ms=25.0)}
        assert {"straggler-slowdown", "spec-accept-collapse",
                "canary-divergence", "mailbox-staleness", "slo-burn-rate",
                "kv-pool-saturation", "loss-gap-drift"} <= names


# ----------------------------------------------------------------------------
# engine semantics: hysteresis, burn rate, drift
# ----------------------------------------------------------------------------

class TestEngine:
    def test_fire_resolve_hysteresis(self):
        m = MetricsRegistry()
        w = Watchtower(m, [_rule(metric="g", fire_after=2, resolve_after=2)],
                       unit_us=1000.0, clock="test")
        seq = [5.0, 5.0, 0.0, 5.0, 0.0, 0.0, 0.0]
        events = []
        for t, v in enumerate(seq):
            m.gauge("g").set(v)
            events += w.evaluate(t)
        # breach at t=0 does not fire (streak 1 < fire_after 2); t=1 fires;
        # the single recovery at t=2 does NOT resolve and the breach at t=3
        # resets the ok-streak; only t=4..5 back-to-back recoveries resolve
        assert [(e["ts"], e["state"]) for e in events] == [
            (1000, "firing"), (5000, "resolved")]
        assert w.firing() == []
        assert w.summary()["counts"] == {"r__firing": 1, "r__resolved": 1}

    def test_no_data_leaves_streaks_untouched(self):
        m = MetricsRegistry()
        w = Watchtower(m, [_rule(metric="absent")])
        assert w.evaluate(0) == [] and w.n_events == 0
        # min_count gate: a histogram below min_count is skipped too
        w2 = Watchtower(m, [_rule(metric="h", min_count=3)])
        m.histogram("h").observe(99.0)
        assert w2.evaluate(0) == []

    def test_burn_rate_budget(self):
        m = MetricsRegistry()
        rule = _rule(metric="lat", kind="burn_rate", op=">", value=50.0,
                     window=4, budget=0.5)
        w = Watchtower(m, [rule])
        h = m.histogram("lat")
        for v in (10.0, 60.0, 10.0, 10.0):   # 1/4 breaching < budget
            h.observe(v)
        assert w.evaluate(0) == []
        h.observe(70.0)                      # window now 60,10,10,70 -> 2/4
        ev = w.evaluate(1)
        assert ev and ev[0]["state"] == "firing" and ev[0]["value"] == 0.5

    def test_ewma_drift_watches_change_then_self_resolves(self):
        m = MetricsRegistry()
        w = Watchtower(m, [_rule(metric="g", kind="ewma_drift", op=">",
                                 value=0.5, alpha=0.5)])
        m.gauge("g").set(1.0)
        assert w.evaluate(0) == []           # seeds the baseline, no breach
        m.gauge("g").set(3.0)                # drift 2.0 > 0.5 -> fires
        assert w.evaluate(1)[0]["state"] == "firing"
        events = []
        for t in range(2, 8):                # level holds; baseline catches up
            events += w.evaluate(t)
        assert [e["state"] for e in events] == ["resolved"]

    def test_jsonl_canonical_and_validates(self, tmp_path):
        m = MetricsRegistry()
        w = Watchtower(m, [_rule(metric="g")])
        m.gauge("g").set(9.0)
        w.evaluate(2)
        path = tmp_path / "alerts.jsonl"
        w.save(str(path))
        head = json.loads(path.read_text().splitlines()[0])
        assert head["schema_version"] == ALERTS_SCHEMA_VERSION
        assert head["kind"] == "alerts"
        assert trace_check.main([str(path)]) == 0
        # ci_bitcheck's JSONL loader exposes the fire counts
        assert ci_bitcheck.main([str(path), str(path), "--require",
                                 "schema_version",
                                 "--expect", "counts.r__firing>=1"]) == 0

    def test_negative_time_rejected(self):
        w = Watchtower(MetricsRegistry(), [_rule(metric="g")])
        with pytest.raises(ValueError, match="negative"):
            w.evaluate(-1.0)


# ----------------------------------------------------------------------------
# flight recorder bounds
# ----------------------------------------------------------------------------

class TestFlightRecorder:
    def test_ring_bound_enforced(self, tmp_path):
        fr = FlightRecorder(str(tmp_path), capacity=4)
        for i in range(10):
            fr.offer(i, i, {"ts": i, "name": f"e{i}"})
        evs = fr.events()
        assert len(evs) == 4 and evs[0]["ts"] == 6 and evs[-1]["ts"] == 9
        assert fr.n_offered == 10

    def test_dump_budget_and_schema(self, tmp_path):
        fr = FlightRecorder(str(tmp_path), capacity=4, max_dumps=1)
        fr.offer(0, 0, {"ts": 0, "name": "e"})
        p1 = fr.dump("alert-test", 5)
        assert p1 and os.path.exists(p1)
        assert fr.dump("alert-again", 6) is None     # budget spent
        assert len(fr.dumped) == 1
        doc = json.loads(open(p1).read())
        assert doc["schema_version"] == POSTMORTEM_SCHEMA_VERSION
        assert doc["kind"] == "postmortem" and doc["n_events_seen"] == 1
        assert trace_check.main([p1]) == 0

    def test_invalid_bounds_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="capacity"):
            FlightRecorder(str(tmp_path), capacity=0)
        with pytest.raises(ValueError, match="max_dumps"):
            FlightRecorder(str(tmp_path), max_dumps=0)

    def test_dumps_on_firing_not_resolve(self, tmp_path):
        fr = FlightRecorder(str(tmp_path))
        assert fr.on_alert({"rule": "x", "state": "resolved", "ts": 1}) \
            is None
        assert fr.on_alert({"rule": "x", "state": "firing", "ts": 2})


# ----------------------------------------------------------------------------
# end-to-end on the chaos fleet (shared tiny-model fixtures mirror
# tests/test_obs.py)
# ----------------------------------------------------------------------------

def _tiny_cfg():
    return replace(get_reduced("qwen1.5-0.5b"), num_layers=2, d_model=64,
                   d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=2,
                   head_dim=32)


def _requests(cfg, lens, max_new=5, gap_ms=4.0, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, i * gap_ms,
                    tuple(int(x) for x in rng.integers(0, cfg.padded_vocab,
                                                       size=l)), max_new)
            for i, l in enumerate(lens)]


class _ListWorkload:
    def __init__(self, requests, scenario="custom", seed=0):
        self.requests = requests
        self.scenario = scenario
        self.seed = seed


def _fleet_fc():
    return FleetConfig(max_slots=2, block_size=4, num_blocks=32,
                       max_blocks_per_slot=8, max_queue=32)


@pytest.fixture(scope="module")
def fleet_setup():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    wl = _ListWorkload(_requests(cfg, [5, 9, 12, 7] * 4))
    return model, params, wl


_PREEMPT = ((0, 6, 150.0),)   # peer 0: peer 1 straggles too hard to reach it

# fires while any engine holds live KV (utilization is recorded before
# eviction, so it never reads 0 — this rule only ever fires)
_KV_RULE = Rule(name="kv-busy", metric="fleet/kv_utilization",
                kind="threshold", op=">", value=0.0, signal="window_max",
                window=2, resolve_after=2)


def _chaos_watch_run(fleet_setup, out_dir):
    model, params, wl = fleet_setup
    # the CI smoke scenario: a short-horizon straggler episode that both
    # starts and ends mid-run (fire AND resolve), plus one preemption
    chaos = ChaosConfig(FaultConfig(n_peers=2, seed=0,
                                    straggler_peers=(1,),
                                    straggler_factor=6.0,
                                    straggler_frac=0.9, straggler_len=6,
                                    preemptions=_PREEMPT),
                        horizon_ticks=12)
    rules = [r for r in default_rules()
             if r.name == "straggler-slowdown"] + [_KV_RULE]
    mreg = MetricsRegistry()
    watch = Watchtower(mreg, rules, unit_us=1000.0, clock="sim_ms")
    tracer = for_sim_ms()
    recorder = FlightRecorder(out_dir, capacity=32, metrics=mreg)
    tracer.recorder = recorder
    watch.on_alert(recorder.on_alert)
    watch.on_fault(recorder.on_fault)
    rep = FleetRouter(model, [params, params], config=_fleet_fc(),
                      chaos=chaos, defense=FleetDefense(), tracer=tracer,
                      metrics=mreg, watch=watch).run(wl)
    bundles = [open(p).read() for p in recorder.dumped]
    return rep, watch, bundles


def test_chaos_alert_log_bit_identical(fleet_setup, tmp_path):
    """Two seeded chaos runs emit byte-identical alert JSONL and
    postmortem bundles, with the kv alert both firing and resolving and
    the preemption fault captured as a bundle."""
    a = _chaos_watch_run(fleet_setup, str(tmp_path / "a"))
    b = _chaos_watch_run(fleet_setup, str(tmp_path / "b"))
    assert a[1].to_jsonl() == b[1].to_jsonl()
    assert a[2] == b[2] and a[2], "no postmortem bundles dumped"
    counts = a[1].summary()["counts"]
    assert counts.get("kv-busy__firing", 0) >= 1
    assert counts.get("straggler-slowdown__firing", 0) >= 1
    assert counts.get("straggler-slowdown__resolved", 0) >= 1
    reasons = [json.loads(doc)["reason"] for doc in a[2]]
    assert any(r.startswith("fault-preempt") for r in reasons)
    assert any(r.startswith("alert-") for r in reasons)
    path = tmp_path / "alerts.jsonl"
    a[1].save(str(path))
    assert trace_check.main([str(path)]) == 0


def test_watchtower_does_not_perturb_the_fleet(fleet_setup, tmp_path):
    """Overhead-off from the other side: full watchtower + flight
    recorder enabled produces a byte-identical gated FleetReport to the
    uninstrumented run."""
    model, params, wl = fleet_setup
    plain = FleetRouter(model, [params, params], config=_fleet_fc()).run(wl)
    mreg = MetricsRegistry()
    watch = Watchtower(mreg, default_rules(), unit_us=1000.0)
    recorder = FlightRecorder(str(tmp_path), metrics=mreg)
    watch.on_alert(recorder.on_alert)
    watch.on_fault(recorder.on_fault)
    instrumented = FleetRouter(model, [params, params], config=_fleet_fc(),
                               metrics=mreg, watch=watch).run(wl)
    assert plain.to_json() == instrumented.to_json()
