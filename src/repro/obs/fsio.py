"""Crash-safe artifact writes for the observability layer.

Every obs artifact (metrics registry dump, trace JSON, alert JSONL,
postmortem bundle) goes through :func:`atomic_write_text`: the bytes land
in a temporary sibling, are flushed and fsynced, and only then replace the
final path — matching ``checkpoint/io.save_pytree``'s discipline. A run
killed mid-save leaves either the previous complete artifact or the new
one on disk, never a truncated file that a CI bit-gate or a resume pass
would misread as a finished export.
"""
from __future__ import annotations

import os


def atomic_write_text(path: str, text: str) -> None:
    """Write ``text`` to ``path`` atomically (tmp + fsync + os.replace)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
