"""GQA attention: training forward, prefill (cache emit) and decode (cache read).

Supports: grouped-query heads (num_kv_heads <= num_heads), optional QKV bias
(Qwen), RoPE, causal and sliding-window masks, cross-attention (enc-dec), and
ring-buffer windowed KV caches for long-context decode (the sub-quadratic dense
variant used by ``long_500k``).

Keys are stored in the cache ALREADY rotated (standard practice) so ring-buffer
eviction never needs absolute positions at read time.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, apply_rope, dense_init, zeros

NEG_INF = -1e30


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig,
                   dtype=jnp.float32) -> Dict[str, jax.Array]:
    kg = KeyGen(key)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": dense_init(kg(), d, (h, hd), dtype),
        "wk": dense_init(kg(), d, (kv, hd), dtype),
        "wv": dense_init(kg(), d, (kv, hd), dtype),
        "wo": dense_init(kg(), h * hd, (d,), dtype, scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((h, hd), dtype)
        p["bk"] = zeros((kv, hd), dtype)
        p["bv"] = zeros((kv, hd), dtype)
    return p


def _project_qkv(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def _out_proj(p: Dict[str, jax.Array], o: jax.Array) -> jax.Array:
    b, s, h, hd = o.shape
    return jnp.einsum("bse,ed->bsd", o.reshape(b, s, h * hd),
                      p["wo"].astype(o.dtype))


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,S,H,hd), k: (B,T,KV,hd) -> scores (B,H,S,T) with head grouping."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k)
    return scores.reshape(b, kvh * g, s, k.shape[1]) * (hd ** -0.5)


def _gqa_combine(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B,H,S,T), v: (B,T,KV,hd) -> (B,S,H,hd)."""
    b, h, s, t = w.shape
    kvh = v.shape[2]
    g = h // kvh
    wg = w.reshape(b, kvh, g, s, t)
    o = jnp.einsum("bkgst,btkd->bskgd", wg, v)
    return o.reshape(b, s, h, v.shape[-1])


def _softmax(scores: jax.Array) -> jax.Array:
    return jax.nn.softmax(scores.astype(jnp.float32), axis=-1)


# ----------------------------------------------------------------------------
# training / prefill forward
# ----------------------------------------------------------------------------

def attention_forward(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                      positions: Optional[jax.Array] = None,
                      causal: bool = True,
                      return_cache: bool = False):
    """Full-sequence attention. x: (B,S,d). Returns (out, cache|None).

    cache = {"k": roped keys (B,S,KV,hd), "v": values} for prefill handoff.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    from repro.models.sharding_hints import hint
    scores = hint(_gqa_scores(q, k), "scores")  # (B,H,S,S)
    if causal:
        i = jnp.arange(s)[:, None]
        j = jnp.arange(s)[None, :]
        mask = j <= i
        if cfg.sliding_window > 0:
            mask = mask & (i - j < cfg.sliding_window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = _softmax(scores).astype(x.dtype)
    out = _out_proj(p, _gqa_combine(w, v))
    cache = {"k": k, "v": v} if return_cache else None
    return out, cache


def cross_attention_forward(p: Dict[str, jax.Array], x: jax.Array,
                            memory: jax.Array, cfg: ModelConfig):
    """Decoder-to-encoder attention (no RoPE on memory, no mask)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    w = _softmax(_gqa_scores(q, k)).astype(x.dtype)
    return _out_proj(p, _gqa_combine(w, v))


# ----------------------------------------------------------------------------
# KV cache (decode)
# ----------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype) -> Dict[str, jax.Array]:
    """Windowed ring buffer when sliding_window>0, else a full-length buffer."""
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, length, kv, hd), dtype),
        "v": jnp.zeros((batch, length, kv, hd), dtype),
    }


def prefill_into_cache(cache: Dict[str, jax.Array],
                       new: Dict[str, jax.Array], cfg: ModelConfig):
    """Copy prefill keys/values into the (possibly windowed) cache buffer."""
    s = new["k"].shape[1]
    cap = cache["k"].shape[1]
    if s >= cap:
        # keep the trailing window, rolled so position p lands at slot p % cap —
        # decode writes use (pos % cap) and must overwrite the oldest slot.
        shift = s % cap
        return {"k": jnp.roll(new["k"][:, s - cap:], shift, axis=1),
                "v": jnp.roll(new["v"][:, s - cap:], shift, axis=1)}
    k = jax.lax.dynamic_update_slice(cache["k"], new["k"], (0, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], new["v"], (0, 0, 0, 0))
    return {"k": k, "v": v}


def attention_decode(p: Dict[str, jax.Array], x: jax.Array,
                     cache: Dict[str, jax.Array], pos: jax.Array,
                     cfg: ModelConfig):
    """One-token decode. x: (B,1,d); pos: () int32 absolute position, or a
    (B,) int32 vector of PER-ROW positions (ragged continuous batching: each
    cache row advances on its own clock; full-length caches only).

    Returns (out (B,1,d), new_cache). With a windowed cache the write index is
    pos % window (ring buffer) and reads mask out unwritten / evicted slots.
    """
    b = x.shape[0]
    cap = cache["k"].shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    vector_pos = pos.ndim == 1
    positions = pos[:, None] if vector_pos else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)

    slot = jnp.arange(cap)
    if vector_pos:
        assert cfg.sliding_window <= 0, \
            "per-row positions require a full-length (non-ring) cache"
        rows = jnp.arange(b)
        k = cache["k"].at[rows, pos].set(k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[rows, pos].set(v_new[:, 0].astype(cache["v"].dtype))
        valid = (slot[None, :] <= pos[:, None])[:, None, None, :]  # (B,1,1,cap)
    else:
        write_idx = (pos % cap) if cfg.sliding_window > 0 else pos
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, write_idx, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, write_idx, 0, 0))
        if cfg.sliding_window > 0:
            # slot holds absolute position: the largest written pos congruent mod cap
            age = (write_idx - slot) % cap           # 0 == just written
            abs_pos = pos - age
            valid = (abs_pos >= 0) & (age < jnp.minimum(cap, pos + 1))
        else:
            valid = slot <= pos
        valid = valid[None, None, None, :]

    scores = _gqa_scores(q, k)  # (B,H,1,cap)
    scores = jnp.where(valid, scores, NEG_INF)
    w = _softmax(scores).astype(x.dtype)
    out = _out_proj(p, _gqa_combine(w, v))
    return out, {"k": k, "v": v}


def cross_attention_decode(p: Dict[str, jax.Array], x: jax.Array,
                           mem_cache: Dict[str, jax.Array], cfg: ModelConfig):
    """Decode-time cross attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    w = _softmax(_gqa_scores(q, mem_cache["k"].astype(x.dtype)))
    return _out_proj(p, _gqa_combine(w.astype(x.dtype),
                                     mem_cache["v"].astype(x.dtype)))


def encoder_kv(p: Dict[str, jax.Array], memory: jax.Array, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(memory.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(memory.dtype)
        v = v + p["bv"].astype(memory.dtype)
    return {"k": k, "v": v}
