"""Execute sweep cells through the existing training entry points.

One cell = one training run. Synchronous modes go through the unified
engine (``train_allreduce`` / ``train_codist`` -> ``build_train_step``);
``codist-async`` goes through the :class:`~repro.runtime.AsyncScheduler`
on a clean (fault-free) schedule. Every cell is seeded from its own
``cell.seed`` — model init, data stream, and fault schedule — so a cell is
a pure function of its :class:`~repro.experiments.spec.Cell` and re-running
it reproduces the trajectory bit-for-bit (pinned by
``tests/test_experiments.py``).

Persistence is crash-safe: each cell writes its full per-step
:class:`~repro.train.loop.History` as ``<cell_id>.jsonl`` FIRST, then an
atomic (write-tmp + rename) ``<cell_id>.json`` summary marked
``status: complete``. Resume (``--resume``) skips exactly the cells whose
summary exists and validates against the requested cell + step count, so a
killed sweep restarts where it died and a finished sweep is a no-op.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.experiments.spec import (ASYNC_MODES, Cell, SweepSpec,
                                    cell_to_dict, spec_to_dict)

SCHEMA_VERSION = 1


# ----------------------------------------------------------------------------
# paths + resume validation
# ----------------------------------------------------------------------------

def sweep_dir_for(spec_name: str, out_root: str = "results/sweeps") -> str:
    return os.path.join(out_root, spec_name)


def cell_paths(sweep_dir: str, cell: Cell) -> Tuple[str, str]:
    """(summary .json, history .jsonl) for one cell."""
    return (os.path.join(sweep_dir, f"{cell.cell_id}.json"),
            os.path.join(sweep_dir, f"{cell.cell_id}.jsonl"))


def load_summary(sweep_dir: str, cell: Cell) -> Optional[Dict]:
    path, _ = cell_paths(sweep_dir, cell)
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _jsonable_cell(cell: Cell) -> Dict:
    """The cell dict as it reads back from JSON (tuples become lists)."""
    return json.loads(json.dumps(cell_to_dict(cell)))


def summary_is_valid(sweep_dir: str, cell: Cell, steps: int) -> bool:
    """True iff this cell's result can be trusted and skipped on resume:
    the summary parses, is marked complete, matches the FULL requested
    cell (id alone is not enough — a spec edit that keeps axis names but
    changes their values, the arch, seq_len, or model_overrides must
    invalidate stale results) and step count, and its history file has a
    final record at the last step."""
    doc = load_summary(sweep_dir, cell)
    if (not doc or doc.get("status") != "complete"
            or doc.get("schema") != SCHEMA_VERSION
            or doc.get("cell_id") != cell.cell_id
            or doc.get("steps") != steps
            or doc.get("cell") != _jsonable_cell(cell)):
        return False
    _, hist_path = cell_paths(sweep_dir, cell)
    try:
        from repro.train.loop import History
        hist = History.load(hist_path)
        return bool(hist.records) and hist.last("step") == steps - 1
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return False


def _write_atomic(path: str, doc: Dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)


# ----------------------------------------------------------------------------
# one cell
# ----------------------------------------------------------------------------

def _build_cell_setup(cell: Cell):
    """Model + data task for a cell (shared by the sync and async paths)."""
    from repro.configs import get_reduced
    from repro.data import MarkovLM
    from repro.models import build_model

    cfg = get_reduced(cell.arch)
    if cell.overrides:
        cfg = replace(cfg, **dict(cell.overrides))
    model = build_model(cfg)
    vocab = min(cfg.vocab_size, 512)
    task = MarkovLM(vocab=vocab, seed=cell.seed,
                    effective_vocab=min(vocab, 256))
    return model, task


def _train_config(cell: Cell, steps: int):
    from repro.configs import TrainConfig
    return TrainConfig(
        lr=cell.lr.resolve_lr(cell.batch), lr_schedule=cell.lr.kind,
        warmup_steps=max(1, int(round(cell.lr.warmup_frac * steps))),
        total_steps=steps, optimizer=cell.optimizer, seed=cell.seed)


def _codist_config(cell: Cell, steps: int):
    from repro.configs import CodistConfig
    return CodistConfig(
        n_models=cell.peers,
        mode="checkpoints" if cell.mode == "codist-ckpt" else "predictions",
        pipelined=(cell.mode == "codist-pipelined"),
        distill_loss=cell.distill_loss,
        alpha0=cell.alpha.alpha0, alpha_growth=cell.alpha.growth,
        steps_per_epoch=max(1, steps // 10),
        burn_in_steps=int(round(cell.alpha.burn_in_frac * steps)))


def run_cell(cell: Cell, steps: Optional[int] = None, *,
             trace_path: Optional[str] = None,
             metrics_path: Optional[str] = None,
             alerts_path: Optional[str] = None,
             rules: Optional[List] = None):
    """Train one grid cell; returns ``(summary_dict, History)``.

    The summary's ``final`` block carries what the aggregator needs: final
    task loss (the paper's quality metric), accuracy, and the Section-3
    communication accounting. ``trace_path``/``metrics_path`` enable the
    ``repro.obs`` hooks for this cell and write the Perfetto trace / metrics
    registry there (sync modes trace on the step clock, async on the
    runtime's simulated seconds); ``None`` leaves the run uninstrumented.
    ``alerts_path`` additionally evaluates a Watchtower (``rules``, or the
    built-in pack) over the cell's live metrics on the same clock and
    writes its alert JSONL there.
    """
    from repro.data import make_lm_batch
    from repro.train import (History, stack_batches, train_allreduce,
                             train_codist)

    steps = int(steps or cell.steps)
    model, task = _build_cell_setup(cell)
    tc = _train_config(cell, steps)

    metrics = None
    if metrics_path or alerts_path:
        # alerting needs a live registry even when no metrics dump was
        # requested; the internal registry is simply not written out
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    watch = None
    if alerts_path:
        from repro.obs import Watchtower, default_rules
        is_async = cell.mode in ASYNC_MODES
        watch = Watchtower(
            metrics, rules if rules is not None else default_rules(),
            unit_us=(1_000_000.0 if is_async else 1000.0),
            clock=("sim_s" if is_async else "steps"))

    def _tracer(async_clock: bool):
        if not trace_path:
            return None
        from repro.obs import for_sim_seconds, for_steps
        return for_sim_seconds() if async_clock else for_steps()

    if cell.mode == "allreduce":
        tracer = _tracer(False)

        def it():
            s = 0
            while True:
                yield make_lm_batch(task, cell.batch, cell.seq_len, s, None,
                                    seed=cell.seed)
                s += 1
        _, hist = train_allreduce(model, tc, it(), log_every=1,
                                  tracer=tracer, metrics=metrics, watch=watch)
        comm = {"comm_events": hist.last("comm_events"),
                "comm_bytes": hist.last("comm_bytes")}
    elif cell.mode in ASYNC_MODES:
        from repro.runtime import AsyncScheduler, FaultConfig
        codist = _codist_config(cell, steps)
        faults = FaultConfig(n_peers=cell.peers, seed=cell.seed)
        tracer = _tracer(True)

        def batches(step):
            return make_lm_batch(task, cell.batch, cell.seq_len, step, None,
                                 seed=cell.seed)
        report = AsyncScheduler(model, tc, codist, batches, faults,
                                log_every=1, tracer=tracer,
                                metrics=metrics, watch=watch).run()
        records = sorted(
            (r for h in report.histories.values() for r in h.records),
            key=lambda r: (r["step"], r.get("peer", 0)))
        hist = History(records)
        comm = {"comm_events": report.comm_events,
                "comm_bytes": report.comm_bytes}
    else:
        codist = _codist_config(cell, steps)
        coordinated = codist.mode == "predictions"
        tracer = _tracer(False)

        def batches(step):
            return stack_batches([
                make_lm_batch(task, cell.batch, cell.seq_len, step,
                              None if coordinated else g, seed=cell.seed)
                for g in range(cell.peers)])
        _, hist = train_codist(model, codist, tc, batches, log_every=1,
                               tracer=tracer, metrics=metrics, watch=watch)
        comm = {"comm_events": hist.last("comm_events"),
                "comm_bytes": hist.last("comm_bytes")}

    def last_mean(key: str) -> float:
        """Final value of a metric; async cells average every peer's LAST
        record (clean schedule: all peers survive) so no single peer's
        final step skews the row."""
        if cell.mode in ASYNC_MODES:
            per_peer: Dict[int, float] = {}
            for rec in hist.records:
                if key in rec:
                    per_peer[rec.get("peer", 0)] = rec[key]
            if not per_peer:
                raise KeyError(key)
            return sum(per_peer.values()) / len(per_peer)
        return hist.last(key)

    final = {"task_loss": last_mean("task_loss"),
             "loss": last_mean("loss"), **comm}
    try:
        final["accuracy"] = last_mean("accuracy")
    except KeyError:
        pass
    summary = {
        "schema": SCHEMA_VERSION,
        "status": "complete",
        "cell_id": cell.cell_id,
        "cell": cell_to_dict(cell),
        "grid_key": list(cell.grid_key),
        "baseline_key": list(cell.baseline_key),
        "steps": steps,
        "final": final,
    }
    if tracer is not None:
        tracer.save(trace_path)
    if metrics is not None and metrics_path:
        metrics.save(metrics_path)
    if watch is not None:
        watch.save(alerts_path)
    return summary, hist


# ----------------------------------------------------------------------------
# the sweep driver
# ----------------------------------------------------------------------------

@dataclass
class CellResult:
    cell: Cell
    status: str            # 'ran' | 'skipped' | 'failed'
    seconds: float
    summary: Optional[Dict] = None
    error: str = ""


def _observe_loss_gap(watch, by_key: Dict[tuple, Dict[str, float]],
                      cell: Cell, summary: Dict, idx: int) -> None:
    """Feed one finished cell into the sweep-level loss-gap Watchtower.

    ``by_key`` maps ``baseline_key`` (batch, lr) -> {mode: final task_loss}.
    Whenever a codist cell and its allreduce baseline are both known, the
    ``sweep/loss_gap`` gauge is set to codist - baseline and the watch is
    evaluated at the cell index (one cell renders as 1 ms on the sweep
    clock), so the EWMA-drift rule sees gaps in deterministic cell order.
    """
    final = summary.get("final") or {}
    task_loss = final.get("task_loss")
    if task_loss is None:
        return
    key = tuple(summary.get("baseline_key", cell.baseline_key))
    slot = by_key.setdefault(key, {})
    slot[cell.mode] = float(task_loss)
    base = slot.get("allreduce")
    if base is None:
        return
    if cell.mode == "allreduce":
        # baseline arrived after its codist partners: flush them in order
        pairs = [(m, v) for m, v in sorted(slot.items()) if m != "allreduce"]
    else:
        pairs = [(cell.mode, slot[cell.mode])]
    for _, loss in pairs:
        watch.registry.gauge("sweep/loss_gap").set(round(loss - base, 6))
        watch.evaluate(idx)


def run_sweep(spec: SweepSpec, out_root: str = "results/sweeps", *,
              resume: bool = False, max_cells: Optional[int] = None,
              steps: Optional[int] = None, trace: bool = False,
              metrics: bool = False, alerts: bool = False,
              rules_path: Optional[str] = None,
              log: Callable[[str], None] = print) -> List[CellResult]:
    """Run (a prefix of) a sweep's cells, persisting each as it completes.

    A failed cell is recorded and the sweep continues — crash-safety means
    one bad cell never costs the finished ones. The caller decides whether
    failures are fatal (the CLI exits 1 if any cell failed).

    ``trace``/``metrics`` write per-cell observability artifacts next to
    each result: ``<cell_id>.trace.json`` (Perfetto trace) and
    ``<cell_id>.metrics.json`` (repro.obs registry dump). ``alerts`` adds
    ``<cell_id>.alerts.jsonl`` per cell plus a sweep-level ``alerts.jsonl``
    that watches the codist-vs-baseline loss gap across cells
    (``rules_path`` overrides the built-in rule pack for both).
    """
    sweep_dir = sweep_dir_for(spec.name, out_root)
    os.makedirs(sweep_dir, exist_ok=True)
    _write_atomic(os.path.join(sweep_dir, "spec.json"), spec_to_dict(spec))

    cell_rules = None
    swatch = None
    by_key: Dict[tuple, Dict[str, float]] = {}
    if alerts:
        from repro.obs import (MetricsRegistry, Watchtower, default_rules,
                               load_rules)
        cell_rules = load_rules(rules_path) if rules_path else None
        swatch = Watchtower(
            MetricsRegistry(),
            cell_rules if cell_rules is not None else default_rules(),
            unit_us=1000.0, clock="cells")

    cells = spec.cells()
    if max_cells:
        cells = cells[:max_cells]
    eff_steps = int(steps or 0)
    results: List[CellResult] = []
    for i, cell in enumerate(cells):
        n_steps = eff_steps or cell.steps
        tag = f"[{i + 1}/{len(cells)}] {cell.cell_id}"
        if resume and summary_is_valid(sweep_dir, cell, n_steps):
            log(f"{tag}: skipped (already complete)")
            summary = load_summary(sweep_dir, cell)
            if swatch is not None and summary:
                _observe_loss_gap(swatch, by_key, cell, summary, i)
            results.append(CellResult(cell, "skipped", 0.0, summary))
            continue
        t0 = time.time()
        try:
            summary, hist = run_cell(
                cell, n_steps,
                trace_path=(os.path.join(
                    sweep_dir, f"{cell.cell_id}.trace.json")
                    if trace else None),
                metrics_path=(os.path.join(
                    sweep_dir, f"{cell.cell_id}.metrics.json")
                    if metrics else None),
                alerts_path=(os.path.join(
                    sweep_dir, f"{cell.cell_id}.alerts.jsonl")
                    if alerts else None),
                rules=cell_rules)
        except Exception as e:  # noqa: BLE001 - record and keep sweeping
            dt = time.time() - t0
            log(f"{tag}: FAILED after {dt:.1f}s ({type(e).__name__}: {e})")
            results.append(CellResult(cell, "failed", dt,
                                      error=f"{type(e).__name__}: {e}"))
            continue
        summary_path, hist_path = cell_paths(sweep_dir, cell)
        hist.save(hist_path)          # history first...
        _write_atomic(summary_path, summary)  # ...summary marks completion
        if swatch is not None:
            _observe_loss_gap(swatch, by_key, cell, summary, i)
        dt = time.time() - t0
        log(f"{tag}: final task_loss={summary['final']['task_loss']:.4f} "
            f"in {dt:.1f}s")
        results.append(CellResult(cell, "ran", dt, summary))
    if swatch is not None:
        swatch.save(os.path.join(sweep_dir, "alerts.jsonl"))
        s = swatch.summary()
        log(f"sweep alerts: {s['n_events']} events, still firing: "
            f"{', '.join(s['firing']) or 'none'}")
    counts = {s: sum(1 for r in results if r.status == s)
              for s in ("ran", "skipped", "failed")}
    log(f"sweep {spec.name}: total={len(results)} ran={counts['ran']} "
        f"skipped={counts['skipped']} failed={counts['failed']}")
    return results
