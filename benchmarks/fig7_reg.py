"""Figure 7: the regularization effect — parameters stay closer to their
initialization under codistillation than under independent training."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import CodistConfig, TrainConfig
from repro.train import train_codist

from benchmarks.common import coord_batches, lm_setup, timed


def run(quick: bool = False) -> List[Dict]:
    model, task = lm_setup()
    steps = 40 if quick else 120
    tc = TrainConfig(lr=3e-3, total_steps=steps, warmup_steps=5,
                     optimizer="adamw", lr_schedule="cosine", seed=0)
    rows: List[Dict] = []
    dists = {}
    for alpha, tag in ((0.0, "independent"), (1.0, "codist_a1"),
                       (4.0, "codist_a4")):
        codist = CodistConfig(n_models=2, alpha0=alpha)
        (_, hist), us = timed(
            lambda cd=codist: train_codist(model, cd, tc,
                                           coord_batches(task, 2, 8, 32),
                                           log_every=steps - 1,
                                           track_param_distance=True),
            warmup=0, iters=1)
        d = hist.records[-1]["param_distance"]
        dists[tag] = d
        rows.append({"name": f"fig7/param_distance_{tag}",
                     "us_per_call": us, "derived": round(d, 4)})
    rows.append({"name": "fig7/codist_closer_to_init",
                 "derived": int(dists["codist_a1"] < dists["independent"])})
    return rows
