"""Serving correctness: prefill+decode must reproduce teacher-forced forward
logits, across every architecture family (dense GQA / ssm / hybrid+moe /
enc-dec / vlm) — this is the invariant a KV-cache bug breaks first."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from dataclasses import replace

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import Engine

FAMILIES = ["qwen2-7b", "rwkv6-1.6b", "jamba-v0.1-52b", "whisper-tiny",
            "internvl2-76b", "grok-1-314b"]


def _inputs(cfg, b=2, s=12, key=7):
    k = jax.random.key(key)
    batch = {"tokens": jax.random.randint(k, (b, s), 0, cfg.padded_vocab)}
    if cfg.num_patches:
        batch["patches"] = 0.1 * jax.random.normal(
            k, (b, cfg.num_patches, cfg.d_model))
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            k, (b, cfg.num_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_teacher_forcing(arch):
    """Incremental decode must match position-wise ground truth.

    Dense/ssm/enc-dec: ground truth is the teacher-forced train forward.
    MoE archs: training uses GShard capacity DROPPING (per-sequence groups)
    while serving paths are no-drop, so the position-wise ground truth is a
    fresh PREFILL at each length — the serving-internal invariant.
    """
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 12
    batch = _inputs(cfg, b, s)
    is_moe = cfg.moe is not None
    # VLM: the patch prefix occupies cache slots and position indices
    prefix = cfg.num_patches if (not cfg.is_encdec and cfg.num_patches) else 0
    cap = prefix + s + 2

    def truth(i):
        """logits at TEXT position i (predicting token i+1)."""
        if not is_moe:
            full, _ = model.forward(params, batch)
            return full[:, i]
        pb = dict(batch, tokens=batch["tokens"][:, :i + 1])
        lg, _ = model.prefill(params, pb, cap=cap, cache_dtype=jnp.float32)
        return lg[:, 0]

    split = s - 4
    pb = dict(batch, tokens=batch["tokens"][:, :split])
    logits, cache = model.prefill(params, pb, cap=cap,
                                  cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(truth(split - 1)),
                               rtol=2e-4, atol=2e-4)
    for i in range(split, s):
        tok = batch["tokens"][:, i:i + 1]
        logits, cache = model.decode(params, cache, tok,
                                     jnp.int32(prefix + i))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(truth(i)),
            rtol=5e-4, atol=5e-4, err_msg=f"{arch} step {i}")


def test_sliding_window_decode_matches_windowed_forward():
    """Ring-buffer windowed cache == sliding-window teacher forcing."""
    cfg = replace(get_reduced("qwen2-7b"), sliding_window=6)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 1, 16
    batch = _inputs(cfg, b, s)
    full, _ = model.forward(params, batch)
    split = 8
    pb = dict(batch, tokens=batch["tokens"][:, :split])
    logits, cache = model.prefill(params, pb, cap=s, cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, split - 1]),
                               rtol=2e-4, atol=2e-4)
    for i in range(split, s):
        tok = batch["tokens"][:, i:i + 1]
        logits, cache = model.decode(params, cache, tok, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"window decode step {i}")


def test_engine_greedy_generation_deterministic():
    cfg = get_reduced("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params)
    batch = _inputs(cfg, b=2, s=8)
    r1 = eng.generate(batch, max_new_tokens=5)
    r2 = eng.generate(batch, max_new_tokens=5)
    assert r1.tokens.shape == (2, 13)
    np.testing.assert_array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))


@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-1.6b"])
def test_ragged_batch_matches_per_request(arch):
    """A right-padded mixed-length batch with ``prompt_lens`` must generate
    token-for-token what per-request generation produces at temperature 0 —
    the invariant the fleet's continuous batcher relies on (pads must never
    leak into attention caches or recurrent states)."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params)
    b, max_len, new = 4, 12, 6
    prompt = jax.random.randint(jax.random.key(3), (b, max_len), 0,
                                cfg.padded_vocab)
    lens = [12, 5, 9, 7]
    ragged = eng.generate({"tokens": prompt}, new, prompt_lens=lens)
    assert ragged.tokens.shape == (b, max_len + new)
    assert ragged.prompt_lens == lens
    for r, l in enumerate(lens):
        ref = eng.generate({"tokens": prompt[r:r + 1, :l]}, new)
        np.testing.assert_array_equal(
            np.asarray(ragged.tokens[r, max_len:]),
            np.asarray(ref.tokens[0, l:]),
            err_msg=f"{arch} row {r} (len {l}) diverges from per-request")


def test_cache_dtype_default_and_parity():
    """``cache_dtype`` is configurable end-to-end: the backend default is
    fp32 in interpret/CPU mode (bf16 on TPU), the CLI spellings resolve, and
    a bf16 cache stays within logits-parity tolerance of the fp32 cache."""
    from repro.serve import default_cache_dtype, resolve_cache_dtype
    assert jax.default_backend() != "tpu"
    assert default_cache_dtype() == jnp.float32
    assert resolve_cache_dtype("auto") == jnp.float32
    assert resolve_cache_dtype("bf16") == jnp.bfloat16
    assert resolve_cache_dtype("fp32") == jnp.float32
    # quantized paged-pool spellings resolve...
    assert resolve_cache_dtype("int8") == jnp.int8
    assert resolve_cache_dtype("fp8") == jnp.float8_e4m3fn
    assert resolve_cache_dtype("float8_e4m3fn") == jnp.float8_e4m3fn
    # ...unknown names fail with the valid list spelled out...
    with pytest.raises(ValueError, match="valid names: auto.*int8"):
        resolve_cache_dtype("int4")
    # ...and the dense Engine refuses them (fleet-only storage dtypes)
    with pytest.raises(ValueError, match="fleet"):
        Engine(build_model(get_reduced("qwen1.5-0.5b")), params=None,
               cache_dtype=jnp.int8)

    cfg = get_reduced("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _inputs(cfg, b=2, s=10)
    outs = {}
    for name, dtype in (("fp32", jnp.float32), ("bf16", jnp.bfloat16)):
        logits, cache = model.prefill(params, batch, cap=12,
                                      cache_dtype=dtype)
        logits2, _ = model.decode(params, cache,
                                  batch["tokens"][:, -1:] * 0 + 1,
                                  jnp.int32(10))
        outs[name] = np.asarray(logits2[:, 0], np.float32)
    scale = np.abs(outs["fp32"]).max()
    np.testing.assert_allclose(outs["bf16"], outs["fp32"],
                               atol=2e-2 * scale, rtol=0,
                               err_msg="bf16 cache beyond parity tolerance")


def test_engine_sampling_varies_with_seed():
    cfg = get_reduced("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params)
    batch = _inputs(cfg, b=4, s=8)
    r1 = eng.generate(batch, max_new_tokens=8, temperature=1.0, seed=0)
    r2 = eng.generate(batch, max_new_tokens=8, temperature=1.0, seed=1)
    assert not np.array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))
