from repro.checkpoint.io import (  # noqa: F401
    has_snapshot,
    load_pytree,
    load_snapshot,
    save_pytree,
    save_snapshot,
    snapshot_path,
)
