"""Async fault-tolerant runtime: seeded determinism, staleness-bound parity
with the synchronous prediction exchange, straggler/preemption semantics,
checkpoint recovery, elastic membership, and History JSONL persistence."""
import os
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CodistConfig, TrainConfig, get_reduced
from repro.core.codistillation import model_slice
from repro.data import MarkovLM, make_lm_batch
from repro.models import build_model
from repro.runtime import (AsyncScheduler, FaultConfig, FaultSchedule,
                           parse_faults, simulate_allreduce)
from repro.train import History, stack_batches, train_codist

B, S = 4, 16
TASK = MarkovLM(vocab=64, seed=0)


def tiny_model():
    cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=1, d_model=32,
                  d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                  head_dim=16)
    return build_model(cfg)


def batches(step):
    return make_lm_batch(TASK, B, S, step, None, seed=0)


def coord_batches(n):
    def fn(step):
        return stack_batches([make_lm_batch(TASK, B, S, step, None, seed=0)
                              for _ in range(n)])
    return fn


def tc_for(steps, **kw):
    kw.setdefault("lr", 1e-3)
    kw.setdefault("warmup_steps", 2)
    kw.setdefault("optimizer", "adamw")
    kw.setdefault("seed", 0)
    return TrainConfig(total_steps=steps, **kw)


# ----------------------------------------------------------------------------
# determinism of the seeded schedule and of whole runs
# ----------------------------------------------------------------------------

def test_fault_schedule_deterministic():
    cfg = FaultConfig(n_peers=3, seed=7, speed_sigma=0.4,
                      straggler_peers=(1,), straggler_factor=4.0,
                      straggler_frac=0.3)
    a = FaultSchedule(cfg, 50)
    b = FaultSchedule(cfg, 50)
    np.testing.assert_array_equal(a.speeds, b.speeds)
    np.testing.assert_array_equal(a.mult, b.mult)
    c = FaultSchedule(replace(cfg, seed=8), 50)
    assert not (np.array_equal(a.speeds, c.speeds)
                and np.array_equal(a.mult, c.mult))
    # straggler coverage lands near the requested fraction
    frac = np.mean(a.mult[1] > 1.0)
    assert 0.2 <= frac <= 0.45
    assert np.all(a.mult[0] == 1.0)


def test_same_seed_identical_run():
    model = tiny_model()
    tc = tc_for(6)
    codist = CodistConfig(n_models=2, period=1)
    faults = FaultConfig(n_peers=2, seed=3, speeds=(1.0, 1.6),
                         preemptions=((1, 2, 4.0),))

    def go():
        return AsyncScheduler(model, tc, codist, batches, faults,
                              staleness_bound=2).run()

    r1, r2 = go(), go()
    assert r1.completion == r2.completion
    assert r1.staleness == r2.staleness
    for p in (0, 1):
        assert (r1.histories[p].series("task_loss")
                == r2.histories[p].series("task_loss"))


def test_mailbox_bills_each_transfer_once():
    from repro.runtime import Mailbox
    mb = Mailbox(None)
    mb.post(1, 0, 0.0, {"vals": jnp.zeros((4,), jnp.float32)})  # 16 bytes
    mb.collect(0, 0, [1])
    mb.collect(0, 1, [1])  # keep-last re-read: receiver already holds it
    assert mb.bytes_delivered == 16
    assert mb.stats.accepted == 2  # staleness is still measured per use
    mb.post(1, 1, 1.0, {"vals": jnp.zeros((4,), jnp.float32)})
    mb.collect(0, 2, [1])
    assert mb.bytes_delivered == 32


def test_fault_config_rejects_bad_joins():
    with pytest.raises(ValueError):
        FaultConfig(n_peers=2, joins=((0, 5.0),))  # would replace incumbent
    with pytest.raises(ValueError):
        FaultConfig(n_peers=2, joins=((2, 5.0), (2, 9.0)))  # duplicate
    assert FaultConfig(n_peers=2, joins=((2, 5.0), (3, 9.0))).n_total == 4


def test_parse_faults_rejects_conflicting_stragglers():
    with pytest.raises(ValueError):
        parse_faults("straggler=0*2@0.5,straggler=1*8@0.1", 2)
    f = parse_faults("straggler=0*2@0.5,straggler=1*2@0.5", 2)
    assert f.straggler_peers == (0, 1)
    assert f.straggler_factor == 2.0 and f.straggler_frac == 0.5


def test_parse_faults_roundtrip():
    f = parse_faults("straggler=1*4@0.25,preempt=0@3+5,fail=1@30,hetero=0.2",
                     n_peers=2, seed=9)
    assert f.straggler_peers == (1,)
    assert f.straggler_factor == 4.0 and f.straggler_frac == 0.25
    assert f.preemptions == ((0, 3, 5.0),)
    assert f.failures == ((1, 30),)
    assert f.speed_sigma == 0.2 and f.seed == 9
    assert parse_faults("", 2).n_peers == 2
    assert parse_faults("none", 2) == FaultConfig(n_peers=2)
    with pytest.raises(ValueError):
        parse_faults("bogus=1", 2)


def test_parse_faults_rejects_malformed_specs():
    """Every malformed clause gets an actionable ValueError naming the
    clause — never a silent misparse."""
    cases = {
        "preempt=1@3+-5": "pause duration",       # negative duration
        "preempt=1@3+0": "pause duration",        # zero-length pause
        "preempt=1@-3+5": "negative",             # negative step
        "fail=1@-2": "negative",
        "straggler=1*-4@0.2": "slowdown factor",  # non-positive factor
        "straggler=1*4@0": "step fraction",       # frac outside (0, 1]
        "straggler=1*4@1.5": "step fraction",
        "straggler=3*4@0.2": "out of range",      # peer >= n_peers
        "preempt=-1@3+5": "negative",             # negative peer
        "fail=x@3": "peer index",                 # non-numeric peer
        "preempt=1@here+5": "must be an integer", # non-numeric step
        "melt=1": "unknown fault clause",         # unknown kind
        "speeds=1.0:0": "must all be > 0",
        "hetero=-0.5": "negative",
    }
    for spec, needle in cases.items():
        with pytest.raises(ValueError, match=needle):
            parse_faults(spec, 2)
        with pytest.raises(ValueError) as exc:
            parse_faults(spec, 2)
        # the offending clause is named, so a bad flag is findable in a
        # comma-separated pile of clauses
        assert spec.split(",")[0].split("=")[0] in str(exc.value)


def test_parse_faults_rejects_overlapping_windows():
    # two preemptions on one peer at the same step would silently collapse
    # into one dict entry
    with pytest.raises(ValueError, match="overlapping"):
        parse_faults("preempt=1@3+5,preempt=1@3+9", 2)
    # distinct steps on one peer are fine
    f = parse_faults("preempt=1@3+5,preempt=1@9+5", 2)
    assert f.preemptions == ((1, 3, 5.0), (1, 9, 5.0))
    # a peer can only die once
    with pytest.raises(ValueError, match="only die once"):
        parse_faults("fail=1@3,fail=1@9", 2)
    # duplicate straggler clause on one peer would overlap episodes
    with pytest.raises(ValueError, match="overlap"):
        parse_faults("straggler=1*4@0.2,straggler=1*4@0.2", 2)


# ----------------------------------------------------------------------------
# staleness-bound 0 == the synchronous prediction exchange
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("period", [1, 2])
def test_s0_reproduces_sync_prediction_exchange(period):
    model = tiny_model()
    steps = 6
    tc = tc_for(steps)
    codist = CodistConfig(n_models=2, period=period)
    rep = AsyncScheduler(model, tc, codist, batches,
                         FaultConfig(n_peers=2, seed=0),
                         staleness_bound=0).run()
    assert rep.staleness["staleness_max"] == 0.0
    assert rep.staleness["payloads_dropped"] == 0

    state, hist = train_codist(model, codist, tc, coord_batches(2),
                               log_every=1)
    for p in (0, 1):
        np.testing.assert_allclose(
            rep.histories[p].series("task_loss"),
            hist.series(f"task_loss_per_model_{p}"), atol=5e-5)
        np.testing.assert_allclose(
            rep.histories[p].series("distill_loss"),
            hist.series(f"distill_loss_per_model_{p}"), atol=5e-5)
        for a, b in zip(jax.tree.leaves(rep.states[p].params),
                        jax.tree.leaves(model_slice(state.params, p))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4)


# ----------------------------------------------------------------------------
# straggler / preemption semantics: barrier gates, async doesn't
# ----------------------------------------------------------------------------

def test_straggler_gates_barrier_not_async():
    model = tiny_model()
    tc = tc_for(8)
    codist = CodistConfig(n_models=2, period=1)
    clean = FaultConfig(n_peers=2, seed=0)
    strag = FaultConfig(n_peers=2, seed=0, straggler_peers=(1,),
                        straggler_factor=4.0, straggler_frac=0.5)

    a_clean = AsyncScheduler(model, tc, codist, batches, clean,
                             staleness_bound=2).run()
    a_strag = AsyncScheduler(model, tc, codist, batches, strag,
                             staleness_bound=2).run()
    # healthy peer 0 never waits: its completion time is unchanged
    assert a_strag.completion[0] == a_clean.completion[0]
    assert a_strag.completion[1] > a_clean.completion[1]
    assert a_strag.time_to_first == a_clean.time_to_first

    r_clean = simulate_allreduce(model, tc, batches, clean)
    r_strag = simulate_allreduce(model, tc, batches, strag)
    assert r_strag.sim_time > r_clean.sim_time  # barrier pays for every slow step
    # preemption stalls the whole barrier job by the pause
    pre = FaultConfig(n_peers=2, seed=0, preemptions=((1, 3, 7.0),))
    r_pre = simulate_allreduce(model, tc, batches, pre)
    assert r_pre.sim_time == pytest.approx(r_clean.sim_time + 7.0)
    a_pre = AsyncScheduler(model, tc, codist, batches, pre,
                           staleness_bound=None).run()
    assert a_pre.completion[0] == a_clean.completion[0]


def test_staleness_bound_drop_vs_keep_last():
    model = tiny_model()
    tc = tc_for(8)
    codist = CodistConfig(n_models=2, period=1)
    hetero = FaultConfig(n_peers=2, seed=0, speeds=(1.0, 2.0))
    keep = AsyncScheduler(model, tc, codist, batches, hetero,
                          staleness_bound=None).run()
    assert keep.staleness["payloads_dropped"] == 0
    assert keep.staleness["staleness_max"] > 0
    drop = AsyncScheduler(model, tc, codist, batches, hetero,
                          staleness_bound=0).run()
    assert drop.staleness["payloads_dropped"] > 0
    assert drop.staleness["staleness_max"] == 0.0
    # dropped payloads mean those steps trained task-only (alpha gated off)
    alphas = drop.histories[0].series("alpha")
    assert 0.0 in alphas


# ----------------------------------------------------------------------------
# failure + checkpoint recovery, elastic membership
# ----------------------------------------------------------------------------

def test_failure_recovers_from_checkpoint_and_converges(tmp_path):
    model = tiny_model()
    steps = 12
    tc = tc_for(steps, lr=3e-3)
    codist = CodistConfig(n_models=2, period=1)
    faults = FaultConfig(n_peers=2, seed=0, failures=((1, 8),))
    rep = AsyncScheduler(model, tc, codist, batches, faults,
                         staleness_bound=None, checkpoint_dir=str(tmp_path),
                         checkpoint_every=3, recover_after=5.0).run()
    # the failed peer rewound to its step-6 snapshot, replayed, and finished
    assert rep.completion[1] > rep.completion[0]
    assert sorted(rep.completion) == [0, 1]
    hist1 = rep.histories[1]
    steps_logged = hist1.series("step")
    assert steps_logged != sorted(set(steps_logged))  # replayed steps appear twice
    assert max(steps_logged) == steps - 1
    assert rep.final_task_loss[1] < hist1.series("task_loss")[0]

    # without a checkpoint dir the failed peer stays dead
    dead = AsyncScheduler(model, tc, codist, batches, faults,
                          staleness_bound=None).run()
    assert 1 not in dead.completion and 0 in dead.completion


def test_elastic_join_burns_in_then_distills():
    model = tiny_model()
    steps = 10
    tc = tc_for(steps)
    codist = CodistConfig(n_models=2, period=1)
    faults = FaultConfig(n_peers=2, seed=0, joins=((2, 3.0),))
    rep = AsyncScheduler(model, tc, codist, batches, faults,
                         staleness_bound=None, join_burn_in=4).run()
    assert set(rep.completion) == {0, 1, 2}
    # joiner trains task-only through burn-in, then its distill loss activates
    alphas = rep.histories[2].series("alpha")
    assert alphas[:4] == [0.0] * 4
    assert any(a > 0 for a in alphas[4:])
    # the joiner's distill targets only flow once it publishes (post burn-in):
    # incumbents see weight 1 (each other) throughout, weight 2 after
    w0 = rep.histories[0].series("peer_weight")
    assert w0[0] == 1.0 and max(w0) == 2.0
    assert rep.completion[2] == pytest.approx(3.0 + steps)


# ----------------------------------------------------------------------------
# History JSONL persistence
# ----------------------------------------------------------------------------

def test_history_jsonl_roundtrip(tmp_path):
    h = History()
    h.log(0, {"loss": jnp.asarray(1.5), "vec": jnp.asarray([1.0, 2.0])},
          sim_time=0.25)
    h.log(5, {"loss": jnp.asarray(0.5)}, sim_time=5.0)
    path = os.path.join(str(tmp_path), "sub", "hist.jsonl")
    h.save(path)
    loaded = History.load(path)
    assert loaded.records == h.records
    assert loaded.series("loss") == [1.5, 0.5]
    assert loaded.last("vec_1") == 2.0


def test_report_save_histories(tmp_path):
    model = tiny_model()
    tc = tc_for(4)
    codist = CodistConfig(n_models=2, period=1)
    rep = AsyncScheduler(model, tc, codist, batches,
                         FaultConfig(n_peers=2, seed=0)).run()
    rep.save_histories(str(tmp_path))
    h0 = History.load(os.path.join(str(tmp_path), "peer0.jsonl"))
    assert h0.series("task_loss") == rep.histories[0].series("task_loss")
