"""Gradient parity of the custom-VJP fused losses vs the jnp references,
plus the structural guarantee the tentpole is about: with ``fused_losses``
enabled, no (T, V)-shaped fp32 temporary exists in the loss computation in
either direction (verified by jaxpr inspection), and every exchange
strategy's step runs end-to-end on the fused path.

All kernels run in interpret=True mode (CPU container); tolerance <=1e-4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codistillation as cd
from repro.kernels import ops
from repro.kernels import ref


TOL = dict(rtol=1e-4, atol=1e-4)


def _data(t=48, v=200, scale=3.0, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    logits = jax.random.normal(ks[0], (2, t // 2, v)) * scale
    target = jax.random.normal(ks[1], (2, t // 2, v)) * scale
    labels = jax.random.randint(ks[2], (2, t // 2), 0, v)
    mask = (jax.random.uniform(ks[3], (2, t // 2)) > 0.3).astype(jnp.float32)
    return logits, target, labels, mask


class TestFusedCEGrads:
    @pytest.mark.parametrize("ls", [0.0, 0.1])
    @pytest.mark.parametrize("masked", [False, True])
    def test_grad_matches_jnp_reference(self, ls, masked):
        logits, _, labels, mask = _data()
        m = mask if masked else None
        ref_fn = lambda x: cd.cross_entropy(x, labels, ls, m, fused=False)
        fused_fn = lambda x: ops.fused_cross_entropy_loss(x, labels, ls, m,
                                                          interpret=True)
        np.testing.assert_allclose(fused_fn(logits), ref_fn(logits), **TOL)
        np.testing.assert_allclose(jax.grad(fused_fn)(logits),
                                   jax.grad(ref_fn)(logits), **TOL)

    def test_grad_wrt_label_smoothing_schedule(self):
        """ls is a traced scalar (schedule output) — must stay differentiable
        through the custom-VJP boundary."""
        logits, _, labels, mask = _data()
        ref_fn = lambda s: cd.cross_entropy(logits, labels, s, mask,
                                            fused=False)
        fused_fn = lambda s: ops.fused_cross_entropy_loss(
            logits, labels, s, mask, interpret=True)
        np.testing.assert_allclose(jax.grad(fused_fn)(0.1),
                                   jax.grad(ref_fn)(0.1), **TOL)

    def test_bf16_logits(self):
        logits, _, labels, _ = _data(scale=2.0)
        lb = logits.astype(jnp.bfloat16)
        got = jax.grad(lambda x: ops.fused_cross_entropy_loss(
            x, labels, 0.1, interpret=True))(lb)
        want = jax.grad(lambda x: cd.cross_entropy(x, labels, 0.1,
                                                   fused=False))(lb)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-2, atol=1e-2)


class TestFusedDistillGrads:
    @pytest.mark.parametrize("mode", ["mse", "kl"])
    @pytest.mark.parametrize("masked", [False, True])
    def test_grads_match_jnp_reference(self, mode, masked):
        logits, target, _, mask = _data()
        m = mask if masked else None
        ref_f = cd.distill_mse if mode == "mse" else cd.distill_kl
        ref_fn = lambda a, b: ref_f(a, b, m, fused=False)
        fused_fn = lambda a, b: ops.fused_distill_mean(a, b, mode, m,
                                                       interpret=True)
        np.testing.assert_allclose(fused_fn(logits, target),
                                   ref_fn(logits, target), **TOL)
        for argnum in (0, 1):  # student AND (stop-gradient-free) target side
            np.testing.assert_allclose(
                jax.grad(fused_fn, argnum)(logits, target),
                jax.grad(ref_fn, argnum)(logits, target), **TOL)

    @pytest.mark.parametrize("mode", ["mse", "kl"])
    def test_per_token_kernel_grad_vs_ref_oracle(self, mode):
        """Bare kernel-level parity against kernels/ref.py oracles."""
        t, v = 32, 128
        a = jax.random.normal(jax.random.key(0), (t, v)) * 2
        b = jax.random.normal(jax.random.key(1), (t, v)) * 2
        oracle = ref.distill_mse_ref if mode == "mse" else ref.distill_kl_ref
        fused_fn = lambda x, y: jnp.sum(ops.fused_distill_mean(
            x, y, mode, interpret=True)) * t  # sum of per-token losses
        ref_fn = lambda x, y: jnp.sum(oracle(x, y))
        np.testing.assert_allclose(jax.grad(fused_fn)(a, b),
                                   jax.grad(ref_fn)(a, b), **TOL)


class TestCombinedKernelGrads:
    @pytest.mark.parametrize("mode", ["mse", "kl"])
    def test_combined_matches_separate(self, mode):
        logits, target, labels, mask = _data()
        ref_f = cd.distill_mse if mode == "mse" else cd.distill_kl

        def fused_total(a, b):
            task, dist = ops.fused_ce_distill(a, b, labels, mode, 0.1, mask,
                                              interpret=True)
            return task + 0.7 * dist

        def ref_total(a, b):
            return (cd.cross_entropy(a, labels, 0.1, mask, fused=False)
                    + 0.7 * ref_f(a, b, mask, fused=False))

        np.testing.assert_allclose(fused_total(logits, target),
                                   ref_total(logits, target), **TOL)
        for argnum in (0, 1):
            np.testing.assert_allclose(
                jax.grad(fused_total, argnum)(logits, target),
                jax.grad(ref_total, argnum)(logits, target), **TOL)


# ----------------------------------------------------------------------------
# structural guarantee: no (T, V) fp32 temporaries outside the kernels
# ----------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for val in jax.tree.leaves(eqn.params, is_leaf=lambda x: isinstance(
                x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))):
            if isinstance(val, jax.core.ClosedJaxpr):
                yield from _iter_eqns(val.jaxpr)
            elif isinstance(val, jax.core.Jaxpr):
                yield from _iter_eqns(val)


# data movement of the logits themselves or call boundaries returning the
# (T, V) gradient — not math temporaries (inner jaxprs are recursed anyway)
_ALLOWED_TV_PRODUCERS = {"pallas_call", "reshape", "squeeze", "slice",
                         "transpose", "copy", "convert_element_type",
                         "pjit", "custom_vjp_call", "custom_vjp_call_jaxpr",
                         "custom_jvp_call"}


def _tv_offenders(fn, *args, shape):
    from jax.interpreters import partial_eval as pe
    closed = jax.make_jaxpr(fn)(*args)
    # drop dead code first (e.g. instantiated-then-unused zero cotangents
    # that XLA would DCE anyway)
    jaxpr, _ = pe.dce_jaxpr(closed.jaxpr,
                            [True] * len(closed.jaxpr.outvars))
    offenders = set()
    for eqn in _iter_eqns(jaxpr):
        for var in eqn.outvars:
            aval = var.aval
            if (getattr(aval, "shape", None) == shape
                    and aval.dtype == jnp.float32
                    and eqn.primitive.name not in _ALLOWED_TV_PRODUCERS):
                offenders.add(eqn.primitive.name)
    return offenders


class TestNoVocabWidthTemporaries:
    # block-aligned (no wrapper padding) AND strictly larger than one
    # (256, 512) block, so interpret-mode kernel internals (which trace as
    # ordinary tile-shaped eqns) can never collide with the full (T, V) shape
    T, V = 512, 1024

    def _args(self):
        logits = jax.random.normal(jax.random.key(0), (self.T, self.V))
        target = jax.random.normal(jax.random.key(1), (self.T, self.V))
        labels = jax.random.randint(jax.random.key(2), (self.T,), 0, self.V)
        return logits, target, labels

    def test_fused_ce_value_and_grad_is_clean(self):
        logits, _, labels = self._args()
        fn = jax.value_and_grad(
            lambda x: ops.fused_cross_entropy_loss(x, labels, 0.1,
                                                   interpret=True))
        assert _tv_offenders(fn, logits, shape=(self.T, self.V)) == set()

    @pytest.mark.parametrize("mode", ["mse", "kl"])
    def test_fused_distill_value_and_grad_is_clean(self, mode):
        logits, target, _ = self._args()
        fn = jax.value_and_grad(
            lambda a: ops.fused_distill_mean(a, target, mode,
                                             interpret=True))
        assert _tv_offenders(fn, logits, shape=(self.T, self.V)) == set()

    @pytest.mark.parametrize("mode", ["mse", "kl"])
    def test_combined_value_and_grad_is_clean(self, mode):
        logits, target, labels = self._args()
        fn = jax.value_and_grad(lambda a: sum(ops.fused_ce_distill(
            a, target, labels, mode, 0.1, interpret=True)))
        assert _tv_offenders(fn, logits, shape=(self.T, self.V)) == set()

    def test_jnp_path_is_dirty(self):
        """Sanity: the check has teeth — the jnp path DOES materialize."""
        logits, _, labels = self._args()
        fn = jax.value_and_grad(
            lambda x: cd.cross_entropy(x, labels, 0.1, fused=False))
        assert _tv_offenders(fn, logits, shape=(self.T, self.V)) != set()


# ----------------------------------------------------------------------------
# every step variant runs end-to-end with fused_losses enabled
# ----------------------------------------------------------------------------

class TestStepVariantsFused:
    @pytest.fixture(scope="class")
    def setup(self):
        from dataclasses import replace
        from repro.configs import get_reduced
        from repro.data import MarkovLM, make_lm_batch
        from repro.models import build_model
        from repro.optim import make_optimizer
        from repro.train import init_codist_state, init_train_state, \
            stack_batches
        cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=1, d_model=32,
                      d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                      head_dim=16)
        model = build_model(cfg)
        task = MarkovLM(vocab=64, seed=0)
        opt_init, _ = make_optimizer("sgdm")
        state = init_codist_state(model, jax.random.key(0), 2, opt_init,
                                  with_stale=True)
        single = init_train_state(model, jax.random.key(0), opt_init)
        batch1 = make_lm_batch(task, 2, 16, 0, None, seed=0)
        batch = stack_batches([batch1, batch1])
        return model, state, single, batch1, batch

    def _tc(self, fused):
        from repro.configs import TrainConfig
        return TrainConfig(lr=1e-2, total_steps=10, warmup_steps=0,
                           optimizer="sgdm", label_smoothing=0.1,
                           fused_losses=fused)

    @pytest.mark.parametrize("distill_loss", ["mse", "kl"])
    def test_prediction_step(self, setup, distill_loss):
        from repro.configs import CodistConfig
        from repro.train.engine import PredictionExchange, build_train_step
        model, state, _, _, batch = setup
        codist = CodistConfig(n_models=2, distill_loss=distill_loss)
        for distill in (True, False):
            v = "on" if distill else "off"
            s_f, m_f = build_train_step(
                model, self._tc(True), codist,
                PredictionExchange(codist)).variants[v](state, batch)
            s_r, m_r = build_train_step(
                model, self._tc(False), codist,
                PredictionExchange(codist)).variants[v](state, batch)
            assert np.isfinite(float(m_f["loss"]))
            np.testing.assert_allclose(float(m_f["loss"]),
                                       float(m_r["loss"]), rtol=1e-4,
                                       atol=1e-4)

    def test_checkpoint_step(self, setup):
        from repro.configs import CodistConfig
        from repro.train.engine import CheckpointExchange, build_train_step
        model, state, _, _, batch = setup
        codist = CodistConfig(n_models=2, mode="checkpoints")
        _, m_f = build_train_step(
            model, self._tc(True), codist,
            CheckpointExchange(codist)).variants["on"](state, batch)
        _, m_r = build_train_step(
            model, self._tc(False), codist,
            CheckpointExchange(codist)).variants["on"](state, batch)
        np.testing.assert_allclose(float(m_f["loss"]), float(m_r["loss"]),
                                   rtol=1e-4, atol=1e-4)

    def test_pipelined_step(self, setup):
        from repro.configs import CodistConfig
        from repro.train.engine import PipelinedPredictions, build_train_step
        from repro.train.state import init_peer_state
        model, state, _, _, batch = setup
        codist = CodistConfig(n_models=2, pipelined=True)
        logits, _ = model.forward(
            jax.tree.map(lambda x: x[0], state.params),
            jax.tree.map(lambda x: x[0], batch))
        peer = init_peer_state(batch, (2,) + logits.shape)
        st = state._replace(peer=peer)
        _, m_f = build_train_step(
            model, self._tc(True), codist,
            PipelinedPredictions(codist)).variants["on"](st, batch)
        _, m_r = build_train_step(
            model, self._tc(False), codist,
            PipelinedPredictions(codist)).variants["on"](st, batch)
        np.testing.assert_allclose(float(m_f["loss"]), float(m_r["loss"]),
                                   rtol=1e-4, atol=1e-4)

    def test_allreduce_step(self, setup):
        from repro.train.engine import AllReduce, build_train_step
        model, _, single, batch1, _ = setup
        _, m_f = build_train_step(
            model, self._tc(True), None,
            AllReduce()).variants["on"](single, batch1)
        _, m_r = build_train_step(
            model, self._tc(False), None,
            AllReduce()).variants["on"](single, batch1)
        np.testing.assert_allclose(float(m_f["loss"]), float(m_r["loss"]),
                                   rtol=1e-4, atol=1e-4)
