"""Declarative sweep specs for the paper's experiment grid.

The paper's central claim (Sections 4-5) is a *grid* result: codistillation
matches synchronous data-parallel SGD across batch sizes and learning-rate
schedules once its regularization effect (alpha schedules, burn-in) is
accounted for. A :class:`SweepSpec` declares that grid once — the
cross-product of

    {batch size} x {LR schedule} x {exchange mode} x {alpha schedule}
                 x {peers} x {seeds}

— and :meth:`SweepSpec.cells` expands it into canonicalized, deduplicated
:class:`Cell`\\ s. Canonicalization encodes which axes are meaningful for
which mechanism: the ``allreduce`` baseline trains ONE model with no
distillation term, so its ``alpha`` and ``peers`` coordinates collapse
(otherwise the grid would re-run an identical baseline once per alpha x
peers combination). Seeds are a real axis: the aggregator reports final
loss +- range across them, the paper's error bars.

Specs load from YAML (committed under ``experiments/specs/``) or JSON;
every field of the file maps 1:1 onto a dataclass field below, so the file
format is the dataclass.
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

#: modes executed by the synchronous engine (``build_train_step`` + ``train``)
SYNC_MODES = ("allreduce", "codist", "codist-ckpt", "codist-pipelined")
#: modes executed by the async runtime (``AsyncScheduler``, clean schedule)
ASYNC_MODES = ("codist-async",)
KNOWN_MODES = SYNC_MODES + ASYNC_MODES


def _slug(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9.]+", "_", str(s)).strip("_")


@dataclass(frozen=True)
class LRPoint:
    """One point on the learning-rate-schedule axis (Section 4 / A.4).

    ``scale_with_batch`` applies Goyal et al.'s linear scaling rule
    (``lr * batch / base_batch``) so one point covers every batch size the
    way the paper's scaling study does.
    """
    name: str
    kind: str = "cosine"          # 'cosine' | 'step' | 'constant'
    lr: float = 1e-3
    warmup_frac: float = 0.1      # fraction of total steps spent warming up
    scale_with_batch: bool = False
    base_batch: int = 256

    def __post_init__(self):
        if self.kind not in ("cosine", "step", "constant"):
            raise ValueError(f"unknown LR schedule kind {self.kind!r}")

    def resolve_lr(self, batch: int) -> float:
        if self.scale_with_batch:
            return self.lr * batch / max(1, self.base_batch)
        return self.lr

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "LRPoint":
        return cls(**d)


@dataclass(frozen=True)
class AlphaPoint:
    """One point on the distillation-weight-schedule axis.

    The three paper-motivated shapes: ``constant`` (vision, alpha=1),
    ``burn-in delayed`` (Anil et al.: alpha=0 for the first
    ``burn_in_frac`` of training), and ``ramped`` (NMT: alpha grown by
    ``growth`` per epoch). All three are expressible with the same triple.
    """
    name: str
    alpha0: float = 1.0
    growth: float = 1.0           # per-epoch multiplier (>1 => ramped)
    burn_in_frac: float = 0.0     # fraction of total steps with alpha == 0

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "AlphaPoint":
        return cls(**d)


#: the collapsed alpha coordinate for mechanisms without a distillation term
NONE_ALPHA = AlphaPoint("none", alpha0=0.0)

#: ``model_overrides`` shrinking the standard reduced() config to a
#: seconds-per-cell smoke model — shared by the sweep_smoke benchmark and
#: the tests (``experiments/specs/paper_grid_small.yaml`` mirrors it)
TINY_OVERRIDES = (("d_model", 64), ("d_ff", 128), ("vocab_size", 128),
                  ("num_heads", 2), ("num_kv_heads", 2), ("head_dim", 32))


@dataclass(frozen=True)
class Cell:
    """One fully-resolved grid cell: everything ``run_cell`` needs."""
    sweep: str
    arch: str
    seq_len: int
    steps: int
    optimizer: str
    distill_loss: str
    batch: int
    lr: LRPoint
    mode: str
    alpha: AlphaPoint
    peers: int
    seed: int
    overrides: Tuple[Tuple[str, Any], ...] = ()

    @property
    def cell_id(self) -> str:
        """Stable filesystem-safe id; doubles as the dedup key (axis names
        are validated unique per spec, so ids are injective on the grid)."""
        return (f"{_slug(self.mode)}-b{self.batch}-{_slug(self.lr.name)}"
                f"-a{_slug(self.alpha.name)}-n{self.peers}-s{self.seed}")

    @property
    def grid_key(self) -> Tuple[str, int, str, str, int]:
        """Aggregation key: the grid coordinates MINUS the seed axis."""
        return (self.mode, self.batch, self.lr.name, self.alpha.name,
                self.peers)

    @property
    def baseline_key(self) -> Tuple[int, str]:
        """The (batch, lr) coordinates shared with the all-reduce baseline
        this cell is compared against in the paper-style gap tables."""
        return (self.batch, self.lr.name)


@dataclass(frozen=True)
class SweepSpec:
    """Declarative description of one experiment grid."""
    name: str
    arch: str = "qwen1.5-0.5b"
    seq_len: int = 16
    steps: int = 50
    optimizer: str = "adamw"
    distill_loss: str = "mse"
    seeds: Tuple[int, ...] = (0,)
    batch_sizes: Tuple[int, ...] = (8,)
    lr_schedules: Tuple[LRPoint, ...] = (LRPoint("cos"),)
    modes: Tuple[str, ...] = ("allreduce", "codist")
    alpha_schedules: Tuple[AlphaPoint, ...] = (AlphaPoint("const"),)
    peers: Tuple[int, ...] = (2,)
    # reduced-model config overrides (e.g. {"d_model": 64}) applied with
    # dataclasses.replace on get_reduced(arch) — lets CI grids shrink the
    # model below the standard reduced() size
    model_overrides: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        for axis in ("seeds", "batch_sizes", "lr_schedules", "modes",
                     "alpha_schedules", "peers"):
            if not getattr(self, axis):
                # an empty axis would silently expand to ZERO cells — a
                # typo'd grid must not read as a successful sweep
                raise ValueError(f"axis {axis!r} must be non-empty")
        unknown = [m for m in self.modes if m not in KNOWN_MODES]
        if unknown:
            raise ValueError(f"unknown mode(s) {unknown}; "
                             f"known: {list(KNOWN_MODES)}")
        for axis, pts in (("lr_schedules", self.lr_schedules),
                          ("alpha_schedules", self.alpha_schedules)):
            # cell ids carry SLUGGED axis names, so slugs (not just raw
            # names) must be unique or distinct cells would silently dedup
            slugs = [_slug(p.name) for p in pts]
            if len(slugs) != len(set(slugs)):
                raise ValueError(
                    f"duplicate {axis} names after slugging: "
                    f"{[p.name for p in pts]} -> {slugs}")
        if not re.match(r"^[A-Za-z0-9_\-]+$", self.name or ""):
            raise ValueError(f"sweep name {self.name!r} must be a slug "
                             "(it names the results directory)")
        if min(self.batch_sizes) < 1 or min(self.peers) < 2:
            raise ValueError("batch_sizes must be >=1 and peers >=2")

    # ------------------------------------------------------------------
    def cells(self) -> List[Cell]:
        """Expand the cross-product, canonicalize collapsed axes, dedup,
        and order baseline-first so truncated runs (``--max-cells``) still
        contain the all-reduce reference for each (batch, lr) group."""
        out: List[Cell] = []
        seen = set()
        for batch in self.batch_sizes:
            for lrp in self.lr_schedules:
                for mode in self.modes:
                    for alphap in self.alpha_schedules:
                        for n in self.peers:
                            for seed in self.seeds:
                                a, p = alphap, n
                                if mode == "allreduce":
                                    a, p = NONE_ALPHA, 1
                                cell = Cell(
                                    sweep=self.name, arch=self.arch,
                                    seq_len=self.seq_len, steps=self.steps,
                                    optimizer=self.optimizer,
                                    distill_loss=self.distill_loss,
                                    batch=batch, lr=lrp, mode=mode,
                                    alpha=a, peers=p, seed=seed,
                                    overrides=self.model_overrides)
                                if cell.cell_id in seen:
                                    continue
                                seen.add(cell.cell_id)
                                out.append(cell)
        out.sort(key=lambda c: (c.batch, c.lr.name, c.mode != "allreduce",
                                c.mode, c.alpha.name, c.peers, c.seed))
        return out


# ----------------------------------------------------------------------------
# (de)serialization
# ----------------------------------------------------------------------------

def spec_from_dict(doc: Dict[str, Any]) -> SweepSpec:
    """Dict (parsed YAML/JSON) -> SweepSpec. Lists become tuples; the two
    structured axes accept plain dicts."""
    d = dict(doc)
    if "lr_schedules" in d:
        d["lr_schedules"] = tuple(
            p if isinstance(p, LRPoint) else LRPoint.from_dict(p)
            for p in d["lr_schedules"])
    if "alpha_schedules" in d:
        d["alpha_schedules"] = tuple(
            p if isinstance(p, AlphaPoint) else AlphaPoint.from_dict(p)
            for p in d["alpha_schedules"])
    if "model_overrides" in d and isinstance(d["model_overrides"], dict):
        d["model_overrides"] = tuple(sorted(d["model_overrides"].items()))
    for key in ("seeds", "batch_sizes", "modes", "peers"):
        if key in d:
            d[key] = tuple(d[key])
    known = {f.name for f in dataclasses.fields(SweepSpec)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown spec field(s) {sorted(unknown)}; "
                         f"known: {sorted(known)}")
    return SweepSpec(**d)


def spec_to_dict(spec: SweepSpec) -> Dict[str, Any]:
    return dataclasses.asdict(spec)


def cell_to_dict(cell: Cell) -> Dict[str, Any]:
    return dataclasses.asdict(cell)


def load_spec(path: str) -> SweepSpec:
    """Load a spec from ``.yaml``/``.yml`` (needs pyyaml) or ``.json``."""
    with open(path) as f:
        text = f.read()
    if path.endswith((".yaml", ".yml")):
        try:
            import yaml
        except ImportError as e:  # pragma: no cover - container ships pyyaml
            raise RuntimeError(
                f"loading {path} needs pyyaml (pip install pyyaml) — or "
                "convert the spec to .json, which loads without it") from e
        doc = yaml.safe_load(text)
    else:
        doc = json.loads(text)
    if not isinstance(doc, dict):
        raise ValueError(f"spec {path} must be a mapping, got {type(doc)}")
    return spec_from_dict(doc)
