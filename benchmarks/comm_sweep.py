"""Full Section-3 communication sweep over the ASSIGNED architectures.

For every assigned arch at the train_4k shape: bits/iteration/device over the
cross-group links for all_reduce vs codistillation {predictions, checkpoints}
x period T x compression — the complete analytic Figure-1 grid at LLM scale
(the dry-run's HLO cross-pod measurements validate the T=1 column; the rest
follow the model exactly since period/compression act multiplicatively).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import ASSIGNED_ARCHS, CodistConfig, INPUT_SHAPES, get_config
from repro.core import comm_model as cm


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    shape = INPUT_SHAPES["train_4k"]
    archs = ASSIGNED_ARCHS[:3] if quick else ASSIGNED_ARCHS
    for arch in archs:
        cfg = get_config(arch)
        b_model = cm.model_bits(cfg, param_bits=16)  # bf16 training
        ar = cm.allreduce_bits(b_model)
        per_model_batch = shape.global_batch // 2
        variants = {
            "pred_T1": CodistConfig(n_models=2, period=1),
            "pred_T5": CodistConfig(n_models=2, period=5),
            "pred_T1_topk64": CodistConfig(n_models=2, period=1,
                                           compression="topk", topk=64),
            "pred_T5_topk64": CodistConfig(n_models=2, period=5,
                                           compression="topk", topk=64),
            "pred_T1_sub256": CodistConfig(n_models=2, period=1,
                                           compression="subsample",
                                           subsample=256),
            "ckpt_T50": CodistConfig(n_models=2, mode="checkpoints",
                                     period=50),
        }
        rows.append({"name": f"comm/{arch}/allreduce_bits",
                     "derived": f"{ar.bits_per_iter_per_device:.3e}"})
        for tag, codist in variants.items():
            c = cm.codist_cost(cfg, codist, per_model_batch,
                               seq_len=shape.seq_len, param_bits=16,
                               logit_bits=16)
            rows.append({"name": f"comm/{arch}/{tag}_ratio",
                         "derived": round(c.ratio_vs(ar), 2)})
    return rows
