"""Batched decode over the paged KV pool: one jitted step for all slots.

Mirrors ``LM.decode``'s scan-over-layers, but attention sublayers read/write
the shared block pool through the ``repro.kernels.paged_cache`` kernels
instead of a dense per-call cache, and every slot carries its OWN absolute
position (= its current context length) — the ragged substrate continuous
batching needs. Recurrent sublayers (mamba / rwkv) reuse the model's
``_sublayer_decode`` unchanged (their state is position-free).

Two attention paths, numerically pinned against each other:

* ``fused_attention=False`` — the jnp oracle: ``paged_gather`` a dense
  ``(S, MB*BS, KVh, hd)`` context, dense fp32 masked softmax. Same
  projections, same fp32 softmax as the dense engine path — masked (dead /
  padded) slots contribute exactly 0 after ``exp(NEG - max)`` underflow, so
  per-slot logits match single-request ``Engine.generate`` decode and
  greedy streams are token-identical (the fleet-vs-engine parity pinned in
  tests/test_fleet.py).
* ``fused_attention=True`` (the default) — the
  ``repro.kernels.paged_attention`` streaming-softmax kernel consumes the
  block table directly: the gather temporary never exists and each live KV
  block is read exactly once (Mosaic on TPU, interpret on CPU — the usual
  ``auto_interpret`` convention). Logits parity vs the oracle is <=1e-4 at
  fp32 cache dtype (tests/test_paged_attention.py).

Quantized pools (``cache_dtype`` int8/fp8) append through the fused
``paged_scatter_quant`` (quantize-at-scatter) and dequantize per-row inside
whichever attention path runs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import paged_attention_decode
from repro.kernels.paged_cache import (paged_gather, paged_scatter,
                                       paged_scatter_quant)
from repro.models import attention as attn
from repro.models.common import apply_norm, embed_tokens, lm_head
from repro.models.ffn import ffn_forward
from repro.models.moe import moe_forward
from repro.models.transformer import _n_scan, _sub_kinds, _sublayer_decode

PyTree = Any


def _paged_attention_decode(p: Dict, x: jax.Array, kv: Dict[str, jax.Array],
                            table: jax.Array, lengths: jax.Array,
                            write_slot: jax.Array, write_off: jax.Array,
                            cfg, fused: bool
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode for every slot against its paged context.

    x (S,1,d); kv {"k","v"[,"k_scale","v_scale"]}: (NB,BS,KVh,hd) pools for
    THIS layer (plus (NB,BS) fp32 row scales when quantized); table (S,MB);
    lengths (S,) = each slot's context length == the new token's absolute
    position; write_slot/write_off (NB,) from ``PagedCachePool.write_maps``
    (inactive slots appear in no map entry, so they never touch the pool).
    """
    quantized = "k_scale" in kv
    bs = kv["k"].shape[1]
    positions = lengths[:, None]                       # (S,1) per-slot pos
    q, k_new, v_new = attn._project_qkv(p, x, cfg)
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k_new = attn.apply_rope(k_new, positions, cfg.rope_theta)

    if quantized:
        k_pool, k_sc = paged_scatter_quant(kv["k"], kv["k_scale"],
                                           k_new[:, 0], write_slot, write_off)
        v_pool, v_sc = paged_scatter_quant(kv["v"], kv["v_scale"],
                                           v_new[:, 0], write_slot, write_off)
        kv_out = {"k": k_pool, "v": v_pool,
                  "k_scale": k_sc, "v_scale": v_sc}
    else:
        k_pool = paged_scatter(kv["k"], k_new[:, 0], write_slot, write_off)
        v_pool = paged_scatter(kv["v"], v_new[:, 0], write_slot, write_off)
        k_sc = v_sc = None
        kv_out = {"k": k_pool, "v": v_pool}

    if fused:
        o = paged_attention_decode(q[:, 0], k_pool, v_pool, table, lengths,
                                   k_scale=k_sc, v_scale=v_sc)  # (S, H, hd)
        out = attn._out_proj(p, o[:, None].astype(x.dtype))
        return out, kv_out

    n_live = (lengths + bs) // bs                      # blocks incl. new token
    k = paged_gather(k_pool, table, n_live)            # (S, MB*BS, KVh, hd)
    v = paged_gather(v_pool, table, n_live)
    if quantized:
        ks = paged_gather(k_sc[..., None, None], table, n_live)  # (S,T,1,1)
        vs = paged_gather(v_sc[..., None, None], table, n_live)
        k = (k.astype(jnp.float32) * ks).astype(x.dtype)
        v = (v.astype(jnp.float32) * vs).astype(x.dtype)

    scores = attn._gqa_scores(q, k)                    # (S, H, 1, MB*BS)
    slot_pos = jnp.arange(k.shape[1])
    valid = (slot_pos[None, :] <= lengths[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, attn.NEG_INF)
    w = attn._softmax(scores).astype(x.dtype)
    out = attn._out_proj(p, attn._gqa_combine(w, v))
    return out, kv_out


def _attn_sublayer(p: Dict, x: jax.Array, kv, table, lengths, write_slot,
                   write_off, cfg, ffn_kind: str, fused: bool):
    h = apply_norm(p["norm1"], x, cfg.norm_eps)
    h, kv = _paged_attention_decode(p["mix"], h, kv, table, lengths,
                                    write_slot, write_off, cfg, fused)
    x = x + h
    h2 = apply_norm(p["norm2"], x, cfg.norm_eps)
    if ffn_kind == "moe":
        h2, _ = moe_forward(p["ffn"], h2, cfg, capacity_factor=0.0)
    else:
        h2 = ffn_forward(p["ffn"], h2, cfg)
    return x + h2, kv


def build_decode_step(model, fused_attention: Optional[bool] = None):
    """Compile-once batched decode: (params, kv, states, table, lengths,
    write_slot, write_off, tokens) -> (logits (S,V), kv, states).

    ``fused_attention`` None/True (the default) runs the
    ``kernels.paged_attention`` streaming-softmax kernel; False pins the
    jnp gather+dense-softmax oracle. All operands have step-invariant
    shapes, so the returned jit compiles exactly once per fleet engine and
    every scheduler tick reuses it.
    """
    cfg = model.cfg
    kinds = _sub_kinds(cfg)
    fused = True if fused_attention is None else bool(fused_attention)

    def step(params, kv, states, table, lengths, write_slot, write_off,
             tokens):
        dtype = cfg.activation_dtype
        x = embed_tokens(params["embed"], tokens, dtype)   # (S,1,d)
        if "embed_norm" in params:
            x = apply_norm(params["embed_norm"], x, cfg.norm_eps)

        def body(carry, xs):
            h = carry
            lp, kv_l, st_l = xs
            kv_out, st_out = {}, {}
            for i, (m, f) in enumerate(kinds):
                name = f"sub{i}"
                if m == "attn":
                    h, kv_out[name] = _attn_sublayer(
                        lp[name], h, kv_l[name], table, lengths,
                        write_slot, write_off, cfg, f, fused)
                else:
                    h, st_out[name] = _sublayer_decode(
                        lp[name], h, st_l[name], cfg, m, f,
                        jnp.zeros((), jnp.int32))
            return h, (kv_out, st_out)

        x, (kv, states) = jax.lax.scan(body, x, (params["layers"], kv,
                                                 states))
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = lm_head(params["embed"], x)               # (S,1,V)
        return logits[:, -1], kv, states

    _n_scan(cfg)           # called for effect: validates the scan layout early
    return jax.jit(step)


def _paged_attention_verify(p: Dict, x: jax.Array, kv: Dict[str, jax.Array],
                            table: jax.Array, lengths: jax.Array,
                            write_slots: jax.Array, write_offs: jax.Array,
                            cfg, fused: bool
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """k-token speculative verify for every slot in one forward.

    x (S,k,d) — the k draft inputs per slot at positions
    ``lengths[s] + j``; write_slots/write_offs (k,NB) from
    ``PagedCachePool.write_maps_k`` (one scatter per draft position, k is
    static so the loop unrolls inside the jit). The fused path expands each
    slot into k pseudo-slots sharing its block table — the decode kernel's
    inclusive ``pos <= length`` mask then gives exact causal semantics:
    pseudo-slot (s, j) attends positions ``0..lengths[s]+j``, i.e. the full
    prior context plus drafts ``<= j``. Bitwise, each row reproduces what a
    plain one-token decode at that position would compute, which is what
    makes accept/reject resampling exact at temperature 0.
    """
    quantized = "k_scale" in kv
    bs = kv["k"].shape[1]
    S, kq, _ = x.shape
    positions = lengths[:, None] + jnp.arange(kq)[None, :]      # (S,k)
    q, k_new, v_new = attn._project_qkv(p, x, cfg)
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k_new = attn.apply_rope(k_new, positions, cfg.rope_theta)

    k_pool, v_pool = kv["k"], kv["v"]
    k_sc, v_sc = kv.get("k_scale"), kv.get("v_scale")
    for j in range(kq):
        if quantized:
            k_pool, k_sc = paged_scatter_quant(k_pool, k_sc, k_new[:, j],
                                               write_slots[j], write_offs[j])
            v_pool, v_sc = paged_scatter_quant(v_pool, v_sc, v_new[:, j],
                                               write_slots[j], write_offs[j])
        else:
            k_pool = paged_scatter(k_pool, k_new[:, j],
                                   write_slots[j], write_offs[j])
            v_pool = paged_scatter(v_pool, v_new[:, j],
                                   write_slots[j], write_offs[j])
    kv_out = ({"k": k_pool, "v": v_pool, "k_scale": k_sc, "v_scale": v_sc}
              if quantized else {"k": k_pool, "v": v_pool})

    if fused:
        qf = q.reshape(S * kq, *q.shape[2:])                    # (S*k, H, hd)
        table_x = jnp.repeat(table, kq, axis=0)                 # (S*k, MB)
        len_x = positions.reshape(-1)                           # (S*k,)
        o = paged_attention_decode(qf, k_pool, v_pool, table_x, len_x,
                                   k_scale=k_sc, v_scale=v_sc)
        o = o.reshape(S, kq, *o.shape[1:])                      # (S,k,H,hd)
        return attn._out_proj(p, o.astype(x.dtype)), kv_out

    last = positions[:, -1]                            # deepest draft position
    n_live = jnp.minimum((last + bs) // bs, table.shape[1])
    k = paged_gather(k_pool, table, n_live)            # (S, MB*BS, KVh, hd)
    v = paged_gather(v_pool, table, n_live)
    if quantized:
        ks = paged_gather(k_sc[..., None, None], table, n_live)
        vs = paged_gather(v_sc[..., None, None], table, n_live)
        k = (k.astype(jnp.float32) * ks).astype(x.dtype)
        v = (v.astype(jnp.float32) * vs).astype(x.dtype)

    scores = attn._gqa_scores(q, k)                    # (S, H, k, MB*BS)
    slot_pos = jnp.arange(k.shape[1])
    valid = (slot_pos[None, None, :] <=
             positions[:, :, None])[:, None, :, :]     # (S,1,k,T) causal
    scores = jnp.where(valid, scores, attn.NEG_INF)
    w = attn._softmax(scores).astype(x.dtype)
    out = attn._out_proj(p, attn._gqa_combine(w, v))
    return out, kv_out


def _attn_verify_sublayer(p: Dict, x, kv, table, lengths, write_slots,
                          write_offs, cfg, ffn_kind: str, fused: bool):
    h = apply_norm(p["norm1"], x, cfg.norm_eps)
    h, kv = _paged_attention_verify(p["mix"], h, kv, table, lengths,
                                    write_slots, write_offs, cfg, fused)
    x = x + h
    h2 = apply_norm(p["norm2"], x, cfg.norm_eps)
    if ffn_kind == "moe":
        h2, _ = moe_forward(p["ffn"], h2, cfg, capacity_factor=0.0)
    else:
        h2 = ffn_forward(p["ffn"], h2, cfg)
    return x + h2, kv


def build_verify_step(model, k: int, fused_attention: Optional[bool] = None):
    """Compile-once k-token speculative verify: (params, kv, states, table,
    lengths, write_slots (k,NB), write_offs (k,NB), tokens (S,k)) ->
    (logits (S,k,V), kv, states).

    ``logits[s, j]`` is the target's distribution for position
    ``lengths[s]+j+1`` given the prompt plus draft tokens ``<= j`` — the
    greedy argmax over it is exactly the token plain decode would emit
    there, so the caller can accept the matching draft prefix and resample
    the first divergence bit-identically. Attention-only models only:
    recurrent sublayer state (mamba/rwkv) cannot be rolled back when a
    draft is rejected, so those architectures raise here.
    """
    cfg = model.cfg
    kinds = _sub_kinds(cfg)
    if any(m != "attn" for m, _ in kinds):
        raise ValueError(
            "speculative verify requires attention-only models (recurrent "
            f"sublayer state has no rollback); got kinds={[m for m, _ in kinds]}")
    fused = True if fused_attention is None else bool(fused_attention)

    def step(params, kv, states, table, lengths, write_slots, write_offs,
             tokens):
        dtype = cfg.activation_dtype
        x = embed_tokens(params["embed"], tokens, dtype)   # (S,k,d)
        if "embed_norm" in params:
            x = apply_norm(params["embed_norm"], x, cfg.norm_eps)

        def body(carry, xs):
            h = carry
            lp, kv_l, st_l = xs
            kv_out = {}
            for i, (m, f) in enumerate(kinds):
                name = f"sub{i}"
                h, kv_out[name] = _attn_verify_sublayer(
                    lp[name], h, kv_l[name], table, lengths,
                    write_slots, write_offs, cfg, f, fused)
            return h, (kv_out, st_l)

        x, (kv, states) = jax.lax.scan(body, x, (params["layers"], kv,
                                                 states))
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = lm_head(params["embed"], x)               # (S,k,V)
        return logits, kv, states

    _n_scan(cfg)           # called for effect: validates the scan layout early
    return jax.jit(step)
