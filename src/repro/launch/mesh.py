"""Production meshes.

Functions (not module-level constants) so importing never touches jax device
state. The dry-run sets XLA_FLAGS --xla_force_host_platform_device_count=512
BEFORE any jax import (see dryrun.py); smoke tests and benches see 1 device.

Axes:
  single-pod:  (16, 16)      ("data", "model")      — 256 chips (one v5e pod)
  multi-pod:   (2, 16, 16)   ("pod", "data", "model") — 512 chips

The ``"pod"`` axis doubles as the CODISTILLATION axis: n=2 codistilling
models, one per pod, so the only traffic crossing the (slow) pod-to-pod links
is the prediction exchange — the paper's setup mapped onto TPU topology.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` where available (jax >= 0.6); on older jax a ``Mesh`` is
    itself a context manager with the same effect for pjit/shard_map.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def abstract_mesh(axis_sizes, axis_names):
    """Device-free mesh for sharding-rule unit tests, across jax versions.

    Newer jax: ``AbstractMesh(axis_sizes, axis_names)``; jax <= 0.4 takes a
    single ``((name, size), ...)`` shape tuple.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_codist_mesh(n_models: int = 2, data: int = 8, model: int = 16):
    """Single-pod codistillation mesh: the pod's chips are partitioned into
    n_models groups (the paper's '8 GPUs per model on one server' analogue)."""
    return jax.make_mesh((n_models, data, model), ("pod", "data", "model"))


def make_host_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Tiny mesh for CI-scale distributed tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes)


def mesh_chips(mesh) -> int:
    return mesh.devices.size


def pod_index_of_device(mesh, device_id: int) -> int:
    """Which pod a flat device id belongs to (0 if no pod axis)."""
    if "pod" not in mesh.axis_names:
        return 0
    import numpy as np
    idx = np.argwhere(np.vectorize(lambda d: d.id)(mesh.devices) == device_id)
    return int(idx[0][mesh.axis_names.index("pod")]) if idx.size else 0
