"""Host training loop: metric logging, plan-driven variant dispatch, comm
event/byte accounting, eval, and the Fig.-7 parameter-distance probe.

The loop is strategy-agnostic: ``strategy.plan(k)`` picks the compiled
variant and decides when an exchange happens; the strategy's
``host_exchange`` performs any host-side communication (the checkpoint-mode
stale refresh); ``strategy.comm_bytes`` prices each exchange event for the
Section-3 accounting. No mechanism-specific branching lives here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CodistConfig, TrainConfig
from repro.core.codistillation import param_distance_from
from repro.train.engine import (ExchangeStrategy, AllReduce, build_train_step,
                                resolve_strategy)

PyTree = Any

# History JSONL schema: bump when the on-disk record shape changes in a way
# old readers would misparse. v1 = a header line {"schema_version": 1}
# followed by one record per line (files written before the header existed
# load as legacy v1 — their record shape is identical).
HISTORY_SCHEMA_VERSION = 1


@dataclass
class History:
    records: List[Dict[str, float]] = field(default_factory=list)

    def log(self, step: int, metrics: Dict[str, Any], **extra):
        rec = {"step": step}
        for k, v in metrics.items():
            try:
                arr = jnp.asarray(v)
                if arr.ndim == 0:
                    rec[k] = float(arr)
                else:
                    for i, x in enumerate(arr.reshape(-1)):
                        rec[f"{k}_{i}"] = float(x)
            except Exception:
                pass
        rec.update(extra)
        self.records.append(rec)

    def last(self, key: str) -> float:
        for rec in reversed(self.records):
            if key in rec:
                return rec[key]
        raise KeyError(key)

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self.records if key in r]

    def save(self, path: str) -> None:
        """Persist as JSONL: a ``{"schema_version": N}`` header line, then
        one record per line — async runs and benchmarks stream trajectories
        to disk instead of keeping them in memory."""
        import json
        import os
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(json.dumps({"schema_version": HISTORY_SCHEMA_VERSION})
                    + "\n")
            for rec in self.records:
                f.write(json.dumps(rec) + "\n")

    @classmethod
    def load(cls, path: str) -> "History":
        import json
        with open(path) as f:
            rows = [json.loads(line) for line in f if line.strip()]
        if rows and "schema_version" in rows[0] and "step" not in rows[0]:
            version = rows[0]["schema_version"]
            if version != HISTORY_SCHEMA_VERSION:
                raise ValueError(
                    f"{path}: History schema_version {version} is not "
                    f"supported by this reader (expects "
                    f"{HISTORY_SCHEMA_VERSION}). Re-generate the JSONL with "
                    "this version of the repo, or load it with the matching "
                    "older version.")
            rows = rows[1:]
        # headerless files predate the schema header: legacy v1, same shape
        return cls(rows)


def train(model, tc: TrainConfig, batches: Callable[[int], Dict],
          strategy: ExchangeStrategy, codist: Optional[CodistConfig] = None,
          eval_batches: Optional[Callable[[int], Dict]] = None,
          eval_every: int = 0, log_every: int = 10,
          state=None, trainable: Optional[PyTree] = None,
          track_param_distance: bool = False,
          tracer=None, metrics=None, watch=None) -> tuple:
    """Generic strategy-driven loop. ``batches(step)`` returns the batch for
    that step (stacked with a leading n axis for codist strategies — it owns
    coordinated vs. independent sampling).

    ``tracer``/``metrics`` are optional ``repro.obs`` hooks on the step
    clock (one step renders as 1 ms): per-step spans with exchange markers
    and comm-byte counters. ``watch`` is an optional Watchtower on the same
    step clock, evaluated at each log point against the live
    ``train/task_loss`` gauge. ``None`` leaves the loop untouched."""
    from repro.optim import make_optimizer
    opt_init, _ = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                 b1=tc.adam_b1, b2=tc.adam_b2,
                                 dtype=tc.opt_dtype)
    example = batches(0)
    if state is None:
        state = strategy.init_state(model, tc, jax.random.key(tc.seed),
                                    opt_init, example)
    else:
        state = strategy.ensure_state(state, model, tc, example)
    bundle = build_train_step(model, tc, codist, strategy, trainable)
    eval_fn = jax.jit(bundle.eval_fn)
    params0 = (jax.tree.map(jnp.array, state.params)
               if track_param_distance else None)
    bytes_per_event = strategy.comm_bytes(model, state, example, tc.microbatch)
    hist = History()
    comm_events = 0
    mreg = metrics                   # the obs registry; the loop's local
    del metrics                      # ``metrics`` name is the step's dict
    if tracer is not None:
        tracer.name_process(0, "train")
        tracer.name_thread(0, 0, strategy.__class__.__name__)
    for k in range(tc.total_steps):
        batch = example if k == 0 else batches(k)
        state, metrics, plan = bundle.apply(state, batch, k)
        if plan.exchange:
            comm_events += 1
        if tracer is not None:
            tracer.complete("step", k, k + 1, cat="train",
                            args={"step": k, "exchange": bool(plan.exchange)})
            if plan.exchange:
                tracer.instant("exchange", k, cat="train")
        if k % log_every == 0 or k == tc.total_steps - 1:
            extra = {"comm_events": comm_events,
                     "comm_bytes": comm_events * bytes_per_event}
            if track_param_distance:
                extra["param_distance"] = float(
                    param_distance_from(state.params, params0))
            if eval_every and eval_batches is not None and (
                    k % eval_every == 0 or k == tc.total_steps - 1):
                metrics = {**metrics, **eval_fn(state.params, eval_batches(k))}
            hist.log(k, metrics, **extra)
            if tracer is not None:
                tracer.counter("comm", k, {"events": comm_events,
                                           "bytes": extra["comm_bytes"]})
            if mreg is not None:
                # live loss stream for alert rules: scalar runs log
                # "task_loss", codist runs log one "task_loss_<i>" per
                # peer — average the peers into one gauge
                rec = hist.records[-1]
                losses = [v for name, v in sorted(rec.items())
                          if name == "task_loss"
                          or name.startswith("task_loss_")]
                if losses:
                    mreg.gauge("train/task_loss").set(
                        sum(losses) / len(losses))
            if watch is not None:
                watch.evaluate(k)
    if mreg is not None:
        mreg.counter("train/comm_events").inc(comm_events)
        mreg.counter("train/comm_bytes").inc(comm_events * bytes_per_event)
        mreg.gauge("train/steps").set(tc.total_steps)
        try:
            mreg.gauge("train/final_task_loss").set(hist.last("task_loss"))
        except KeyError:
            pass
    return state, hist


def train_allreduce(model, tc: TrainConfig, batches: Iterator[Dict],
                    eval_batches: Optional[Callable[[int], Dict]] = None,
                    eval_every: int = 0, log_every: int = 10,
                    state=None, trainable: Optional[PyTree] = None,
                    track_param_distance: bool = False,
                    tracer=None, metrics=None, watch=None) -> tuple:
    it = iter(batches)
    return train(model, tc, lambda k: next(it), AllReduce(),
                 eval_batches=eval_batches, eval_every=eval_every,
                 log_every=log_every, state=state, trainable=trainable,
                 track_param_distance=track_param_distance,
                 tracer=tracer, metrics=metrics, watch=watch)


def train_codist(model, codist: CodistConfig, tc: TrainConfig,
                 batches: Callable[[int], Dict],
                 eval_batches: Optional[Callable[[int], Dict]] = None,
                 eval_every: int = 0, log_every: int = 10,
                 state=None, trainable: Optional[PyTree] = None,
                 track_param_distance: bool = False,
                 strategy: Optional[ExchangeStrategy] = None,
                 tracer=None, metrics=None, watch=None) -> tuple:
    """Codistillation loop; the mechanism comes from ``strategy`` (explicit
    instance, e.g. ``ShardMapCompressed``) or ``resolve_strategy(codist)``."""
    strategy = strategy if strategy is not None else resolve_strategy(codist)
    return train(model, tc, batches, strategy, codist=codist,
                 eval_batches=eval_batches, eval_every=eval_every,
                 log_every=log_every, state=state, trainable=trainable,
                 track_param_distance=track_param_distance,
                 tracer=tracer, metrics=metrics, watch=watch)


def stack_batches(batch_list: List[Dict]) -> Dict:
    """[batch_i] -> stacked dict with leading n axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)
