"""Decoder-only LM covering the dense / moe / hybrid / ssm / vlm families.

Layers are *scanned*: per-layer params are stacked along a leading axis and the
forward runs ``jax.lax.scan`` over them, keeping HLO size O(1) in depth (a
95-layer model lowers as fast as a 2-layer one — essential for the 512-device
dry-runs). Hybrid (Jamba) models scan over *blocks* of ``attn_layer_period``
sub-layers so the scanned pytree stays homogeneous.

API (pure functions bundled by ``LM``):
    init(key) -> params
    forward(params, batch, remat=False) -> (logits, aux)        # train
    prefill(params, batch) -> (logits, cache)                   # emit cache
    init_cache(batch, cap, dtype) -> cache
    decode(params, cache, tokens, pos) -> (logits, cache)       # one token
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import rwkv as rk
from repro.models.common import (KeyGen, apply_norm, embed_tokens,
                                 init_embedding, init_rms_norm, lm_head)
from repro.models.ffn import ffn_forward, init_ffn
from repro.models.moe import init_moe, moe_forward

PyTree = Any


# ----------------------------------------------------------------------------
# sub-layer templates
# ----------------------------------------------------------------------------

def _sub_kinds(cfg: ModelConfig) -> list[Tuple[str, str]]:
    """(mixer, ffn) kind per scanned sub-layer within one scan step."""
    if cfg.family == "ssm":
        return [("rwkv", "rwkv")]
    period = cfg.attn_layer_period or 1
    kinds = []
    for i in range(period):
        mixer = cfg.layer_kind(i)
        ffn = "moe" if cfg.is_moe_layer(i) else "dense"
        kinds.append((mixer, ffn))
    return kinds


def _n_scan(cfg: ModelConfig) -> int:
    period = len(_sub_kinds(cfg))
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return cfg.num_layers // period


def _init_sublayer(key: jax.Array, cfg: ModelConfig, mixer: str,
                   ffn: str, dtype) -> Dict:
    kg = KeyGen(key)
    d = cfg.d_model
    p: Dict = {"norm1": init_rms_norm(d, dtype)}
    if mixer == "attn":
        p["mix"] = attn.init_attention(kg(), cfg, dtype)
    elif mixer == "ssm":
        p["mix"] = mb.init_mamba(kg(), cfg, dtype)
    elif mixer == "rwkv":
        p["mix"] = rk.init_time_mix(kg(), cfg, dtype)
    if ffn == "rwkv":
        p["norm2"] = init_rms_norm(d, dtype)
        p["ffn"] = rk.init_channel_mix(kg(), cfg, dtype)
    elif ffn == "moe":
        p["norm2"] = init_rms_norm(d, dtype)
        p["ffn"] = init_moe(kg(), cfg, dtype)
    else:
        p["norm2"] = init_rms_norm(d, dtype)
        p["ffn"] = init_ffn(kg(), cfg, dtype=dtype)
    return p


def _init_scan_step(key: jax.Array, cfg: ModelConfig, dtype) -> Dict:
    kinds = _sub_kinds(cfg)
    kg = KeyGen(key)
    return {f"sub{i}": _init_sublayer(kg(), cfg, m, f, dtype)
            for i, (m, f) in enumerate(kinds)}


# ----------------------------------------------------------------------------
# forward bodies (one scan step = one block of sub-layers)
# ----------------------------------------------------------------------------

def _sublayer_fwd(p: Dict, x: jax.Array, cfg: ModelConfig, mixer: str,
                  ffn: str, positions: Optional[jax.Array]):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        h, _ = attn.attention_forward(p["mix"], h, cfg, positions)
    elif mixer == "ssm":
        h = mb.mamba_forward(p["mix"], h, cfg)
    else:  # rwkv time mix
        h, _ = rk.time_mix_forward(p["mix"], h, cfg)
    x = x + h
    h = apply_norm(p["norm2"], x, cfg.norm_eps)
    if ffn == "moe":
        h, aux = moe_forward(p["ffn"], h, cfg)
    elif ffn == "rwkv":
        h, _ = rk.channel_mix_forward(p["ffn"], h, cfg)
    else:
        h = ffn_forward(p["ffn"], h, cfg)
    return x + h, aux


def _scan_forward(layers: PyTree, x: jax.Array, cfg: ModelConfig,
                  positions: Optional[jax.Array], remat: bool):
    kinds = _sub_kinds(cfg)

    from repro.models.sharding_hints import hint

    def body(carry, lp):
        h = carry
        aux = jnp.zeros((), jnp.float32)
        for i, (m, f) in enumerate(kinds):
            h, a = _sublayer_fwd(lp[f"sub{i}"], h, cfg, m, f, positions)
            aux = aux + a
        return hint(h, "btd"), aux

    from repro.models.runtime_flags import scan_unroll
    if remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, layers, unroll=scan_unroll())
    return x, jnp.sum(auxs)


# ----------------------------------------------------------------------------
# caches (decode state) per sub-layer kind
# ----------------------------------------------------------------------------

def _init_sub_cache(cfg: ModelConfig, mixer: str, batch: int, cap: int, dtype):
    if mixer == "attn":
        return attn.init_kv_cache(cfg, batch, cap, dtype)
    if mixer == "ssm":
        return mb.init_mamba_state(cfg, batch, dtype)
    # rwkv: wkv state + token-shift carries for both mixes
    h, hd, _ = rk._dims(cfg)
    d = cfg.d_model
    return {
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, 1, d), dtype),
        "shift_cm": jnp.zeros((batch, 1, d), dtype),
    }


def _sublayer_prefill(p: Dict, x: jax.Array, cfg: ModelConfig, mixer: str,
                      ffn: str, positions, cap: int, dtype):
    """Forward + emit decode cache for this sub-layer."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        h, kv = attn.attention_forward(p["mix"], h, cfg, positions,
                                       return_cache=True)
        cache = attn.prefill_into_cache(
            attn.init_kv_cache(cfg, x.shape[0], cap, dtype),
            {"k": kv["k"].astype(dtype), "v": kv["v"].astype(dtype)}, cfg)
    elif mixer == "ssm":
        h, cache = mb.mamba_prefill(p["mix"], h, cfg)
    else:
        h, (shift_tm, s_fin) = rk.time_mix_forward(p["mix"], h, cfg)
        cache = {"s": s_fin, "shift_tm": shift_tm.astype(dtype)}
    x = x + h
    h2 = apply_norm(p["norm2"], x, cfg.norm_eps)
    if ffn == "moe":
        h2, aux = moe_forward(p["ffn"], h2, cfg, capacity_factor=0.0)
    elif ffn == "rwkv":
        h2, shift_cm = rk.channel_mix_forward(p["ffn"], h2, cfg)
        cache["shift_cm"] = shift_cm.astype(dtype)
    else:
        h2 = ffn_forward(p["ffn"], h2, cfg)
    return x + h2, cache, aux


def _sublayer_decode(p: Dict, x: jax.Array, cache, cfg: ModelConfig,
                     mixer: str, ffn: str, pos):
    h = apply_norm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        h, cache = attn.attention_decode(p["mix"], h, cache, pos, cfg)
    elif mixer == "ssm":
        h, cache = mb.mamba_decode(p["mix"], h, cache, cfg)
    else:
        h, (shift_tm, s_fin) = rk.time_mix_forward(
            p["mix"], h, cfg, shift_prev=cache["shift_tm"].astype(h.dtype),
            s0=cache["s"])
        cache = dict(cache, s=s_fin, shift_tm=shift_tm.astype(cache["shift_tm"].dtype))
    x = x + h
    h2 = apply_norm(p["norm2"], x, cfg.norm_eps)
    if ffn == "moe":
        h2, _ = moe_forward(p["ffn"], h2, cfg, capacity_factor=0.0)
    elif ffn == "rwkv":
        h2, shift_cm = rk.channel_mix_forward(
            p["ffn"], h2, cfg, shift_prev=cache["shift_cm"].astype(h2.dtype))
        cache = dict(cache, shift_cm=shift_cm.astype(cache["shift_cm"].dtype))
    else:
        h2 = ffn_forward(p["ffn"], h2, cfg)
    return x + h2, cache


# ----------------------------------------------------------------------------
# the model
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # -- init ---------------------------------------------------------------
    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        kg = KeyGen(key)
        n_scan = _n_scan(cfg)
        layer_keys = jax.random.split(kg(), n_scan)
        layers = jax.vmap(lambda k: _init_scan_step(k, cfg, dtype))(layer_keys)
        params: Dict = {
            "embed": init_embedding(kg(), cfg, dtype),
            "final_norm": init_rms_norm(cfg.d_model, dtype),
            "layers": layers,
        }
        if cfg.family == "ssm":
            params["embed_norm"] = init_rms_norm(cfg.d_model, dtype)
        return params

    # -- shared embedding path ----------------------------------------------
    def _embed(self, params: PyTree, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        x = embed_tokens(params["embed"], batch["tokens"], dtype)
        if cfg.num_patches and "patches" in batch:
            # VLM: precomputed patch embeddings prefix (stub frontend)
            x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
        if "embed_norm" in params:
            x = apply_norm(params["embed_norm"], x, cfg.norm_eps)
        from repro.models.sharding_hints import hint
        x = hint(x, "btd")
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        return x, positions

    # -- train forward --------------------------------------------------------
    def forward(self, params: PyTree, batch: Dict,
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        x, aux = _scan_forward(params["layers"], x, cfg, positions, remat)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        if cfg.num_patches and "patches" in batch:
            x = x[:, batch["patches"].shape[1]:]  # logits only over text tokens
        return lm_head(params["embed"], x), aux

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, cap: int, dtype=jnp.bfloat16) -> PyTree:
        cfg = self.cfg
        kinds = _sub_kinds(cfg)
        n_scan = _n_scan(cfg)

        def one(_):
            return {f"sub{i}": _init_sub_cache(cfg, m, batch, cap, dtype)
                    for i, (m, _f) in enumerate(kinds)}

        return jax.vmap(one)(jnp.arange(n_scan))

    def prefill(self, params: PyTree, batch: Dict, cap: int,
                cache_dtype=jnp.bfloat16) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        kinds = _sub_kinds(cfg)
        x, positions = self._embed(params, batch)
        # cap must cover the full prefix (VLM patches included) unless the
        # model uses windowed attention — otherwise the ring-buffer path
        # would silently evict live context.
        assert cfg.sliding_window > 0 or cap >= x.shape[1], \
            (cap, x.shape[1], "cache capacity smaller than prefill length")

        def body(carry, lp):
            h = carry
            caches = {}
            for i, (m, f) in enumerate(kinds):
                h, c, _ = _sublayer_prefill(lp[f"sub{i}"], h, cfg, m, f,
                                            positions, cap, cache_dtype)
                caches[f"sub{i}"] = c
            return h, caches

        from repro.models.runtime_flags import scan_unroll
        x, cache = jax.lax.scan(body, x, params["layers"],
                                unroll=scan_unroll())
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        if cfg.num_patches and "patches" in batch:
            x = x[:, batch["patches"].shape[1]:]
        # only the last position's logits are needed to continue decoding
        return lm_head(params["embed"], x[:, -1:]), cache

    def decode(self, params: PyTree, cache: PyTree, tokens: jax.Array,
               pos: jax.Array) -> Tuple[jax.Array, PyTree]:
        """tokens: (B,1) int32; pos: () int32 absolute position, or (B,)
        int32 per-row positions (ragged decode — state-based mixers ignore
        it, attention scatters per row; see ``attention_decode``)."""
        cfg = self.cfg
        kinds = _sub_kinds(cfg)
        dtype = cfg.activation_dtype
        x = embed_tokens(params["embed"], tokens, dtype)
        if "embed_norm" in params:
            x = apply_norm(params["embed_norm"], x, cfg.norm_eps)

        def body(carry, xs):
            h = carry
            lp, c_in = xs
            c_out = {}
            for i, (m, f) in enumerate(kinds):
                h, c = _sublayer_decode(lp[f"sub{i}"], h, c_in[f"sub{i}"],
                                        cfg, m, f, pos)
                c_out[f"sub{i}"] = c
            return h, c_out

        from repro.models.runtime_flags import scan_unroll
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                    unroll=scan_unroll())
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        return lm_head(params["embed"], x), new_cache
