"""resnet50 [paper's own vision workload] — He et al. [arXiv:1512.03385], trained on
ImageNet-1k per Goyal et al. [arXiv:1706.02677] (the paper's Section 4.1 baseline).

Used for the paper-faithful communication model numbers (b_model = 8e8 bits,
b_pred = 3.2e4 bits at 1000 classes) and reduced-scale codistillation runs.
Conv configs use a separate dataclass (see repro/models/conv.py).
"""
from repro.models.conv import ConvConfig

CONFIG = ConvConfig(
    name="resnet50",
    kind="resnet",
    depths=(3, 4, 6, 3),
    widths=(256, 512, 1024, 2048),
    bottleneck=True,
    num_classes=1000,
    image_size=224,
    source="ResNet-50 [arXiv:1512.03385] / Goyal et al. [arXiv:1706.02677]",
)


def reduced():
    return ConvConfig(
        name="resnet50-reduced",
        kind="resnet",
        depths=(1, 1),
        widths=(32, 64),
        bottleneck=True,
        num_classes=10,
        image_size=32,
        source=CONFIG.source,
    )
