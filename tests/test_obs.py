"""Observability-layer tests: tracer invariants (span nesting, monotonic
clocks, Perfetto-event validity), exact-quantile histograms vs numpy, trace
bit-determinism under a fixed seed (train + fleet + chaos), the overhead-off
guarantee (instrumentation disabled leaves behavior byte-identical), the
migrated-request span-tree acceptance chain, History schema versioning, and
the shared ``to_dict`` serialization path.

Hypothesis-driven property tests live in ``tests/test_obs_property.py``
(they skip where the optional dev dependency isn't installed); everything
here runs unconditionally.
"""
import json
import os
import subprocess
import sys
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.obs import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                       MetricsRegistry, TraceError, Tracer, for_sim_ms,
                       for_steps)
from repro.runtime import FaultConfig
from repro.serve.fleet import (ChaosConfig, FleetConfig, FleetDefense,
                               FleetRouter, Request)
from repro.train.loop import HISTORY_SCHEMA_VERSION, History

TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
sys.path.insert(0, TOOLS)
import trace_check  # noqa: E402


# ----------------------------------------------------------------------------
# tracer unit invariants
# ----------------------------------------------------------------------------

class TestTracer:
    def test_sync_spans_nest_and_export(self):
        tr = Tracer(unit_us=1000.0)
        tr.begin("outer", 1.0, pid=0, tid=0)
        tr.begin("inner", 2.0, pid=0, tid=0)
        tr.end("inner", 3.0, pid=0, tid=0)
        tr.end("outer", 4.0, pid=0, tid=0)
        doc = tr.to_dict()
        phs = [e["ph"] for e in doc["traceEvents"]]
        assert phs == ["B", "B", "E", "E"]
        assert doc["traceEvents"][0]["ts"] == 1000

    def test_lifo_name_mismatch_raises(self):
        tr = Tracer()
        tr.begin("a", 0.0, pid=0, tid=0)
        with pytest.raises(TraceError, match="does not match"):
            tr.end("b", 1.0, pid=0, tid=0)

    def test_clock_must_be_monotonic_per_track(self):
        tr = Tracer()
        tr.begin("a", 5.0, pid=0, tid=0)
        with pytest.raises(TraceError, match="precedes"):
            tr.end("a", 4.0, pid=0, tid=0)

    def test_negative_time_rejected(self):
        with pytest.raises(TraceError, match="negative"):
            Tracer().instant("x", -1.0, pid=0, tid=0)

    def test_dangling_span_fails_export(self):
        tr = Tracer()
        tr.begin("leak", 0.0, pid=0, tid=0)
        assert tr.open_spans()
        with pytest.raises(TraceError, match="still open"):
            tr.to_dict()

    def test_complete_and_counter_shapes(self):
        tr = Tracer(unit_us=1.0)
        tr.complete("x", 10.0, 14.0, pid=1, tid=2, cat="c", args={"k": 1})
        tr.counter("pool", 12.0, {"util": 0.5}, pid=1)
        evs = tr.to_dict()["traceEvents"]
        x = next(e for e in evs if e["ph"] == "X")
        assert (x["ts"], x["dur"], x["pid"], x["tid"]) == (10, 4, 1, 2)
        c = next(e for e in evs if e["ph"] == "C")
        assert c["args"] == {"util": 0.5}

    def test_async_span_balanced_per_id(self):
        tr = Tracer()
        tr.async_begin("request", 7, "req", 0.0, pid=0, tid=7)
        tr.async_instant("migrate", 7, "req", 1.0, pid=0, tid=7)
        tr.async_end("request", 7, "req", 2.0, pid=0, tid=7)
        phs = [e["ph"] for e in tr.to_dict()["traceEvents"]]
        assert phs == ["b", "n", "e"]

    def test_export_sorted_and_canonical(self):
        tr = for_steps()
        tr.complete("late", 5, 6, pid=0, tid=0)
        tr.complete("early", 1, 2, pid=0, tid=0)
        evs = tr.to_dict()["traceEvents"]
        assert [e["name"] for e in evs] == ["early", "late"]
        # canonical JSON: key-sorted, no whitespace
        assert "\n" not in tr.to_json() and '", "' not in tr.to_json()

    def test_validator_rejects_corruption(self, tmp_path):
        tr = for_steps()
        tr.complete("ok", 0, 1, pid=0, tid=0)
        doc = json.loads(tr.to_json())
        doc["traceEvents"][0]["dur"] = -5
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps(doc))
        good = tmp_path / "good.json"
        tr.save(str(good))
        assert trace_check.main([str(good)]) == 0
        assert trace_check.main([str(bad)]) == 1


# ----------------------------------------------------------------------------
# metrics registry (exact quantiles; hypothesis properties live in
# tests/test_obs_property.py so this module runs without the optional dep)
# ----------------------------------------------------------------------------

class TestMetrics:
    def test_percentile_matches_numpy_exactly(self):
        vals = [3.0, 1.5, 9.0, 2.2, 7.7, 0.4]
        h = Histogram()
        for v in vals:
            h.observe(v)
        for q in (0, 12.5, 50, 90, 99, 100):
            assert h.percentile(q) == float(np.percentile(np.asarray(vals),
                                                          q))
        assert h.quantile(0.9) == float(np.quantile(
            np.asarray(vals, np.float64), 0.9))

    def test_empty_histogram_quantile_raises_with_metric_name(self):
        # silent 0.0 on an empty histogram masked missing-instrumentation
        # bugs; the error must name the metric so the call site is findable
        with pytest.raises(ValueError, match="fleet/ttft_ms"):
            Histogram(name="fleet/ttft_ms").percentile(99)
        with pytest.raises(ValueError, match="histogram"):
            Histogram().quantile(0.9)
        # export still serializes an empty histogram (0.0 placeholders)
        assert Histogram(name="x").to_dict()["p50"] == 0.0

    def test_gauge_windowed_min_max(self):
        g = Gauge()
        assert (g.window_min(), g.window_max()) == (0.0, 0.0)
        for v in (3.0, 1.0, 4.0, 1.5):
            g.set(v)
        assert g.window(2) == [4.0, 1.5]
        assert g.window_min() == 1.0 and g.window_max() == 4.0
        assert g.window_min(2) == 1.5 and g.window_max(3) == 4.0
        with pytest.raises(ValueError, match="window"):
            g.window(0)

    def test_registry_get_or_create_and_export(self):
        m = MetricsRegistry()
        m.counter("a").inc(3)
        assert m.counter("a").value == 3
        m.gauge("g").set(1.5)
        m.histogram("h", buckets=(1, 10)).observe(4)
        d = m.to_dict()
        assert d["schema_version"] == 1
        assert d["counters"]["a"] == 3
        assert d["gauges"]["g"] == 1.5
        assert d["histograms"]["h"]["count"] == 1

    def test_counter_rejects_negative(self):
        with pytest.raises(ValueError):
            Counter().inc(-1)

    def test_default_buckets_sorted(self):
        assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)
        assert Gauge().value == 0.0


# ----------------------------------------------------------------------------
# end-to-end: fleet tracing (determinism, overhead-off, span-tree chain)
# ----------------------------------------------------------------------------

def _tiny_cfg():
    return replace(get_reduced("qwen1.5-0.5b"), num_layers=2, d_model=64,
                   d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=2,
                   head_dim=32)


def _requests(cfg, lens, max_new=5, gap_ms=4.0, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, i * gap_ms,
                    tuple(int(x) for x in rng.integers(0, cfg.padded_vocab,
                                                       size=l)), max_new)
            for i, l in enumerate(lens)]


class _ListWorkload:
    def __init__(self, requests, scenario="custom", seed=0):
        self.requests = requests
        self.scenario = scenario
        self.seed = seed


def _fleet_fc():
    return FleetConfig(max_slots=2, block_size=4, num_blocks=32,
                       max_blocks_per_slot=8, max_queue=32)


@pytest.fixture(scope="module")
def fleet_setup():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    wl = _ListWorkload(_requests(cfg, [5, 9, 12, 7] * 4))
    return model, params, wl


_PREEMPT = ((1, 6, 150.0),)


def test_tracing_does_not_perturb_the_fleet(fleet_setup):
    """Overhead-off guarantee, exercised from the other side: running WITH
    the tracer + metrics produces a byte-identical FleetReport to the
    uninstrumented run (the PR-7 behavior)."""
    model, params, wl = fleet_setup
    plain = FleetRouter(model, [params, params], config=_fleet_fc()).run(wl)
    mreg = MetricsRegistry()
    traced = FleetRouter(model, [params, params], config=_fleet_fc(),
                         tracer=for_sim_ms(), metrics=mreg).run(wl)
    assert plain.to_json() == traced.to_json()
    # the registry mirrors the report it didn't perturb
    assert mreg.to_dict()["gauges"]["report/completed"] == traced.completed


def test_chaos_trace_bit_identical_and_valid(fleet_setup, tmp_path):
    """Two seeded runs of the preemption chaos scenario produce
    byte-identical Perfetto JSON that the validator accepts."""
    model, params, wl = fleet_setup
    chaos = ChaosConfig(FaultConfig(n_peers=2, seed=0,
                                    preemptions=_PREEMPT))
    docs = []
    for _ in range(2):
        tr = for_sim_ms()
        FleetRouter(model, [params, params], config=_fleet_fc(),
                    chaos=chaos, defense=FleetDefense(), tracer=tr).run(wl)
        docs.append(tr.to_json())
    assert docs[0] == docs[1]
    path = tmp_path / "chaos.trace.json"
    path.write_text(docs[0] + "\n")
    assert trace_check.main([str(path)]) == 0


def test_migrated_request_span_tree(fleet_setup):
    """The acceptance chain: a migrated request's span tree carries
    admit -> queue -> prefill -> decode -> migrate -> re-prefill -> emit
    on the simulated-ms timeline."""
    model, params, wl = fleet_setup
    chaos = ChaosConfig(FaultConfig(n_peers=2, seed=0,
                                    preemptions=_PREEMPT))
    tr = for_sim_ms()
    rep = FleetRouter(model, [params, params], config=_fleet_fc(),
                      chaos=chaos, defense=FleetDefense(), tracer=tr).run(wl)
    assert rep.migrations >= 1
    names = {}
    for e in tr.to_dict()["traceEvents"]:
        if e.get("cat") == "request":
            names.setdefault(e["tid"], []).append(e["name"])
    migrated = [tid for tid, ns in names.items() if "migrate" in ns]
    assert migrated, "no migrate annotation in any request tree"
    chain = names[migrated[0]]
    for stage in ("request", "queue", "admit", "prefill", "decode",
                  "migrate", "re-prefill", "emit"):
        assert stage in chain, f"missing {stage} in {chain}"
    # engine rows exist too (tick spans + kv_pool counters)
    cats = {e.get("cat") for e in tr.to_dict()["traceEvents"]}
    assert "engine" in cats and "chaos" in cats


def test_train_trace_bit_identical(tmp_path):
    """Sync-train tracing on the step clock is bit-deterministic."""
    from repro.configs import CodistConfig, TrainConfig
    from repro.data import MarkovLM, make_lm_batch
    from repro.train import stack_batches, train_codist
    cfg = _tiny_cfg()
    model = build_model(cfg)
    task = MarkovLM(vocab=64, seed=0)

    def one_run():
        tc = TrainConfig(lr=1e-3, total_steps=4, warmup_steps=1, seed=0)
        codist = CodistConfig(n_models=2)
        tr = for_steps()
        mreg = MetricsRegistry()

        def batches(step):
            return stack_batches([make_lm_batch(task, 2, 8, step, None,
                                                seed=0) for _ in range(2)])
        train_codist(model, codist, tc, batches, log_every=1,
                     tracer=tr, metrics=mreg)
        return tr.to_json(), mreg.to_json()

    a, b = one_run(), one_run()
    assert a == b
    doc = json.loads(a[0])
    steps = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] == "step"]
    assert len(steps) == 4
    assert json.loads(a[1])["counters"]["train/comm_events"] == 4


# ----------------------------------------------------------------------------
# History schema versioning
# ----------------------------------------------------------------------------

class TestHistorySchema:
    def test_roundtrip_writes_header(self, tmp_path):
        h = History()
        h.log(0, {"loss": 1.0})
        h.log(1, {"loss": 0.5})
        p = tmp_path / "h.jsonl"
        h.save(str(p))
        first = json.loads(p.read_text().splitlines()[0])
        assert first == {"schema_version": HISTORY_SCHEMA_VERSION}
        assert History.load(str(p)).records == h.records

    def test_unknown_version_rejected_actionably(self, tmp_path):
        p = tmp_path / "future.jsonl"
        p.write_text(json.dumps({"schema_version": 99}) + "\n"
                     + json.dumps({"step": 0, "loss": 1.0}) + "\n")
        with pytest.raises(ValueError, match=r"schema_version 99.*Re-gen"):
            History.load(str(p))

    def test_legacy_headerless_still_loads(self, tmp_path):
        p = tmp_path / "legacy.jsonl"
        p.write_text(json.dumps({"step": 0, "loss": 2.0}) + "\n")
        hist = History.load(str(p))
        assert hist.records == [{"step": 0, "loss": 2.0}]


# ----------------------------------------------------------------------------
# shared serialization path
# ----------------------------------------------------------------------------

class TestToDict:
    def test_fleet_report_to_dict_matches_json(self):
        from repro.serve.fleet.router import FleetReport
        rep = FleetReport(
            scenario="custom", router="round_robin", peers=2, seed=0,
            completed=4, rejected=0, p50_ttft_ms=1.0, p99_ttft_ms=2.0,
            p50_e2e_ms=3.0, p99_e2e_ms=4.0, slo_ms=50.0, slo_attainment=1.0,
            sim_tokens_per_s=10.0, generated_tokens=20, kv_bytes_written=64,
            refresh_bytes=0, refreshes=0, refreshes_dropped_stale=0,
            peak_pool_utilization=0.5)
        d = rep.to_dict()
        assert set(d) == set(rep.__dict__)
        assert json.loads(rep.to_json()) == json.loads(
            json.dumps(d, sort_keys=True))

    def test_chaos_stats_to_dict_is_summary(self):
        from repro.serve.fleet.chaos import ChaosStats
        s = ChaosStats()
        s.preemptions = 3
        assert s.to_dict()["preemptions"] == 3
        assert s.summary() == s.to_dict()


# ----------------------------------------------------------------------------
# the CLI validator as CI runs it
# ----------------------------------------------------------------------------

def test_trace_check_cli_subprocess(tmp_path):
    tr = for_sim_ms()
    tr.complete("tick", 0.0, 1.0, pid=1, tid=0, cat="engine")
    p = tmp_path / "t.json"
    tr.save(str(p))
    out = subprocess.run(
        [sys.executable, os.path.join(TOOLS, "trace_check.py"), str(p)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
