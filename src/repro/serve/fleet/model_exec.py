"""Batched decode over the paged KV pool: one jitted step for all slots.

Mirrors ``LM.decode``'s scan-over-layers, but attention sublayers read/write
the shared block pool through the ``repro.kernels.paged_cache`` kernels
instead of a dense per-call cache, and every slot carries its OWN absolute
position (= its current context length) — the ragged substrate continuous
batching needs. Recurrent sublayers (mamba / rwkv) reuse the model's
``_sublayer_decode`` unchanged (their state is position-free).

Numerics are kept identical to the dense engine path: same projections, same
fp32 masked softmax, same cache-dtype handling — masked (dead / padded)
slots contribute exactly 0 after ``exp(NEG - max)`` underflow, so per-slot
logits match single-request ``Engine.generate`` decode and greedy streams
are token-identical (the fleet-vs-engine parity pinned in tests/test_fleet.py).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.paged_cache import paged_gather, paged_scatter
from repro.models import attention as attn
from repro.models.common import apply_norm, embed_tokens, lm_head
from repro.models.ffn import ffn_forward
from repro.models.moe import moe_forward
from repro.models.transformer import _n_scan, _sub_kinds, _sublayer_decode

PyTree = Any


def _paged_attention_decode(p: Dict, x: jax.Array, kv: Dict[str, jax.Array],
                            table: jax.Array, lengths: jax.Array,
                            write_slot: jax.Array, write_off: jax.Array,
                            cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode for every slot against its paged context.

    x (S,1,d); kv {"k","v"}: (NB,BS,KVh,hd) pools for THIS layer; table
    (S,MB); lengths (S,) = each slot's context length == the new token's
    absolute position; write_slot/write_off (NB,) from
    ``PagedCachePool.write_maps`` (inactive slots appear in no map entry,
    so they never touch the pool).
    """
    bs = kv["k"].shape[1]
    positions = lengths[:, None]                       # (S,1) per-slot pos
    q, k_new, v_new = attn._project_qkv(p, x, cfg)
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k_new = attn.apply_rope(k_new, positions, cfg.rope_theta)

    k_pool = paged_scatter(kv["k"], k_new[:, 0], write_slot, write_off)
    v_pool = paged_scatter(kv["v"], v_new[:, 0], write_slot, write_off)
    n_live = (lengths + bs) // bs                      # blocks incl. new token
    k = paged_gather(k_pool, table, n_live)            # (S, MB*BS, KVh, hd)
    v = paged_gather(v_pool, table, n_live)

    scores = attn._gqa_scores(q, k)                    # (S, H, 1, MB*BS)
    slot_pos = jnp.arange(k.shape[1])
    valid = (slot_pos[None, :] <= lengths[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, attn.NEG_INF)
    w = attn._softmax(scores).astype(x.dtype)
    out = attn._out_proj(p, attn._gqa_combine(w, v))
    return out, {"k": k_pool, "v": v_pool}


def _attn_sublayer(p: Dict, x: jax.Array, kv, table, lengths, write_slot,
                   write_off, cfg, ffn_kind: str):
    h = apply_norm(p["norm1"], x, cfg.norm_eps)
    h, kv = _paged_attention_decode(p["mix"], h, kv, table, lengths,
                                    write_slot, write_off, cfg)
    x = x + h
    h2 = apply_norm(p["norm2"], x, cfg.norm_eps)
    if ffn_kind == "moe":
        h2, _ = moe_forward(p["ffn"], h2, cfg, capacity_factor=0.0)
    else:
        h2 = ffn_forward(p["ffn"], h2, cfg)
    return x + h2, kv


def build_decode_step(model):
    """Compile-once batched decode: (params, kv, states, table, lengths,
    write_slot, write_off, tokens) -> (logits (S,V), kv, states).

    All operands have step-invariant shapes, so the returned jit compiles
    exactly once per fleet engine and every scheduler tick reuses it.
    """
    cfg = model.cfg
    kinds = _sub_kinds(cfg)

    def step(params, kv, states, table, lengths, write_slot, write_off,
             tokens):
        dtype = cfg.activation_dtype
        x = embed_tokens(params["embed"], tokens, dtype)   # (S,1,d)
        if "embed_norm" in params:
            x = apply_norm(params["embed_norm"], x, cfg.norm_eps)

        def body(carry, xs):
            h = carry
            lp, kv_l, st_l = xs
            kv_out, st_out = {}, {}
            for i, (m, f) in enumerate(kinds):
                name = f"sub{i}"
                if m == "attn":
                    h, kv_out[name] = _attn_sublayer(
                        lp[name], h, kv_l[name], table, lengths,
                        write_slot, write_off, cfg, f)
                else:
                    h, st_out[name] = _sublayer_decode(
                        lp[name], h, st_l[name], cfg, m, f,
                        jnp.zeros((), jnp.int32))
            return h, (kv_out, st_out)

        x, (kv, states) = jax.lax.scan(body, x, (params["layers"], kv,
                                                 states))
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = lm_head(params["embed"], x)               # (S,1,V)
        return logits[:, -1], kv, states

    n_scan = _n_scan(cfg)  # noqa: F841  (validates the scan layout early)
    return jax.jit(step)
