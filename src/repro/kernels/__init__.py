"""Pallas TPU kernels for the compute hot spots, validated in interpret mode.

  fused_ce        — streaming cross-entropy over vocab tiles, forward +
                    backward (softmax rebuilt from the saved logZ residual)
  distill_loss    — streaming codistillation D(y, y') (mse / kl), forward +
                    backward (five-accumulator KL residuals)
  combined_loss   — COMBINED CE + distill: one read of each logits tile per
                    model, both losses and both gradients
  flash_attention — online-softmax GQA attention (causal / sliding window)
  paged_cache     — serving-fleet paged KV pool gather/scatter (scalar-
                    prefetched block tables; decode reads only live blocks)

Each has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in
``ops.py`` (auto interpret on CPU, Mosaic on TPU). The differentiable
entry points — ``fused_cross_entropy_loss``, ``fused_distill_mean``,
``fused_ce_distill`` — wrap forward+backward in ``jax.custom_vjp`` and are
what ``core.codistillation`` dispatches to under the ``fused_losses`` flag;
gradient parity vs the jnp references is tested in tests/test_kernel_grads.py.
See docs/fused_losses.md for the paper-term-to-kernel mapping.
"""
from repro.kernels.ops import (  # noqa: F401
    attention,
    auto_interpret,
    cross_entropy_tokens,
    distill_loss_tokens,
    fused_ce_distill,
    fused_cross_entropy_loss,
    fused_distill_mean,
    fused_losses_default,
)
from repro.kernels.paged_cache import (  # noqa: F401
    paged_gather,
    paged_gather_ref,
    paged_scatter,
    paged_scatter_ref,
)
