"""Model registry: build a model object (init/forward/prefill/decode) from a config."""
from __future__ import annotations

from typing import Union

from repro.configs.base import ModelConfig
from repro.models.conv import ConvConfig, ConvNet
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM


def build_model(cfg: Union[ModelConfig, ConvConfig]):
    if isinstance(cfg, ConvConfig):
        return ConvNet(cfg)
    if cfg.is_encdec:
        return EncDecLM(cfg)
    return LM(cfg)
