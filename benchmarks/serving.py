"""Serving-fleet benchmark: p50/p99 latency, tokens/sec and SLO attainment
per workload scenario, through the full continuous-batching stack (paged KV
pool, admission control, peer router) — plus decode-kernel rows for the
fused paged-attention path.

One row per (scenario, router) cell on a tiny LM. ``us_per_call`` is WALL
time per generated token (informational on CPU interpret mode — gated only
through the wide ``--min-us`` floor); everything in ``derived`` is computed
on the SIMULATED clock and is bit-deterministic for the committed seed:
``comm_bytes`` (KV-pool bytes written + router weight-refresh bytes — the
serving side's deterministic traffic accounting) is matched EXACTLY by
``tools/bench_compare.py``, so a scheduling / allocation / workload change
that silently alters fleet behavior fails CI the same way a train-side
comm change does.

The ``serving/decode_*`` rows time one batched decode step (wall us, same
caveat) over a fixed ragged slot population and account its per-tick decode
HBM traffic ANALYTICALLY: the fused kernel reads each live KV block exactly
once (plus the per-row fp32 scales when quantized), while the jnp oracle
additionally writes AND re-reads the dense ``(S, MB*BS, KVh, hd)`` gather
temporary. ``comm_bytes`` carries the exact per-tick byte count per
variant, so a change that silently reintroduces the gather temporary (or
alters what the kernel reads) fails the bench gate.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.fleet import FleetConfig, FleetRouter, generate_workload

from benchmarks.common import timed, tiny_lm_cfg

SEED = 17
CELLS = [
    # (scenario, router policy, peers)
    ("steady", "round_robin", 2),
    ("bursty", "least_loaded", 2),
    ("diurnal", "ensemble", 2),
]


def run(quick: bool = False) -> List[Dict]:
    from repro.models import build_model
    cfg = tiny_lm_cfg()
    model = build_model(cfg)
    peer_params = [model.init(jax.random.key(SEED + i)) for i in range(2)]
    n_requests = 12 if quick else 48
    rows: List[Dict] = []
    for scenario, policy, peers in CELLS:
        wl = generate_workload(scenario, n_requests, cfg.padded_vocab,
                               seed=SEED, max_prompt=16, max_new=6)
        fc = FleetConfig(max_slots=4, block_size=4, num_blocks=64,
                         max_blocks_per_slot=8)
        router = FleetRouter(model, peer_params[:peers], config=fc,
                             policy=policy, canary_every=4)
        t0 = time.perf_counter()
        rep = router.run(wl, slo_ms=50.0)
        wall_s = time.perf_counter() - t0
        # rows read the report through FleetReport.to_dict() — the same
        # serialization path as `launch.serve --report` and the obs
        # metrics export — so a field drift breaks all three at once
        d = rep.to_dict()
        us_per_tok = wall_s * 1e6 / max(1, d["generated_tokens"])
        comm = d["kv_bytes_written"] + d["refresh_bytes"]
        rows.append({
            "name": f"serving/{scenario}_{policy}",
            "us_per_call": us_per_tok,
            "derived": (f"p99_ttft_ms={d['p99_ttft_ms']:.3f},"
                        f"slo={d['slo_attainment']:.3f},"
                        f"sim_tok_s={d['sim_tokens_per_s']:.1f},"
                        f"completed={d['completed']},"
                        f"digest={d['stream_digest'][:12]},"
                        f"comm_bytes={comm}"),
        })
    rows.extend(_spec_rows(model, quick))
    rows.extend(_decode_rows(model, quick))
    return rows


_SPEC_KS = (2, 4)


def _spec_rows(model, quick: bool) -> List[Dict]:
    """Plain vs peer-speculative decode on the steady scenario.

    Both peers share one param set — the converged-codistillation limit the
    paper predicts, where the draft always agrees with the target — so these
    rows gate BOTH speculative guarantees at once: the temp-0 stream digest
    must equal the plain run's exactly (accept/reject-and-resample is
    lossless), and the k=4 simulated tokens/sec must clear 1.5x plain
    (one k-token verify forward beats k sequential decode steps). Arrivals
    are compressed 50x and outputs fixed at 16 tokens to put the fleet in
    the service-bound regime — arrival-bound traces hide decode cost.
    """
    from repro.serve.fleet import Request, SpecConfig, Workload

    cfg = model.cfg
    peer_params = [model.init(jax.random.key(SEED))] * 2
    n_requests = 8 if quick else 16
    base = generate_workload("steady", n_requests, cfg.padded_vocab,
                             seed=SEED, max_prompt=16, max_new=6)
    wl = Workload(base.scenario, base.seed,
                  [Request(r.rid, r.arrival_ms * 0.02, r.prompt, 16)
                   for r in base.requests])
    fc = FleetConfig(max_slots=4, block_size=4, num_blocks=64,
                     max_blocks_per_slot=8)

    def _cell(policy: str, spec=None):
        router = FleetRouter(model, peer_params, config=fc, policy=policy,
                             spec=spec)
        t0 = time.perf_counter()
        rep = router.run(wl, slo_ms=50.0)
        return rep.to_dict(), time.perf_counter() - t0

    plain, plain_wall = _cell("round_robin")
    comm = plain["kv_bytes_written"] + plain["refresh_bytes"]
    rows = [{
        "name": "serving/spec_plain",
        "us_per_call": plain_wall * 1e6 / max(1, plain["generated_tokens"]),
        "derived": (f"sim_tok_s={plain['sim_tokens_per_s']:.1f},"
                    f"completed={plain['completed']},"
                    f"digest={plain['stream_digest'][:12]},"
                    f"comm_bytes={comm}"),
    }]
    for k in _SPEC_KS:
        d, wall = _cell("speculative", spec=SpecConfig(k=k))
        assert d["stream_digest"] == plain["stream_digest"], \
            (k, d["stream_digest"], plain["stream_digest"])
        speedup = d["sim_tokens_per_s"] / plain["sim_tokens_per_s"]
        if k == 4:
            assert speedup > 1.5, (k, speedup)
        # spec comm counts both pools: target KV + the draft KV it mirrors
        comm = d["kv_bytes_written"] + d["refresh_bytes"]
        rows.append({
            "name": f"serving/spec_k{k}",
            "us_per_call": wall * 1e6 / max(1, d["generated_tokens"]),
            "derived": (f"sim_tok_s={d['sim_tokens_per_s']:.1f},"
                        f"speedup={speedup:.3f},"
                        f"accept_rate={d['spec_accept_rate']:.3f},"
                        f"digest_match={int(d['stream_digest'] == plain['stream_digest'])},"
                        f"completed={d['completed']},"
                        f"comm_bytes={comm}"),
        })
    return rows


# one fixed ragged slot population for the decode-kernel rows: 4 live slots
# spanning empty-context to every-block-live
_DECODE_LENGTHS = [2, 6, 11, 15]
_DECODE_POOL = dict(max_slots=4, block_size=4, num_blocks=32,
                    max_blocks_per_slot=8)


def _decode_rows(model, quick: bool) -> List[Dict]:
    """Decode-latency + per-token HBM-bytes rows for the fused
    paged-attention kernel vs the jnp gather oracle, per cache dtype."""
    from repro.kernels.paged_cache import is_quantized_dtype
    from repro.serve.fleet.cache import PagedCachePool
    from repro.serve.fleet.model_exec import build_decode_step

    cfg = model.cfg
    params = model.init(jax.random.key(SEED))
    variants = [("fused_fp32", jnp.float32, True),
                ("oracle_fp32", jnp.float32, False),
                ("fused_int8", jnp.int8, True)]
    rows: List[Dict] = []
    for name, dtype, fused in variants:
        pool = PagedCachePool(model, cache_dtype=dtype, **_DECODE_POOL)
        for s, ln in enumerate(_DECODE_LENGTHS):
            pool.allocate(s, ln + 2)     # covers the append position too
            pool.lengths[s] = ln
        wslot, woff = pool.write_maps(np.ones(pool.max_slots, bool))
        step = build_decode_step(model, fused_attention=fused)
        args = (params, pool.kv, pool.states, jnp.asarray(pool.table),
                jnp.asarray(pool.lengths), jnp.asarray(wslot),
                jnp.asarray(woff),
                jnp.zeros((pool.max_slots, 1), jnp.int32))
        _, us = timed(step, *args, warmup=1, iters=2 if quick else 5)

        # analytic per-tick decode HBM traffic (exact, deterministic):
        bs = pool.block_size
        n_attn = len(pool.kv_subs) * pool.n_scan
        row_b = cfg.num_kv_heads * cfg.resolved_head_dim \
            * jnp.dtype(dtype).itemsize
        live = sum((ln + bs) // bs for ln in _DECODE_LENGTHS)
        # each live block read exactly once, K and V, every attn sublayer
        kv_read = live * bs * row_b * 2 * n_attn
        if is_quantized_dtype(dtype):
            kv_read += live * bs * 4 * 2 * n_attn    # fp32 scale rows
        # the oracle also writes + re-reads the dense gather temporary
        temp = (pool.max_slots * pool.max_blocks_per_slot * bs
                * row_b * 2 * n_attn)
        total = kv_read if fused else kv_read + 2 * temp
        toks = len(_DECODE_LENGTHS)
        rows.append({
            "name": f"serving/decode_{name}",
            "us_per_call": us,
            "derived": (f"kv_read_per_tok={kv_read // toks},"
                        f"gather_temp_bytes={0 if fused else temp},"
                        f"live_blocks={live},"
                        f"comm_bytes={total}"),
        })
    return rows
