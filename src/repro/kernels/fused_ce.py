"""Fused cross-entropy Pallas TPU kernels (forward AND backward).

The (T, V) logits tensor is the dominant HBM object of LM training with large
vocabularies (Qwen: 152k). The jnp path materializes exp/normalizer
intermediates at full width; these kernels stream vocab TILES through VMEM,
maintaining online per-token accumulators — one pass over the logits per
direction, no (T, V) fp32 temporary, MXU-free (pure VPU reduction).

Forward kernels
  * ``_ce_kernel``        — plain NLL (the original seed kernel, kept for the
    forward-only ``fused_cross_entropy`` entry point);
  * ``_ce_parts_kernel``  — NLL *and* the label-smoothing term
    ``logZ - mean_v(x)`` plus the ``logZ`` residual, so the custom-VJP wrapper
    in ``ops.py`` can compose arbitrary smoothing outside the kernel and the
    backward never recomputes the normalizer.

Backward kernel
  * ``_ce_grad_kernel``   — ``dL/dx = (g_nll + g_smooth) * softmax(x)
    - g_nll * onehot(label) - g_smooth * 1/V`` recomputed tile-by-tile from
    the saved per-token ``logZ`` residual (softmax = exp(x - logZ)); the only
    (T, V) write is the gradient itself, emitted in the logits dtype.

Grid: (T/block_t, V/block_v) with the vocab axis INNERMOST so the per-row
scratch carries across vocab steps ("arbitrary" dimension semantics).
Padded vocab columns (callers pad with -1e30) never win the max, never match
a label, and are excluded from the smoothing mean via ``v_real``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def pl_scratch(shape, dtype=jnp.float32):
    return pltpu.VMEM(shape, dtype)


def tok_spec(block_t):
    """BlockSpec for a per-token (T,) operand on a (n_t, n_v) grid."""
    return pl.BlockSpec((block_t,), lambda i, j: (i,))


def tile_spec(block_t, block_v):
    """BlockSpec for a (T, V) operand tiled over the (n_t, n_v) grid."""
    return pl.BlockSpec((block_t, block_v), lambda i, j: (i, j))


def ce_accumulate(x, labels, j, m_ref, s_ref, t_ref, x_ref, *,
                  block_v: int, v_real: int):
    """One vocab tile of the streaming CE state: online (max, sumexp) plus
    the true-logit and real-column logit-sum accumulators. Shared between the
    standalone CE kernel and the combined CE+distill kernel."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    s_ref[...] = s_ref[...] * alpha + jnp.sum(jnp.exp(x - m_new[:, None]),
                                              axis=-1)
    m_ref[...] = m_new
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + j * block_v
    hit = cols == labels[:, None]
    t_ref[...] = t_ref[...] + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)
    # sum of REAL logits only (padded cols hold -1e30, excluded by v_real)
    x_ref[...] = x_ref[...] + jnp.sum(jnp.where(cols < v_real, x, 0.0),
                                      axis=-1)


def ce_grad_term(x, labels, logz, gn, gs, j, *, block_v: int, v_real: int):
    """(dL/dx tile, softmax tile) for g_nll*nll + g_smooth*smooth, from the
    saved logZ residual: (gn+gs)*softmax - gn*onehot - gs*valid/V."""
    p = jnp.exp(x - logz[:, None])
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + j * block_v
    onehot = (cols == labels[:, None]).astype(jnp.float32)
    valid = (cols < v_real).astype(jnp.float32)
    return ((gn + gs)[:, None] * p - gn[:, None] * onehot
            - gs[:, None] * (valid / v_real)), p


def _ce_kernel(labels_ref, logits_ref, loss_ref, m_ref, s_ref, t_ref, *,
               block_v: int, n_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    x = logits_ref[...].astype(jnp.float32)          # (block_t, block_v)
    labels = labels_ref[...]                         # (block_t,)

    # online logsumexp update
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    s_ref[...] = s_ref[...] * alpha + jnp.sum(jnp.exp(x - m_new[:, None]),
                                              axis=-1)
    m_ref[...] = m_new

    # accumulate the true logit if the label falls in this vocab tile
    base = j * block_v
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + base
    hit = cols == labels[:, None]
    t_ref[...] = t_ref[...] + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)

    @pl.when(j == n_v - 1)
    def _fin():
        loss_ref[...] = m_ref[...] + jnp.log(s_ref[...]) - t_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def fused_cross_entropy(logits: jax.Array, labels: jax.Array,
                        block_t: int = 256, block_v: int = 512,
                        interpret: bool = False) -> jax.Array:
    """Per-token CE. logits (T, V), labels (T,) int32 -> (T,) fp32.

    T % block_t == 0 and V % block_v == 0 (callers pad; configs already pad
    vocab to a multiple of 256).
    """
    t, v = logits.shape
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    n_t, n_v = t // block_t, v // block_v
    kernel = functools.partial(_ce_kernel, block_v=block_v, n_v=n_v)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i, j: (i,)),
            pl.BlockSpec((block_t, block_v), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[
            pl_scratch((block_t,)),
            pl_scratch((block_t,)),
            pl_scratch((block_t,)),
        ],
        interpret=interpret,
    )(labels, logits)


# ----------------------------------------------------------------------------
# forward with label-smoothing parts + logZ residual (custom-VJP entry)
# ----------------------------------------------------------------------------

def _ce_parts_kernel(labels_ref, logits_ref, nll_ref, smooth_ref, logz_ref,
                     m_ref, s_ref, t_ref, x_ref, *,
                     block_v: int, n_v: int, v_real: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)
        x_ref[...] = jnp.zeros_like(x_ref)

    x = logits_ref[...].astype(jnp.float32)
    ce_accumulate(x, labels_ref[...], j, m_ref, s_ref, t_ref, x_ref,
                  block_v=block_v, v_real=v_real)

    @pl.when(j == n_v - 1)
    def _fin():
        logz = m_ref[...] + jnp.log(s_ref[...])
        logz_ref[...] = logz
        nll_ref[...] = logz - t_ref[...]
        smooth_ref[...] = logz - x_ref[...] / v_real


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "v_real",
                                             "interpret"))
def fused_cross_entropy_parts(logits: jax.Array, labels: jax.Array,
                              block_t: int = 256, block_v: int = 512,
                              v_real: int = 0, interpret: bool = False):
    """Per-token (nll, smooth, logZ). logits (T, V), labels (T,) -> 3x (T,).

    ``nll = logZ - x[label]``; ``smooth = logZ - mean_{v<v_real}(x)`` (the
    label-smoothing term); ``logZ`` is the residual the backward kernel uses
    to rebuild softmax without a second max pass. ``v_real`` (default: V)
    excludes padded vocab columns from the smoothing mean.
    """
    t, v = logits.shape
    v_real = v_real or v
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    n_t, n_v = t // block_t, v // block_v
    kernel = functools.partial(_ce_parts_kernel, block_v=block_v, n_v=n_v,
                               v_real=v_real)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[tok_spec(block_t), tile_spec(block_t, block_v)],
        out_specs=[tok_spec(block_t) for _ in range(3)],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.float32)] * 3,
        scratch_shapes=[pl_scratch((block_t,)) for _ in range(4)],
        interpret=interpret,
    )(labels, logits)


# ----------------------------------------------------------------------------
# backward: dL/dx from the saved logZ residual, one streaming pass
# ----------------------------------------------------------------------------

def _ce_grad_kernel(labels_ref, logz_ref, gn_ref, gs_ref, logits_ref, dx_ref,
                    *, block_v: int, v_real: int):
    x = logits_ref[...].astype(jnp.float32)
    dx, _ = ce_grad_term(x, labels_ref[...], logz_ref[...], gn_ref[...],
                         gs_ref[...], pl.program_id(1), block_v=block_v,
                         v_real=v_real)
    dx_ref[...] = dx.astype(dx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "v_real",
                                             "interpret"))
def fused_cross_entropy_grad(logits: jax.Array, labels: jax.Array,
                             logz: jax.Array, g_nll: jax.Array,
                             g_smooth: jax.Array, block_t: int = 256,
                             block_v: int = 512, v_real: int = 0,
                             interpret: bool = False) -> jax.Array:
    """dlogits for ``g_nll * nll + g_smooth * smooth`` (per token).

    Each (block_t, block_v) logits tile is read once; the gradient tile is the
    only (T, V) write, in the logits dtype. No cross-tile carry (every tile's
    gradient depends only on the (T,) residuals).
    """
    t, v = logits.shape
    v_real = v_real or v
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    kernel = functools.partial(_ce_grad_kernel, block_v=block_v, v_real=v_real)
    return pl.pallas_call(
        kernel,
        grid=(t // block_t, v // block_v),
        in_specs=[tok_spec(block_t)] * 4 + [tile_spec(block_t, block_v)],
        out_specs=tile_spec(block_t, block_v),
        out_shape=jax.ShapeDtypeStruct((t, v), logits.dtype),
        interpret=interpret,
    )(labels, logz, g_nll, g_smooth, logits)
