"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode for
validation; on TPU they compile to Mosaic. ``auto_interpret()`` picks per
backend so model code can call these unconditionally. Shapes are padded to
block multiples here so callers never worry about alignment.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.distill_loss import fused_distill_loss
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_ce import fused_cross_entropy


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def cross_entropy_tokens(logits: jax.Array, labels: jax.Array,
                         block_t: int = 256, block_v: int = 512,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Per-token CE over the trailing vocab dim; any leading shape."""
    interpret = auto_interpret() if interpret is None else interpret
    lead = logits.shape[:-1]
    v = logits.shape[-1]
    t = int(jnp.prod(jnp.array(lead))) if lead else 1
    lg = logits.reshape(t, v)
    lb = labels.reshape(t)
    tp = (-t) % block_t
    lg = _pad_to(lg, 0, block_t)
    lg = _pad_to(lg, 1, block_v, value=-1e30)
    lb = jnp.pad(lb, (0, tp))
    # padded vocab cols get -1e30 (never win max / never the label)
    out = fused_cross_entropy(lg, lb, block_t=block_t,
                              block_v=min(block_v, lg.shape[1]),
                              interpret=interpret)
    return out[:t].reshape(lead)


def distill_loss_tokens(logits: jax.Array, target_logits: jax.Array,
                        mode: str = "mse", block_t: int = 256,
                        block_v: int = 512,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Per-token distillation loss over the trailing vocab dim."""
    interpret = auto_interpret() if interpret is None else interpret
    lead = logits.shape[:-1]
    v = logits.shape[-1]
    t = int(jnp.prod(jnp.array(lead))) if lead else 1
    a = logits.reshape(t, v)
    b = target_logits.reshape(t, v)
    a = _pad_to(_pad_to(a, 0, block_t), 1, block_v,
                value=0.0 if mode == "mse" else -1e30)
    b = _pad_to(_pad_to(b, 0, block_t), 1, block_v,
                value=0.0 if mode == "mse" else -1e30)
    out = fused_distill_loss(a, b, mode=mode, block_t=block_t,
                             block_v=min(block_v, a.shape[1]),
                             interpret=interpret)
    if mode == "mse" and a.shape[1] != v:
        out = out * (a.shape[1] / v)  # undo the padded-vocab mean denominator
    return out[:t].reshape(lead)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              window: int = 0, block_q: int = 128, block_k: int = 128,
              interpret: Optional[bool] = None) -> jax.Array:
    """GQA flash attention with automatic seq padding."""
    interpret = auto_interpret() if interpret is None else interpret
    sq, tk = q.shape[1], k.shape[1]
    bq = min(block_q, max(16, sq))
    bk = min(block_k, max(16, tk))
    if not causal:
        # padded keys would receive softmax mass without a causal mask
        assert tk % bk == 0, "non-causal attention needs T % block_k == 0"
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    # causal mask makes padded keys unreachable from real queries (padded key
    # positions >= sq > any real query row); padded query rows are sliced off.
    out = flash_attention(qp, kp, vp, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :sq]
