"""Reduce per-cell sweep results into paper-style tables.

Three artifacts per sweep, written next to the cell results under
``results/sweeps/<name>/``:

* ``SWEEP_<name>.json`` — machine-readable grid: one row per grid cell
  (seed axis collapsed to mean / min / max / range — the paper's error
  bars), the codist-vs-allreduce final-loss gap, and the Section-3
  communication cost to reach fixed quality levels;
* ``SWEEP_<name>.md`` — the same grid as a markdown table;
* return value — the JSON document, for benchmarks and tests.

The gap column is the paper's central comparison (Sections 4-5): for every
codistillation cell, ``final_loss - final_loss(allreduce)`` at the SAME
(batch size, LR schedule) coordinates. Quality levels are defined off that
same baseline: ``L* = allreduce mean final task loss`` per (batch, lr)
group, levels at ``factor * L*`` — "bytes to reach quality" is the first
logged step whose task loss crosses the level, priced by the cumulative
``comm_bytes`` the loop metered up to that step.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

from repro.experiments.runner import SCHEMA_VERSION, sweep_dir_for

#: quality levels as multiples of the matched all-reduce baseline's final loss
QUALITY_FACTORS = (1.5, 1.2, 1.05)


def _mean(xs: List[float]) -> Optional[float]:
    xs = [x for x in xs if x is not None]
    return sum(xs) / len(xs) if xs else None


def load_summaries(sweep_dir: str,
                   cell_ids: Optional[set] = None) -> List[Dict]:
    """All valid cell summaries in a sweep directory.

    ``cell_ids`` restricts the load to the given ids — pass the current
    spec expansion's ids so summaries left behind by a PREVIOUS revision
    of a same-named spec (removed axis points, renamed schedules) don't
    pollute the tables. ``None`` loads everything (tests, ad-hoc dirs).
    Results for the SAME cell at different ``--steps`` share an id; the
    aggregator keeps them honest by grouping on step count too.
    """
    out = []
    if not os.path.isdir(sweep_dir):  # never-run sweep: empty, not a crash
        return out
    for fn in sorted(os.listdir(sweep_dir)):
        if not fn.endswith(".json") or fn.startswith(("SWEEP_", "spec")):
            continue
        try:
            with open(os.path.join(sweep_dir, fn)) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if (doc.get("status") == "complete"
                and doc.get("schema") == SCHEMA_VERSION
                and (cell_ids is None or doc.get("cell_id") in cell_ids)):
            out.append(doc)
    return out


def comm_to_quality(history, levels: Dict[str, float]) -> Dict[str, Optional[float]]:
    """First-crossing communication cost: for each quality level, the
    cumulative ``comm_bytes`` at the first logged step whose ``task_loss``
    is at or below the level (None if never reached or the history carries
    no comm metering, e.g. async per-peer records)."""
    out: Dict[str, Optional[float]] = {label: None for label in levels}
    for rec in history.records:
        loss = rec.get("task_loss")
        if loss is None:
            continue
        for label, level in levels.items():
            if out[label] is None and loss <= level:
                out[label] = rec.get("comm_bytes")
    return out


def aggregate(sweep_dir: str, name: Optional[str] = None,
              cell_ids: Optional[set] = None) -> Dict:
    """Collapse the seed axis and compute the paper-style columns."""
    name = name or os.path.basename(os.path.normpath(sweep_dir))
    summaries = load_summaries(sweep_dir, cell_ids)

    # group cells by grid coordinates (minus seed) PLUS step count: results
    # for the same cell id trained for different lengths (--steps override,
    # partial resume of a re-specced sweep) must never be averaged together
    # or compared against each other
    groups: Dict[Tuple, List[Dict]] = {}
    for s in summaries:
        groups.setdefault(tuple(s["grid_key"]) + (s["steps"],),
                          []).append(s)

    # the all-reduce baseline per (batch, lr, steps): mean final task loss
    baselines: Dict[Tuple, float] = {}
    for key, cells in groups.items():
        if key[0] == "allreduce":
            bkey = tuple(cells[0]["baseline_key"]) + (key[-1],)
            baselines[bkey] = _mean(
                [c["final"]["task_loss"] for c in cells])

    levels_by_baseline: Dict[Tuple, Dict[str, float]] = {
        bkey: {f"{f:g}x": f * lstar for f in QUALITY_FACTORS}
        for bkey, lstar in baselines.items()}

    from repro.train.loop import History
    rows: List[Dict] = []
    for key in sorted(groups):
        cells = groups[key]
        mode, batch, lr, alpha, peers = key[:-1]
        steps = key[-1]
        finals = [c["final"]["task_loss"] for c in cells]
        bkey = tuple(cells[0]["baseline_key"]) + (steps,)
        lstar = baselines.get(bkey)
        levels = levels_by_baseline.get(bkey, {})
        per_cell_quality = []
        for c in cells:
            hist_path = os.path.join(sweep_dir, c["cell_id"] + ".jsonl")
            try:
                hist = History.load(hist_path)
            except (OSError, json.JSONDecodeError):
                continue
            per_cell_quality.append(comm_to_quality(hist, levels))
        bytes_to_quality = {
            label: _mean([q[label] for q in per_cell_quality])
            for label in levels}
        row = {
            "mode": mode, "batch": batch, "lr": lr, "alpha": alpha,
            "peers": peers, "steps": steps,
            "seeds": sorted(c["cell"]["seed"] for c in cells),
            "final_loss_mean": _mean(finals),
            "final_loss_min": min(finals),
            "final_loss_max": max(finals),
            "final_loss_range": max(finals) - min(finals),
            "accuracy_mean": _mean(
                [c["final"].get("accuracy") for c in cells]),
            "comm_events_mean": _mean(
                [c["final"].get("comm_events") for c in cells]),
            "comm_bytes_mean": _mean(
                [c["final"].get("comm_bytes") for c in cells]),
            "gap_vs_allreduce": (
                None if mode == "allreduce" or lstar is None
                else _mean(finals) - lstar),
            "bytes_to_quality": bytes_to_quality,
        }
        rows.append(row)

    return {
        "schema": SCHEMA_VERSION,
        "sweep": name,
        "n_cells": len(summaries),
        "quality_factors": list(QUALITY_FACTORS),
        "quality_levels": {
            f"b{bkey[0]}-{bkey[1]}@{bkey[2]}steps": levels
            for bkey, levels in levels_by_baseline.items()},
        "grid": rows,
    }


# ----------------------------------------------------------------------------
# rendering
# ----------------------------------------------------------------------------

def _fmt(x, digits=4) -> str:
    if x is None:
        return "-"
    if isinstance(x, float):
        return f"{x:.{digits}f}"
    return str(x)


def _fmt_bytes(x) -> str:
    if x is None:
        return "-"
    if x >= 1e6:
        return f"{x / 1e6:.2f}MB"
    if x >= 1e3:
        return f"{x / 1e3:.1f}KB"
    return f"{x:.0f}B"


def render_markdown(doc: Dict) -> str:
    q_labels = [f"{f:g}x" for f in doc.get("quality_factors", [])]
    lines = [
        f"# Sweep `{doc['sweep']}`",
        "",
        f"{doc['n_cells']} completed cells. Final loss is the mean over "
        "seeds; +-range is max-min over seeds (the paper's error bars). "
        "`gap` is final loss minus the all-reduce baseline at the same "
        "(batch, LR) coordinates — the paper's central codist-vs-sync "
        "comparison. `bytes->Q` is the cumulative cross-pod communication "
        "until task loss first crossed Q x baseline-final-loss.",
        "",
        "| mode | batch | lr | alpha | peers | steps | final loss | "
        "+-range | gap vs all-reduce | comm bytes |"
        + "".join(f" bytes->{q} |" for q in q_labels),
        "|---|---|---|---|---|---|---|---|---|---|"
        + "---|" * len(q_labels),
    ]
    for r in doc["grid"]:
        cells = [r["mode"], r["batch"], r["lr"], r["alpha"], r["peers"],
                 r["steps"],
                 _fmt(r["final_loss_mean"]), _fmt(r["final_loss_range"]),
                 _fmt(r["gap_vs_allreduce"]),
                 _fmt_bytes(r["comm_bytes_mean"])]
        cells += [_fmt_bytes(r["bytes_to_quality"].get(q)) for q in q_labels]
        lines.append("| " + " | ".join(str(c) for c in cells) + " |")
    lines.append("")
    return "\n".join(lines)


def write_outputs(doc: Dict, sweep_dir: str) -> Tuple[str, str]:
    """Write ``SWEEP_<name>.json`` + ``SWEEP_<name>.md``; returns paths."""
    os.makedirs(sweep_dir, exist_ok=True)
    json_path = os.path.join(sweep_dir, f"SWEEP_{doc['sweep']}.json")
    md_path = os.path.join(sweep_dir, f"SWEEP_{doc['sweep']}.md")
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    with open(md_path, "w") as f:
        f.write(render_markdown(doc))
    return json_path, md_path


def aggregate_and_write(spec, out_root: str = "results/sweeps"
                        ) -> Tuple[Dict, str, str]:
    """Aggregate a :class:`~repro.experiments.spec.SweepSpec`'s results —
    restricted to the spec's CURRENT cell expansion, so stale results from
    an earlier revision of a same-named spec are ignored."""
    sweep_dir = sweep_dir_for(spec.name, out_root)
    doc = aggregate(sweep_dir, spec.name,
                    {c.cell_id for c in spec.cells()})
    json_path, md_path = write_outputs(doc, sweep_dir)
    return doc, json_path, md_path
