"""Event-driven scheduler for the asynchronous codistillation runtime.

``AsyncScheduler`` runs N codistilling peers on **independent step clocks**
over one simulated timeline (:mod:`repro.runtime.clock`): at every tick the
set of peers whose clocks are ready (1) publishes its predictions for the
coordinated batch into the :class:`~repro.runtime.mailbox.Mailbox`, then
(2) steps its model with whatever peer payloads the staleness policy
accepts. No peer ever waits for another — a straggler or preempted peer
only degrades the freshness of the targets it feeds the others, which is
exactly the codistillation fault-tolerance argument (Anil et al.,
arXiv:1804.03235). Equal-speed fault-free peers tie at every tick and the
publish-then-step ordering makes staleness 0, so ``staleness_bound=0``
reproduces the synchronous ``PredictionExchange`` trajectory.

``simulate_allreduce`` is the barrier baseline on the same fault schedule:
one data-parallel model whose per-step time is the MAX over the virtual
peers (the slowest replica gates everyone), preemptions stall the whole
job, and a permanent failure costs a restart-from-checkpoint stall.

Both report simulated wall-clock and metered communication so
``benchmarks/fault_tolerance.py`` can compare the schemes under identical
fault schedules.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CodistConfig, TrainConfig
from repro.core.exchange import StepPlan
from repro.optim import make_optimizer
from repro.runtime.clock import FaultConfig, FaultSchedule, VirtualClock
from repro.runtime.mailbox import Mailbox
from repro.runtime.peer import PeerRuntime
from repro.train.engine import (AllReduce, AsyncPrediction, _task_forward,
                                build_train_step)
from repro.train.loop import History
from repro.train.state import TrainState
from repro.core.codistillation import (compress_targets, init_stacked,
                                       model_slice)

PyTree = Any
Batches = Callable[[int], Dict]


@dataclass
class RunReport:
    """What a simulated run produced, for benchmarks and tests."""
    scheme: str
    sim_time: float                       # last surviving peer's finish time
    time_to_first: float                  # earliest deployable model
    completion: Dict[int, float]          # peer -> finish time
    comm_events: int
    comm_bytes: float
    staleness: Dict[str, float] = field(default_factory=dict)
    final_task_loss: Dict[int, float] = field(default_factory=dict)
    histories: Dict[int, History] = field(default_factory=dict)
    states: Dict[int, Any] = field(default_factory=dict)

    def save_histories(self, directory: str) -> None:
        import os
        for pid, hist in self.histories.items():
            hist.save(os.path.join(directory, f"peer{pid}.jsonl"))


class AsyncScheduler:
    """Drive per-peer ``build_train_step`` bundles on independent clocks."""

    def __init__(self, model, tc: TrainConfig, codist: CodistConfig,
                 batches: Batches, faults: FaultConfig, *,
                 staleness_bound: Optional[int] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 recover_after: Optional[float] = None,
                 join_burn_in: int = 0,
                 log_every: int = 1,
                 max_sim_time: float = float("inf"),
                 tracer=None, metrics=None, watch=None):
        self.model, self.tc, self.codist = model, tc, codist
        # observability (repro.obs) on the virtual cluster clock (simulated
        # seconds): per-peer step/publish/recover spans, mailbox staleness
        # and comm counters. None = the run path is untouched. ``watch`` is
        # an optional Watchtower evaluated once per scheduler round.
        self.tracer = tracer
        self.metrics = metrics
        self.watch = watch
        self.batches = batches
        self.faults = faults
        self.schedule = FaultSchedule(faults, tc.total_steps)
        self.mailbox = Mailbox(staleness_bound)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.recover_after = recover_after
        self.join_burn_in = join_burn_in or codist.burn_in_steps
        self.log_every = max(1, log_every)
        self.max_sim_time = max_sim_time

        n_slots = max(codist.n_models, faults.n_total)
        self.strategy = AsyncPrediction(codist, n_slots=n_slots)
        self.bundle = build_train_step(model, tc, codist, self.strategy)
        self._pred_cfg = replace(codist, mode="predictions")
        opt_init, _ = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                     b1=tc.adam_b1, b2=tc.adam_b2,
                                     dtype=tc.opt_dtype)
        self._opt_init = opt_init

        # identical init to the synchronous engine: one stacked init, sliced
        # per peer — so staleness_bound=0 parity holds down to the bits
        key = jax.random.key(tc.seed)
        stacked = init_stacked(model.init, key, faults.n_peers)
        self.peers: Dict[int, PeerRuntime] = {}
        for p in range(faults.n_peers):
            params = model_slice(stacked, p)
            state = TrainState(params, opt_init(params),
                               jnp.zeros((), jnp.int32))
            self.peers[p] = PeerRuntime(p, state)
            if tracer is not None:
                tracer.name_process(p, f"peer{p}")

        example = batches(0)
        k = max(1, tc.microbatch)

        def publish_wire(pr, b, remat):
            # with gradient accumulation the batch leaves lead with the
            # microbatch axis; payloads keep that (k, B/k, ...) layout.
            # Compression happens HERE, on the producer side — the mailbox
            # carries (and meters) the compressed wire, exactly what would
            # cross the slow links
            f = lambda bb: _task_forward(model, pr, bb, remat)[0]
            logits = (jax.vmap(f)(b) if k > 1 else f(b)).astype(jnp.float32)
            return compress_targets(codist, logits)

        wire_sd = jax.eval_shape(
            lambda pr, b: publish_wire(pr, b, False),
            self.peers[0].state.params, example)
        n_targets = n_slots - 1
        self._zero_wire = jax.tree.map(
            lambda s: jnp.zeros((n_targets,) + s.shape, s.dtype), wire_sd)
        self._zero_vec = jnp.zeros((n_targets,), jnp.float32)
        self._publish = jax.jit(
            lambda pr, b: publish_wire(pr, b, tc.remat))
        self.comm_events = 0
        self._failed_once: set = set()  # a machine dies once; the recovered
        # replacement replays through the failure step unharmed

    # ------------------------------------------------------------------
    def _fresh_peer(self, pid: int, joined_at: float) -> PeerRuntime:
        params = self.model.init(
            jax.random.fold_in(jax.random.key(self.tc.seed), 1000 + pid))
        state = TrainState(params, self._opt_init(params),
                           jnp.zeros((), jnp.int32))
        return PeerRuntime(pid, state, burn_in=self.join_burn_in,
                           joined_at=joined_at)

    def _exchange_on(self, peer: PeerRuntime) -> bool:
        plan = StepPlan.for_step(self._pred_cfg, peer.step)
        return plan.distill and peer.distill_ready

    def _gather_operand(self, peer: PeerRuntime, batch: Dict
                        ) -> Tuple[Dict, float]:
        senders = sorted(q for q, pr in self.peers.items()
                         if q != peer.pid and pr.alive)
        wires, weights, stale = self._zero_wire, self._zero_vec, self._zero_vec
        wsum = 0.0
        for slot, (s, payload, w) in enumerate(
                self.mailbox.collect(peer.pid, peer.step, senders)):
            if payload is not None:
                wires = jax.tree.map(lambda z, v: z.at[slot].set(v),
                                     wires, payload.data)
                weights = weights.at[slot].set(w)
                stale = stale.at[slot].set(
                    max(0.0, float(peer.step - payload.step)))
                wsum += w
        operand = {"batch": batch, "peer_wire": wires,
                   "peer_weight": weights, "peer_staleness": stale}
        return operand, wsum

    def _step_peer(self, peer: PeerRuntime, now: float) -> float:
        """Run one local step; returns its simulated duration (incl. any
        preemption pause that follows it)."""
        step = peer.step
        batch = self.batches(step)
        if self._exchange_on(peer):
            operand, wsum = self._gather_operand(peer, batch)
            variant = "on" if wsum > 0 else "off"
            if wsum > 0:
                self.comm_events += 1
        else:
            operand = {"batch": batch, "peer_wire": self._zero_wire,
                       "peer_weight": self._zero_vec,
                       "peer_staleness": self._zero_vec}
            variant = "off"
        state, metrics = self.bundle.jitted(variant)(peer.state, operand)
        peer.advance(state)
        if step % self.log_every == 0 or peer.step >= self.tc.total_steps:
            peer.hist.log(step, metrics, sim_time=now, peer=peer.pid)
        if (self.checkpoint_dir and self.checkpoint_every
                and peer.step % self.checkpoint_every == 0):
            peer.snapshot(self.checkpoint_dir)
        dur = self.schedule.duration(peer.pid, step)
        pause = self.schedule.pause_after(peer.pid, step)
        if self.tracer is not None:
            self.tracer.complete("step", now, now + dur, pid=peer.pid,
                                 cat="runtime",
                                 args={"step": step, "variant": variant})
            if pause > 0:
                self.tracer.complete("preempted", now + dur,
                                     now + dur + pause, pid=peer.pid,
                                     cat="chaos")
        if self.metrics is not None:
            self.metrics.histogram("runtime/step_s").observe(dur)
            self.metrics.counter("runtime/steps").inc()
        return dur + pause

    # ------------------------------------------------------------------
    def run(self) -> RunReport:
        clock = VirtualClock()
        for p in self.peers:
            clock.add_peer(p)
        pending_joins: List[Tuple[int, float]] = list(self.schedule.joins)
        pending_recoveries: List[Tuple[int, float]] = []

        while True:
            # jump to pending membership events if no peer is on the clock
            if not clock.ready_at:
                upcoming = pending_joins + pending_recoveries
                if not upcoming:
                    break
                clock.now = min(t for _, t in upcoming)
            else:
                t_next = min(clock.ready_at.values())
                clock.now = max(clock.now, min(
                    [t_next] + [t for _, t in pending_joins]
                    + [t for _, t in pending_recoveries]))

            # membership: elastic joins and checkpoint recoveries due now
            for pid, jt in list(pending_joins):
                if jt <= clock.now + 1e-9:
                    pending_joins.remove((pid, jt))
                    self.peers[pid] = self._fresh_peer(pid, jt)
                    clock.add_peer(pid, at=jt)
                    if self.tracer is not None:
                        self.tracer.name_process(pid, f"peer{pid}")
                        self.tracer.instant("join", jt, pid=pid, cat="chaos")
            for pid, rt in list(pending_recoveries):
                if rt <= clock.now + 1e-9:
                    pending_recoveries.remove((pid, rt))
                    self.peers[pid].restore(self.checkpoint_dir, rt)
                    clock.add_peer(pid, at=rt)
                    if self.tracer is not None:
                        self.tracer.instant("recover", rt, pid=pid,
                                            cat="chaos")
            if not clock.ready_at:
                continue

            t, ready = clock.next_ready()
            if t > self.max_sim_time:
                break
            live = []
            for p in ready:
                peer = self.peers[p]
                fail_step = self.schedule.fails_at(p)
                if (fail_step is not None and peer.step >= fail_step
                        and p not in self._failed_once
                        and peer.alive and not peer.finished):
                    self._failed_once.add(p)
                    peer.die()
                    clock.remove_peer(p)
                    self.mailbox.drop_peer(p)
                    if self.tracer is not None:
                        self.tracer.instant("die", t, pid=p, cat="chaos")
                    if self.watch is not None:
                        self.watch.note_fault("fail", t,
                                              {"peer": p, "step": peer.step})
                    if (self.recover_after is not None
                            and peer.can_recover(self.checkpoint_dir)):
                        pending_recoveries.append(
                            (p, t + self.recover_after))
                    continue
                live.append(p)

            # phase 1: everyone ready publishes BEFORE anyone consumes, so
            # tied clocks see same-step (staleness-0) targets
            for p in live:
                peer = self.peers[p]
                if self._exchange_on(peer):
                    wire = self._publish(peer.state.params,
                                         self.batches(peer.step))
                    self.mailbox.post(p, peer.step, t, wire)
                    if self.tracer is not None:
                        self.tracer.instant("publish", t, pid=p,
                                            cat="runtime",
                                            args={"step": peer.step})
                        self.tracer.counter(
                            "mailbox", t,
                            {"bytes_delivered":
                             float(self.mailbox.bytes_delivered)})
                    if self.metrics is not None:
                        self.metrics.counter("runtime/publishes").inc()
                        # live staleness view for alert rules (same names
                        # and final values as the end-of-run block below)
                        for k, v in self.mailbox.stats.as_dict().items():
                            self.metrics.gauge(
                                f"runtime/mailbox_staleness_{k}").set(v)
            # phase 2: step
            for p in live:
                peer = self.peers[p]
                dur = self._step_peer(peer, t)
                if peer.step >= self.tc.total_steps:
                    peer.finished = True
                    peer.completed_at = t + dur
                    clock.remove_peer(p)
                else:
                    clock.advance(p, dur)
            if self.watch is not None:
                self.watch.evaluate(t)

        if self.metrics is not None:
            m = self.metrics
            m.counter("runtime/comm_events").inc(self.comm_events)
            m.counter("runtime/comm_bytes").inc(
                int(self.mailbox.bytes_delivered))
            # the mailbox staleness gauge the ISSUE asks for: the keep-last
            # policy's observed freshness, straight from the mailbox stats
            for k, v in self.mailbox.stats.as_dict().items():
                m.gauge(f"runtime/mailbox_staleness_{k}").set(v)
        completion = {p: pr.completed_at for p, pr in self.peers.items()
                      if pr.completed_at is not None}
        finals = {}
        for p, pr in self.peers.items():
            try:
                finals[p] = pr.hist.last("task_loss")
            except KeyError:
                pass
        return RunReport(
            scheme="codist-async",
            sim_time=max(completion.values()) if completion else clock.now,
            time_to_first=min(completion.values()) if completion
            else float("inf"),
            completion=completion,
            comm_events=self.comm_events,
            comm_bytes=float(self.mailbox.bytes_delivered),
            staleness=self.mailbox.stats.as_dict(),
            final_task_loss=finals,
            histories={p: pr.hist for p, pr in self.peers.items()},
            states={p: pr.state for p, pr in self.peers.items()},
        )


# ----------------------------------------------------------------------------
# the barrier baseline on the same fault schedule
# ----------------------------------------------------------------------------

def simulate_allreduce(model, tc: TrainConfig, batches: Batches,
                       faults: FaultConfig, *,
                       recover_after: Optional[float] = None,
                       log_every: int = 1) -> RunReport:
    """Synchronous data-parallel baseline: one model, but every step's
    simulated duration is the MAX over the virtual peers (the all-reduce
    barrier waits for the slowest replica), a preemption stalls the whole
    job, and a permanent failure costs one restart stall of
    ``recover_after`` simulated seconds (restore from the last checkpoint —
    arXiv:1604.00981's backup-worker problem, without backup workers)."""
    schedule = FaultSchedule(faults, tc.total_steps)
    strategy = AllReduce()
    bundle = build_train_step(model, tc, None, strategy)
    opt_init, _ = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                 b1=tc.adam_b1, b2=tc.adam_b2,
                                 dtype=tc.opt_dtype)
    state = strategy.init_state(model, tc, jax.random.key(tc.seed), opt_init)
    bytes_per_step = strategy.comm_bytes(model, state, batches(0))
    hist = History()
    now = 0.0
    peers = range(faults.n_peers)
    for k in range(tc.total_steps):
        dur = max(schedule.duration(p, k) for p in peers)
        stall = max(schedule.pause_after(p, k) for p in peers)
        for p in peers:
            if schedule.fails_at(p) == k:
                stall += recover_after if recover_after is not None else 0.0
        state, metrics, _ = bundle.apply(state, batches(k), k)
        now += dur + stall
        if k % max(1, log_every) == 0 or k == tc.total_steps - 1:
            hist.log(k, metrics, sim_time=now)
    return RunReport(
        scheme="allreduce",
        sim_time=now, time_to_first=now, completion={0: now},
        comm_events=tc.total_steps,
        comm_bytes=bytes_per_step * tc.total_steps,
        final_task_loss={0: hist.last("task_loss")},
        histories={0: hist}, states={0: state},
    )
