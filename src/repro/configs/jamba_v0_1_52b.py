"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536. One attention layer per
8 layers (the rest Mamba); MoE FFN every 2nd layer.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, reduced as _reduced

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    act="silu",
    attn_layer_period=8,
    moe=MoEConfig(num_experts=16, top_k=2, layer_period=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="Jamba v0.1 [arXiv:2403.19887]",
)


def reduced():
    return _reduced(CONFIG)
