"""Pure-python dry-run helper logic (no device mesh)."""
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import InputShape


def _dr():
    # import inside: dryrun sets XLA_FLAGS at import, safe (env only)
    import repro.launch.dryrun as dr
    return dr


class TestMicrobatchPicker:
    def test_divisibility_preserved(self):
        dr = _dr()
        for arch in ("deepseek-67b", "qwen2-7b", "arctic-480b", "whisper-tiny"):
            cfg = dr.dryrun_config(arch)
            shape = INPUT_SHAPES["train_4k"]
            for n in (1, 2):
                k = dr.pick_microbatch(cfg, shape, 16, n)
                b = shape.global_batch // n
                assert b % k == 0
                assert (b // k) % 16 == 0, (arch, n, k)

    def test_larger_models_get_more_microbatches(self):
        dr = _dr()
        shape = INPUT_SHAPES["train_4k"]
        k_small = dr.pick_microbatch(dr.dryrun_config("whisper-tiny"), shape, 16)
        k_big = dr.pick_microbatch(dr.dryrun_config("deepseek-67b"), shape, 16)
        assert k_big > k_small

    def test_decode_shapes_no_microbatch(self):
        dr = _dr()
        cfg = dr.dryrun_config("deepseek-67b")
        k = dr.pick_microbatch(cfg, INPUT_SHAPES["decode_32k"], 16)
        assert k == 1  # one-token decode has no backward residuals


class TestShapeAdaptation:
    def test_dense_long_context_gets_sliding_window(self):
        dr = _dr()
        cfg = dr.adapt_for_shape(dr.dryrun_config("deepseek-67b"), "long_500k")
        assert cfg.sliding_window == dr.SLIDING_WINDOW_FOR_LONG

    def test_ssm_and_hybrid_keep_native_attention(self):
        dr = _dr()
        for arch in ("rwkv6-1.6b", "jamba-v0.1-52b"):
            cfg = dr.adapt_for_shape(dr.dryrun_config(arch), "long_500k")
            assert cfg.sliding_window == 0

    def test_train_shapes_unmodified(self):
        dr = _dr()
        cfg = dr.adapt_for_shape(dr.dryrun_config("qwen2-7b"), "train_4k")
        assert cfg.sliding_window == 0

    def test_whisper_long_context_skipped(self):
        dr = _dr()
        assert ("whisper-tiny", "long_500k") in dr.SKIP

    def test_coverage_is_39(self):
        dr = _dr()
        from repro.configs import ASSIGNED_ARCHS
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES
                  if (a, s) not in dr.SKIP]
        assert len(combos) == 39


class TestInputSpecs:
    def test_codist_batch_split_and_microbatch(self):
        from repro.launch import specs as sp
        cfg = get_config("qwen2-7b")
        shape = INPUT_SHAPES["train_4k"]
        b = sp.train_batch_specs(cfg, shape, n_stack=2, microbatch=4)
        assert b["tokens"].shape == (2, 4, 32, 4096)  # 256/2/4 = 32

    def test_vlm_patch_prefix(self):
        from repro.launch import specs as sp
        cfg = get_config("internvl2-76b")
        shape = INPUT_SHAPES["train_4k"]
        b = sp.train_batch_specs(cfg, shape)
        assert b["patches"].shape == (256, 256, 8192)
        assert b["tokens"].shape[1] + 256 == 4096

    def test_encdec_frames(self):
        from repro.launch import specs as sp
        cfg = get_config("whisper-tiny")
        b = sp.train_batch_specs(cfg, INPUT_SHAPES["train_4k"])
        assert b["frames"].shape == (256, 1500, 384)

    def test_decode_cache_capacity(self):
        import jax.numpy as jnp
        from repro.launch import specs as sp
        from repro.models import build_model
        cfg = get_config("qwen1.5-0.5b")
        model = build_model(cfg)
        cache = sp.cache_specs(model, cfg, INPUT_SHAPES["decode_32k"])
        k = cache["sub0"]["k"]
        assert k.shape == (24, 128, 32768, 16, 64)
        assert k.dtype == jnp.bfloat16
