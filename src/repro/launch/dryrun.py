"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

Run as a module:
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k \
        --mesh multi --mode codist

Proves the distribution config is coherent without hardware: a sharding
mismatch, compile-time OOM or unsupported collective fails here. Per combo it
records memory_analysis(), cost_analysis() and the parsed collective schedule
(intra- vs cross-pod bytes) for EXPERIMENTS.md §Dry-run / §Roofline.
"""
# The VERY FIRST lines, before ANY other import — jax locks the device count
# on first init. 512 host devices serve both the 256-chip single-pod mesh and
# the 2x256 multi-pod mesh.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from dataclasses import replace  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, CodistConfig,  # noqa: E402
                           TrainConfig, get_config)
from repro.launch import sharding as sh  # noqa: E402
from repro.launch import specs as sp     # noqa: E402
from repro.launch.hlo_analysis import parse_collectives  # noqa: E402
from repro.launch.mesh import make_production_mesh, set_mesh  # noqa: E402
from repro.launch.roofline import build_report  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models import sharding_hints as hints  # noqa: E402
from repro.optim import make_optimizer  # noqa: E402
from repro.train.engine import (AllReduce, PredictionExchange,  # noqa: E402
                                build_train_step)
from repro.train.state import CodistState, TrainState  # noqa: E402

SDS = jax.ShapeDtypeStruct

# dense-family archs take the sliding-window variant for long_500k (the
# sub-quadratic carve-in); whisper skips it entirely (see DESIGN.md).
SLIDING_WINDOW_FOR_LONG = 8192
SKIP = {("whisper-tiny", "long_500k")}


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() across jax versions (older returns a list of
    per-program dicts, newer a single dict)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def dryrun_config(arch: str):
    """Full config adapted for dry-run numerics: bf16 params+activations."""
    cfg = get_config(arch)
    return replace(cfg, dtype="bfloat16", param_dtype="bfloat16")


def adapt_for_shape(cfg, shape_name: str):
    if shape_name == "long_500k" and not cfg.attention_free \
            and cfg.attn_layer_period == 0:
        # dense/moe/vlm: sliding-window attention => O(W) decode state
        cfg = replace(cfg, sliding_window=SLIDING_WINDOW_FOR_LONG)
    return cfg


def pick_microbatch(cfg, shape, data_ways: int, n_models: int = 1,
                    target_gb: float = 2.5) -> int:
    """Gradient-accumulation factor: keep the per-device activations saved
    for backward (one (B,S,d) bf16 residual per scanned layer) under
    ``target_gb``. k must keep B/n/k divisible by the data axis."""
    if getattr(cfg, "kind", None):  # conv models: small
        return 1
    if shape.kind != "train":  # one-token decode / fwd-only prefill
        return 1
    b = shape.global_batch // max(1, n_models)
    per_dev = b / data_ways
    carry_gb = per_dev * shape.seq_len * cfg.d_model * 2 * cfg.num_layers / 1e9
    k, max_k = 1, max(1, b // data_ways)
    while carry_gb / k > target_gb and k < max_k:
        k *= 2
    return min(k, max_k)


def _train_lowering(model, cfg, shape, mesh, mode: str, codist_n: int,
                    remat: bool, extra: Optional[Dict] = None,
                    microbatch: Optional[int] = None,
                    variant: Optional[Dict] = None):
    variant = variant or {}
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    multi = "pod" in sizes
    if mode == "allreduce":
        data_ways = sizes["data"] * (sizes.get("pod", 1))
        k = microbatch or pick_microbatch(cfg, shape, data_ways)
        tc = TrainConfig(optimizer="sgdm", remat=remat, total_steps=1000,
                         microbatch=k, opt_dtype="bfloat16",
                         accum_dtype="bfloat16")
        step = build_train_step(model, tc, None, AllReduce()).variants["on"]
        params_sds = sp.params_specs(model)
        opt_init, _ = make_optimizer("sgdm", dtype="bfloat16")
        opt_sds = jax.eval_shape(opt_init, params_sds)
        state_sds = TrainState(params_sds, opt_sds,
                               SDS((), jnp.int32))
        batch_sds = sp.train_batch_specs(cfg, shape, microbatch=k)
    else:
        k = microbatch or pick_microbatch(cfg, shape, sizes["data"], codist_n)
        tc = TrainConfig(optimizer="sgdm", remat=remat, total_steps=1000,
                         microbatch=k, opt_dtype="bfloat16",
                         accum_dtype="bfloat16")
        codist = CodistConfig(n_models=codist_n, mode="predictions",
                              **(extra or {}))
        step = build_train_step(model, tc, codist,
                                PredictionExchange(codist)).variants["on"]
        params_sds = sp.stacked_params_specs(model, codist_n)
        opt_init, _ = make_optimizer("sgdm", dtype="bfloat16")
        opt_sds = jax.eval_shape(opt_init, params_sds)
        state_sds = CodistState(params_sds, opt_sds, SDS((), jnp.int32),
                                None, None)
        batch_sds = sp.train_batch_specs(cfg, shape, n_stack=codist_n,
                                         microbatch=k)
    stacked = mode != "allreduce"
    state_sh = sh.state_shardings(
        state_sds, mesh, stacked=stacked,
        fsdp_axis=variant.get("train_fsdp_axis", "data"),
        moe_expert_axis=variant.get("moe_expert_axis"))
    batch_sh = sh.batch_shardings(batch_sds, mesh, stacked=stacked,
                                  microbatched=k > 1)
    multi = "pod" in mesh.axis_names
    batch_axes = ("data",) if stacked else (
        ("pod", "data") if multi else ("data",))
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    with set_mesh(mesh), hints.activation_sharding(batch_axes, "model",
                                                   tp_size, mesh):
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(
            state_sds, batch_sds)
    return lowered


def _prefill_lowering(model, cfg, shape, mesh):
    cap = shape.seq_len

    def prefill_step(params, batch):
        return model.prefill(params, batch, cap, cache_dtype=jnp.bfloat16)

    params_sds = sp.params_specs(model)
    batch_sds = sp.prefill_batch_specs(cfg, shape)
    params_sh = sh.state_shardings(params_sds, mesh)
    batch_sh = sh.batch_shardings(batch_sds, mesh)
    with set_mesh(mesh):
        lowered = jax.jit(prefill_step,
                          in_shardings=(params_sh, batch_sh)).lower(
            params_sds, batch_sds)
    return lowered


def _decode_lowering(model, cfg, shape, mesh, variant: Optional[Dict] = None):
    variant = variant or {}

    def decode_step(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    params_sds = sp.params_specs(model)
    cache_sds = sp.cache_specs(model, cfg, shape)
    tok_sds = sp.decode_token_specs(shape)
    pos_sds = SDS((), jnp.int32)
    # 'ws'  = fully weight-stationary: params on the model axis only
    #         (replicated over data) — no re-gathers, but every device reads
    #         the full TP shard per step;
    # '2d'  = FFN/head/embedding 2D-sharded over (data x model) weight-
    #         stationary, attention keeps FSDP+TP (the serving sweet spot).
    # 'repl-batch' = batch-replicated decode: activations are tiny at decode,
    #                so replicate them and psum partial matmuls — weights stay
    #                fully sharded (FSDP+TP) and never move; the cache shards
    #                over TIME (context parallelism) instead of batch.
    ds = variant.get("decode_sharding", "fsdp")
    fsdp = None if ds == "ws" else "data"
    params_sh = sh.state_shardings(
        params_sds, mesh, fsdp_axis=fsdp,
        moe_expert_axis=variant.get("moe_expert_axis"),
        two_d_ffn=ds == "2d")
    cache_sh = sh.cache_shardings(cache_sds, mesh, shape.global_batch,
                                  prefer_time=ds == "repl-batch")
    if ds == "repl-batch":
        tok_sh = jax.tree.map(lambda _: sh.replicated(mesh), tok_sds)
    else:
        tok_sh = sh.batch_shardings(tok_sds, mesh)
    pos_sh = sh.replicated(mesh)
    with set_mesh(mesh):
        lowered = jax.jit(decode_step, in_shardings=(
            params_sh, cache_sh, tok_sh, pos_sh)).lower(
            params_sds, cache_sds, tok_sds, pos_sds)
    return lowered


def _lower_for(model, cfg, shape, mesh, mode: str, codist_n: int,
               remat: bool, codist_extra=None, microbatch=None,
               variant=None):
    if shape.kind == "train":
        return _train_lowering(
            model, cfg, shape, mesh,
            "codist" if mode == "codist" else "allreduce",
            codist_n, remat, codist_extra, microbatch, variant)
    if shape.kind == "prefill":
        return _prefill_lowering(model, cfg, shape, mesh)
    return _decode_lowering(model, cfg, shape, mesh, variant)


def _extract_cost(compiled, multi_pod: bool, devices_per_pod: int = 256):
    cost = _cost_dict(compiled)
    coll = parse_collectives(compiled.as_text(),
                             devices_per_pod=devices_per_pod if multi_pod
                             else 0)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll.total_bytes),
        "cross_pod_bytes": float(coll.cross_pod_bytes),
    }


def corrected_cost(arch: str, cfg, shape, mesh, multi_pod: bool, mode: str,
                   codist_n: int, remat: bool, codist_extra=None,
                   variant=None):
    """XLA cost_analysis counts while-loop bodies ONCE, so scanned-layer costs
    are invisible at full depth. Probe the SAME program with the layer scan
    UNROLLED (and SSM chunk scans widened to one full-sequence chunk) at
    n_scan=1 and n_scan=2 — making every FLOP/collective statically visible —
    then extrapolate: cost(full) = c1 + (n_scan_full - 1) * (c2 - c1).

    Gradient accumulation (microbatch k>1) is a while loop too, and its body
    REPEATS the FSDP weight gathers k times per step. Probes therefore run at
    ONE microbatch's batch size (B/k) with k forced to 1, and the
    extrapolated cost is scaled by k — this overcounts the (cheap, collective-
    free) optimizer epilogue by (k-1)x, which is recorded in `k_scaled`.
    """
    from repro.models.runtime_flags import probe_mode
    period = 1 if cfg.family == "ssm" else (cfg.attn_layer_period or 1)
    n_scan_full = cfg.num_layers // period
    if n_scan_full < 2:
        return None
    k_used = 1
    if shape.kind == "train":
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if mode == "codist":
            k_used = pick_microbatch(cfg, shape, sizes["data"], codist_n)
        else:
            k_used = pick_microbatch(
                cfg, shape, sizes["data"] * sizes.get("pod", 1))
    probe_shape = shape
    if k_used > 1:
        probe_shape = dataclasses.replace(
            shape, global_batch=shape.global_batch // k_used)
    probes = []
    for i in (1, 2):
        kw = {"num_layers": period * i}
        if cfg.encoder_layers:
            if cfg.num_layers != cfg.encoder_layers:
                return None  # extrapolation needs both loops scaling together
            kw["encoder_layers"] = i
            kw["num_layers"] = i
        cfg_i = replace(cfg, **kw)
        model_i = build_model(cfg_i)
        with probe_mode():
            lowered = _lower_for(model_i, cfg_i, probe_shape, mesh, mode,
                                 codist_n, remat, codist_extra, microbatch=1,
                                 variant=variant)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dpp = mesh.devices.size // sizes.get("pod", 1)
        probes.append(_extract_cost(lowered.compile(), multi_pod, dpp))
    c1, c2 = probes
    out = {}
    for key in c1:
        # deltas are per-layer costs and cannot be negative; tiny negatives
        # are fusion noise between the two probe compiles — clamp.
        delta = max(0.0, c2[key] - c1[key])
        out[key] = (c1[key] + (n_scan_full - 1) * delta) * k_used
    out["n_scan"] = n_scan_full
    out["k_scaled"] = k_used
    out["probe1"] = c1
    out["probe2"] = c2
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, mode: str = "auto",
            codist_n: int = 2, remat: bool = True, verbose: bool = True,
            codist_extra: Optional[Dict] = None,
            variant: Optional[Dict] = None) -> Dict:
    """Lower + compile one combination; returns the result record."""
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_for_shape(dryrun_config(arch), shape_name)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size
    if mode == "auto":
        # the paper's deployment: codistillation for training across pods,
        # plain serving (one model) for inference shapes
        mode = "codist" if (shape.kind == "train" and multi_pod) else (
            "allreduce" if shape.kind == "train" else shape.kind)

    t0 = time.time()
    lowered = _lower_for(model, cfg, shape, mesh, mode, codist_n, remat,
                         codist_extra, variant=variant)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = _cost_dict(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dpp = (chips // sizes["pod"]) if multi_pod else 0
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, devices_per_pod=dpp)

    # correct for XLA's count-scan-body-once cost analysis
    corr = None
    try:
        corr = corrected_cost(arch, cfg, shape, mesh, multi_pod, mode,
                              codist_n, remat, codist_extra, variant)
    except Exception as e:  # pragma: no cover
        print(f"[dryrun] cost extrapolation failed for {arch}: {e}",
              flush=True)
    if corr is not None:
        flops, byts = corr["flops"], corr["bytes"]
        coll_b, cross_b = corr["coll_bytes"], corr["cross_pod_bytes"]
    else:
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        coll_b, cross_b = float(coll.total_bytes), float(coll.cross_pod_bytes)
    report = build_report(arch, shape, mesh_name, chips, flops, byts,
                          coll_b, cross_b,
                          cfg if not hasattr(cfg, "kind") else None,
                          note=mode)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "mode": mode,
        "variant": variant or {}, "codist_extra": codist_extra or {},
        "chips": chips, "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "memory": mem_d,
        "collectives": {"counts": coll.counts(), "bytes_by_kind": coll.by_kind(),
                        "total_bytes": coll.total_bytes,
                        "cross_pod_bytes": coll.cross_pod_bytes,
                        "intra_pod_bytes": coll.intra_pod_bytes},
        "cost_corrected": corr,
        "roofline": report.to_dict(),
        "status": "ok",
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ({mode}): "
              f"compile {t_compile:.1f}s, flops/dev {flops:.3e}, "
              f"coll {coll.total_bytes/1e6:.1f}MB "
              f"(cross-pod {coll.cross_pod_bytes/1e6:.1f}MB), "
              f"bottleneck={report.bottleneck}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "allreduce", "codist"])
    ap.add_argument("--codist-n", type=int, default=2)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--decode-sharding", default="fsdp",
                    choices=["fsdp", "ws", "2d", "repl-batch"])
    ap.add_argument("--moe-experts", default="",
                    help="mesh axis to shard MoE experts over (e.g. data)")
    ap.add_argument("--no-train-fsdp", action="store_true",
                    help="TP-only sharding for non-expert train params")
    ap.add_argument("--compression", default="",
                    choices=["", "none", "topk", "bf16", "subsample"])
    ap.add_argument("--topk", type=int, default=64)
    ap.add_argument("--subsample", type=int, default=0)
    ap.add_argument("--tag", default="", help="suffix for the output file")
    ap.add_argument("--all", action="store_true",
                    help="all archs x shapes for the chosen mesh")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            if (a, s) in SKIP:
                print(f"[dryrun] SKIP {a} x {s} (see DESIGN.md)", flush=True)
                continue
            combos.append((a, s))
    if not args.all and args.arch is None:
        combos = combos[:1]

    multi = args.mesh == "multi"
    variant = {}
    if args.decode_sharding != "fsdp":
        variant["decode_sharding"] = args.decode_sharding
    if args.moe_experts:
        variant["moe_expert_axis"] = args.moe_experts
    if args.no_train_fsdp:
        variant["train_fsdp_axis"] = None
    codist_extra = {}
    if args.compression and args.compression != "none":
        codist_extra["compression"] = args.compression
        if args.compression == "topk":
            codist_extra["topk"] = args.topk
        if args.compression == "subsample":
            codist_extra["subsample"] = args.subsample
    results = []
    suffix = f"_{args.tag}" if args.tag else ""
    out_path = os.path.join(args.out,
                            f"dryrun_{args.mesh}_{args.mode}{suffix}.json")
    # resume support: skip combos already recorded as ok
    done = set()
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"]) for r in results
                if r.get("status") == "ok"}
    for a, s in combos:
        if (a, s) in done:
            print(f"[dryrun] cached {a} x {s}", flush=True)
            continue
        try:
            rec = run_one(a, s, multi, args.mode, args.codist_n,
                          remat=not args.no_remat,
                          codist_extra=codist_extra or None,
                          variant=variant or None)
        except Exception as e:
            rec = {"arch": a, "shape": s, "mesh": args.mesh,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun] FAIL {a} x {s}: {e}", flush=True)
        results = [r for r in results
                   if not (r["arch"] == a and r["shape"] == s)]
        results.append(rec)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1, default=str)
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"[dryrun] {ok}/{len(results)} ok -> {out_path}", flush=True)


if __name__ == "__main__":
    main()
