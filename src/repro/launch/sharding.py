"""Sharding rules: param-name-driven PartitionSpecs with divisibility fallback.

Strategy (Megatron+FSDP hybrid, the v5e-idiomatic default):
  * TP  ("model" axis): attention heads, FFN hidden dim, vocab;
  * FSDP ("data" axis): the d_model dim of every large matrix;
  * scan-over-layers leading axis: never sharded;
  * codistillation: stacked model axis -> "pod".

Any rule that does not divide evenly falls back to replication for that dim
(e.g. 8 KV heads over a 16-way model axis), which is always correct — the
perf hillclimb revisits those choices deliberately.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# name -> spec template applied to the LAST len(template) dims of the leaf.
# Symbols: 'fsdp' -> data axis, 'tp' -> model axis, None -> replicated.
# Entries may be (pattern, template) or (pattern, template, slide) — slide=False
# disables the greedy divisibility fallback (attention head dims: sharding
# head_dim when the head count is indivisible provokes SPMD "involuntary full
# rematerialization"; replication + sequence-parallel scores is cheaper).
_RULES = [
    # embeddings / head
    (r"embed/tokens$", ("tp", "fsdp")),            # (V, d)
    (r"embed/head$", ("fsdp", "tp")),              # (d, V)
    # attention
    (r"(self_attn|cross_attn|attn|mix)/wq$", ("fsdp", "tp", None), False),
    (r"(self_attn|cross_attn|attn|mix)/wk$", ("fsdp", "tp", None), False),
    (r"(self_attn|cross_attn|attn|mix)/wv$", ("fsdp", "tp", None), False),
    (r"(self_attn|cross_attn|attn|mix)/wo$", ("tp", "fsdp")),
    (r"/b[qkv]$", ("tp", None), False),
    # dense ffn (also arctic's residual branch)
    (r"(ffn|residual)/w_gate$", ("fsdp", "tp")),
    (r"(ffn|residual)/w_up$", ("fsdp", "tp")),
    (r"(ffn|residual)/w_down$", ("tp", "fsdp")),
    # moe
    (r"ffn/router$", ("fsdp", None)),              # (d, E)
    (r"ffn/w_gate$", (None, "fsdp", "tp")),        # (E, d, f) — matched after dense
    (r"ffn/w_up$", (None, "fsdp", "tp")),
    (r"ffn/w_down$", (None, "tp", "fsdp")),
    # mamba
    (r"mix/in_proj$", ("fsdp", "tp")),
    (r"mix/conv_w$", (None, "tp")),
    (r"mix/conv_b$", ("tp",)),
    (r"mix/x_proj$", ("tp", None)),
    (r"mix/dt_proj$", (None, "tp")),
    (r"mix/dt_bias$", ("tp",)),
    (r"mix/A_log$", ("tp", None)),
    (r"mix/D$", ("tp",)),
    (r"mix/out_proj$", ("tp", "fsdp")),
    # rwkv time-mix / channel-mix
    (r"mix/w_[rkvg]$", ("fsdp", "tp")),
    (r"mix/w_o$", ("tp", "fsdp")),
    (r"mix/decay_lora_a$", ("fsdp", None)),
    (r"mix/decay_lora_b$", (None, "tp")),
    (r"mix/decay_base$", ("tp",)),
    (r"mix/bonus$", ("tp", None)),
    (r"mix/ln_x_(scale|bias)$", ("tp",)),
    (r"ffn/w_k$", ("fsdp", "tp")),
    (r"ffn/w_v$", ("tp", "fsdp")),
    (r"ffn/w_r$", ("fsdp", "tp")),
    # conv nets: replicate (pure DP — they are small)
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def _axis_sizes(mesh) -> dict:
    # works for both Mesh and AbstractMesh
    return dict(mesh.shape)


def param_spec(path_s: str, shape: Tuple[int, ...], mesh: Mesh,
               stacked: bool = False, scanned: bool = False,
               fsdp_axis: Optional[str] = "data",
               tp_axis: Optional[str] = "model",
               moe_expert_axis: Optional[str] = None,
               two_d_ffn: bool = False) -> P:
    """Resolve the PartitionSpec for one parameter leaf.

    moe_expert_axis: shard the EXPERT axis of stacked MoE weights over this
    mesh axis (expert parallelism — token routing becomes an all-to-all)
    instead of FSDP-sharding inside each expert.
    two_d_ffn: decode-serving scheme — FFN / lm-head / embedding weights get
    2D weight-stationary sharding over ("data","model") (no per-step
    re-gather, 1/(data*model) HBM reads) while attention keeps FSDP+TP."""
    sizes = _axis_sizes(mesh)
    symbols = {"fsdp": fsdp_axis, "tp": tp_axis, "exp": moe_expert_axis}
    if two_d_ffn and re.search(r"(embed/tokens|embed/head|ffn/w_(gate|up|down|k|v|r))$",
                               path_s):
        symbols = {"fsdp": None, "tp": ("data", "model"),
                   "exp": moe_expert_axis}

    template: Tuple = ()
    slide = True
    is_expert = (re.search(r"ffn/w_(gate|up|down)$", path_s)
                 and len(shape) >= 3 + int(stacked) + int(scanned))
    if moe_expert_axis and is_expert:
        # (…, E, d, f) / (…, E, f, d): expert axis + tp on the wide dim
        template = (("exp", None, "tp") if path_s.endswith(("w_gate", "w_up"))
                    else ("exp", "tp", None))
        slide = False
    else:
        for rule in _RULES:
            pat, tmpl = rule[0], rule[1]
            if re.search(pat, path_s):
                template = tmpl
                slide = rule[2] if len(rule) > 2 else True
                break

    ndim = len(shape)
    spec: list = [None] * ndim
    lead = 0
    if stacked:
        if "pod" in sizes and shape[0] == sizes["pod"]:
            spec[0] = "pod"
        lead += 1
    if scanned:
        lead += 1  # scan axis never sharded
    # apply template to the trailing dims, with greedy fallback: if the
    # intended dim is not divisible (e.g. 28 heads over a 16-way model axis),
    # slide right to the next free divisible dim (e.g. head_dim=128).
    def axis_ways(axis) -> int:
        if isinstance(axis, tuple):
            if not all(a in sizes for a in axis):
                return 0
            n = 1
            for a in axis:
                n *= sizes[a]
            return n
        return sizes.get(axis, 0)

    t = list(template)[-max(0, ndim - lead):] if template else []
    off = ndim - len(t)
    for i, sym in enumerate(t):
        if sym is None:
            continue
        axis = symbols.get(sym)
        ways = axis_ways(axis) if axis else 0
        if not ways:
            continue
        hi = ndim if slide else min(off + i + 1, ndim)
        for dim in range(max(off + i, lead), hi):
            if spec[dim] is None and shape[dim] % ways == 0 \
                    and shape[dim] >= ways:
                spec[dim] = axis
                break
    return P(*spec)


_SCAN_SUBTREES = ("layers", "enc_layers", "dec_layers")


def params_shardings(params_shapes: PyTree, mesh: Mesh, stacked: bool = False,
                     fsdp_axis: Optional[str] = "data",
                     tp_axis: Optional[str] = "model") -> PyTree:
    """NamedSharding tree for a (possibly stacked) parameter pytree of
    ShapeDtypeStructs."""
    def one(path, leaf):
        ps = _path_str(path)
        scanned = any(f"{s}/" in ps or ps.startswith(f"{s}/")
                      for s in _SCAN_SUBTREES)
        spec = param_spec(ps, leaf.shape, mesh, stacked, scanned,
                          fsdp_axis, tp_axis)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def optstate_shardings(opt_shapes: PyTree, param_shardings: PyTree,
                       mesh: Mesh) -> PyTree:
    """Optimizer moments mirror the param shardings; scalars replicate."""
    flat_p = {_path_str(p): s for p, s in
              jax.tree_util.tree_flatten_with_path(param_shardings)[0]}

    def one(path, leaf):
        ps = _path_str(path)
        # OptState fields are ('step', 'm', 'v'); strip the field prefix
        for field in ("m/", "v/"):
            if ps.startswith(field) and ps[len(field):] in flat_p:
                return flat_p[ps[len(field):]]
        m = re.match(r"^\d+/(m|v)/(.*)$", ps)
        if m and m.group(2) in flat_p:
            return flat_p[m.group(2)]
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, opt_shapes)


def batch_shardings(batch_shapes: PyTree, mesh: Mesh,
                    stacked: bool = False, microbatched: bool = False,
                    shard_seq_when_b1: bool = False) -> PyTree:
    """Batch arrays: the batch dim shards over (pod+)data — pod only when not
    stacked (baseline DP spans pods; codist batches stack over pod). The
    optional microbatch axis (grad accumulation) is never sharded. With
    global_batch=1 (long_500k) the *sequence* axis shards instead (context
    parallelism for the cache read)."""
    sizes = _axis_sizes(mesh)
    has_pod = "pod" in sizes

    def one(leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        i = 0
        if stacked:
            if has_pod and shape[0] == sizes["pod"]:
                spec[0] = "pod"
            i = 1
        if microbatched:
            i += 1
        if len(shape) > i:
            batch_axes = []
            b = shape[i]
            if not stacked and has_pod and b % (sizes["pod"] * sizes["data"]) == 0:
                batch_axes = ["pod", "data"]
            elif b % sizes["data"] == 0:
                batch_axes = ["data"]
            if batch_axes:
                spec[i] = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]
            elif shard_seq_when_b1 and len(shape) > i + 1 and \
                    shape[i + 1] % sizes["data"] == 0:
                spec[i + 1] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shapes)


def cache_shardings(cache_shapes: PyTree, mesh: Mesh, batch: int,
                    prefer_time: bool = False) -> PyTree:
    """KV caches / SSM states: (L, B, T, kv, hd)-style leaves.

    B shards over "data" when divisible; for B==1 (long_500k) — or with
    ``prefer_time`` (batch-replicated decode) — the time axis shards over
    "data" (sequence/context parallelism) and head-like axes take "model"
    when divisible.
    """
    sizes = _axis_sizes(mesh)

    def one(leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        used = set()
        # axis 0 is the scan/layer axis -> never sharded; axis 1 is batch
        if not prefer_time and len(shape) >= 2 \
                and shape[1] % sizes["data"] == 0 and shape[1] > 1:
            spec[1] = "data"
            used.add("data")
        # remaining large axes: prefer time over "data" (if free), heads over "model"
        for dim in range(2, len(shape)):
            if "data" not in used and shape[dim] % sizes["data"] == 0 \
                    and shape[dim] >= sizes["data"] and dim == 2:
                spec[dim] = "data"
                used.add("data")
            elif "model" not in used and shape[dim] % sizes["model"] == 0 \
                    and shape[dim] >= sizes["model"]:
                spec[dim] = "model"
                used.add("model")
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_shapes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def state_shardings(state_shapes: PyTree, mesh: Mesh,
                    stacked: bool = False,
                    fsdp_axis: Optional[str] = "data",
                    tp_axis: Optional[str] = "model",
                    moe_expert_axis: Optional[str] = None,
                    two_d_ffn: bool = False) -> PyTree:
    """Shardings for a whole TrainState/CodistState pytree of
    ShapeDtypeStructs. Optimizer moments and stale replicas mirror the param
    rules automatically because their key paths end with the same leaf names.
    Scalars replicate."""
    def one(path, leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return replicated(mesh)
        ps = _path_str(path)
        scanned = any(f"{s}/" in ps for s in _SCAN_SUBTREES)
        spec = param_spec(ps, leaf.shape, mesh, stacked, scanned,
                          fsdp_axis, tp_axis, moe_expert_axis, two_d_ffn)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, state_shapes)
