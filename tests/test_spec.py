"""Peer-speculative decoding tests: the temperature-0 exactness invariant
(speculative streams bit-identical to plain decode, whatever the draft
proposes), KV rollback bit-identity across cache dtypes and mid-stream
churn, the k-token verify step vs sequential decode, chaos fallback, the
simulated-cost speedup, and the report/stats surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from dataclasses import replace

from repro.configs import get_reduced
from repro.models import build_model
from repro.runtime import FaultConfig
from repro.serve.fleet import (ChaosConfig, FleetConfig, FleetDefense,
                               FleetRouter, Request, SpecConfig, SpecEngine,
                               generate_workload)


def _tiny_cfg():
    return replace(get_reduced("qwen1.5-0.5b"), num_layers=2, d_model=64,
                   d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=2,
                   head_dim=32)


def _requests(cfg, lens, max_new=6, gap_ms=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, i * gap_ms,
                    tuple(int(x) for x in rng.integers(0, cfg.padded_vocab,
                                                       size=l)),
                    max_new)
            for i, l in enumerate(lens)]


class _ListWorkload:
    def __init__(self, requests, scenario="custom", seed=0):
        self.requests = requests
        self.scenario = scenario
        self.seed = seed


def _noised(params, scale, seed=42):
    """Deterministically perturbed copy: a 'student' draft that agrees with
    the target on SOME argmaxes (partial accepts) but not all."""
    leaves, treedef = jax.tree.flatten(params)
    key = jax.random.key(seed)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        out.append(leaf + scale * jax.random.normal(k, leaf.shape,
                                                    leaf.dtype))
    return jax.tree.unflatten(treedef, out)


_FC = dict(max_slots=2, block_size=4, num_blocks=32, max_blocks_per_slot=8,
           max_prefills_per_step=1)


# ----------------------------------------------------------------------------
# the exactness invariant: speculative == plain at temperature 0
# ----------------------------------------------------------------------------

def test_spec_bit_identical_identical_peers():
    """Ring-paired identical peers (the converged-codistillation limit):
    every draft accepted, stream digest identical to plain decode."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _requests(cfg, [5, 9, 12, 7, 5, 9, 12, 7])
    fc = FleetConfig(**_FC)
    plain = FleetRouter(model, [params, params], config=fc).run(
        _ListWorkload(list(reqs)))
    spec = FleetRouter(model, [params, params], config=fc,
                       policy="speculative", spec=SpecConfig(k=4)).run(
        _ListWorkload(list(reqs)))
    assert spec.completed == len(reqs)
    assert spec.stream_digest == plain.stream_digest
    assert spec.spec_accept_rate == 1.0
    assert spec.spec_rounds > 0
    assert spec.spec_fallback_ticks == 0
    assert spec.spec_accepted_tokens == spec.spec_drafted_tokens > 0


def test_spec_bit_identical_under_rejection():
    """A disagreeing draft changes NOTHING about the output: the target
    resamples every divergence from its own verify logits. Partial accepts
    (0 < rate < 1) prove both branches of accept/reject ran."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _requests(cfg, [5, 9, 12, 7, 5, 9, 12, 7])
    fc = FleetConfig(**_FC)
    plain = FleetRouter(model, [params], config=fc).run(
        _ListWorkload(list(reqs)))
    spec = FleetRouter(model, [params], config=fc, policy="speculative",
                       spec=SpecConfig(k=4), draft_model=model,
                       draft_params=_noised(params, 1e-3)).run(
        _ListWorkload(list(reqs)))
    assert spec.stream_digest == plain.stream_digest
    assert 0.0 < spec.spec_accept_rate < 1.0


def test_spec_seeded_determinism():
    """Two identical speculative runs produce byte-identical reports."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _requests(cfg, [5, 9, 12, 7])
    fc = FleetConfig(**_FC)

    def go():
        return FleetRouter(model, [params, params], config=fc,
                           policy="speculative", spec=SpecConfig(k=3)).run(
            _ListWorkload(list(reqs))).to_json()

    assert go() == go()


# ----------------------------------------------------------------------------
# KV rollback: pools bit-identical to a never-drafted run
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("cache_dtype", [jnp.float32, jnp.int8,
                                         jnp.float8_e4m3fn])
def test_spec_rollback_pool_bit_identity(cache_dtype):
    """After a run full of rejected drafts and mid-stream churn (two waves
    reusing the same blocks), the target pool — K/V bits, quantization
    scales, table, lengths, free list — matches a never-drafted run's
    exactly. Freed blocks keep residual rows from earlier occupants, so
    rollback must restore PRIOR CONTENT, not zeros; wave 2's rejections
    overwrite-and-restore wave 1's residue, which is what this pins."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    # two waves far apart: both runs drain wave 1 (same allocate/free
    # sequence) before wave 2 reuses its freed blocks
    wave1 = _requests(cfg, [5, 9], gap_ms=0.0)
    wave2 = [Request(10 + i, 1000.0 + i * 0.0, r.prompt, r.max_new)
             for i, r in enumerate(_requests(cfg, [12, 7], seed=3))]
    reqs = wave1 + wave2
    fc = FleetConfig(**_FC)

    def pool_state(router):
        pool = router.engines[0].pool
        leaves = jax.tree.leaves(pool.kv)
        return (pool.table.copy(), pool.lengths.copy(),
                [list(b) for b in pool.slot_blocks], list(pool.free),
                [np.asarray(x) for x in leaves])

    plain = FleetRouter(model, [params], config=fc, cache_dtype=cache_dtype)
    rp = plain.run(_ListWorkload(list(reqs)))
    spec = FleetRouter(model, [params], config=fc, cache_dtype=cache_dtype,
                       policy="speculative", spec=SpecConfig(k=4),
                       draft_model=model, draft_params=_noised(params, 1e-2))
    rs = spec.run(_ListWorkload(list(reqs)))
    assert rs.stream_digest == rp.stream_digest
    assert rs.spec_accept_rate < 1.0      # rejections actually happened

    pt, pl, pb, pf, pleaves = pool_state(plain)
    st, slens, sb, sf, sleaves = pool_state(spec)
    np.testing.assert_array_equal(pt, st)
    np.testing.assert_array_equal(pl, slens)
    assert pb == sb and pf == sf
    for a, b in zip(pleaves, sleaves):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()    # bit-identity, any dtype


def test_snapshot_restore_roundtrip():
    """Pool-level undo log: overwrite rows, restore a suffix, bits match."""
    from repro.serve.fleet.cache import PagedCachePool
    cfg = _tiny_cfg()
    model = build_model(cfg)
    pool = PagedCachePool(model, max_slots=2, block_size=4, num_blocks=16,
                          max_blocks_per_slot=4, cache_dtype=jnp.int8)
    pool.allocate(0, 10)
    pool.lengths[0] = 3
    before = [np.asarray(x).copy() for x in jax.tree.leaves(pool.kv)]
    snap = pool.snapshot_rows(0, 3, 4)
    # clobber the snapshot window via the writer maps
    wslots, woffs = pool.write_maps_k(np.array([True, False]), 4)
    for j in range(4):
        blk = int(np.nonzero(wslots[j] >= 0)[0][0])
        off = int(woffs[j][blk])
        for sub in pool.kv.values():
            for name in sub:
                sub[name] = sub[name].at[:, blk, off].set(1)
    changed = any(not np.array_equal(a, np.asarray(b)) for a, b in
                  zip(before, jax.tree.leaves(pool.kv)))
    assert changed
    pool.restore_rows(snap, start=0)
    for a, b in zip(before, jax.tree.leaves(pool.kv)):
        np.testing.assert_array_equal(a, np.asarray(b))


# ----------------------------------------------------------------------------
# the verify step: one batched k-token forward == k sequential decodes
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [True, False])
def test_verify_step_matches_sequential_decode(fused):
    """build_verify_step's position-j logits equal the j'th plain decode's
    (argmax-identical; numerically tight), and it leaves the same pool."""
    from repro.serve.fleet.cache import PagedCachePool
    from repro.serve.fleet.model_exec import (build_decode_step,
                                              build_verify_step)
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    k = 3
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.padded_vocab, size=n))
               for n in (5, 9)]
    toks = rng.integers(0, cfg.padded_vocab, size=(2, k)).astype(np.int32)

    def fresh_pool():
        pool = PagedCachePool(model, max_slots=2, block_size=4,
                              num_blocks=16, max_blocks_per_slot=4,
                              cache_dtype=jnp.float32)
        for s, p in enumerate(prompts):
            pool.allocate(s, len(p) + k + 1)
            t = jnp.asarray(p, jnp.int32)[None, :]
            _, cache = model.prefill(params, {"tokens": t}, len(p),
                                     cache_dtype=jnp.float32)
            pool.insert_prefill(s, cache, len(p))
        return pool

    # sequential reference: k plain decode steps
    pool = fresh_pool()
    decode = build_decode_step(model, fused_attention=fused)
    seq_logits = []
    for j in range(k):
        wslot, woff = pool.write_maps(np.ones(2, bool))
        lg, kv, st = decode(params, pool.kv, pool.states,
                            jnp.asarray(pool.table),
                            jnp.asarray(pool.lengths), jnp.asarray(wslot),
                            jnp.asarray(woff), jnp.asarray(toks[:, j:j + 1]))
        pool.kv, pool.states = kv, st
        pool.lengths += 1
        seq_logits.append(np.asarray(lg))
    seq_leaves = [np.asarray(x) for x in jax.tree.leaves(pool.kv)]

    # one batched verify over the same k tokens
    pool2 = fresh_pool()
    verify = build_verify_step(model, k, fused_attention=fused)
    wslots, woffs = pool2.write_maps_k(np.ones(2, bool), k)
    vlg, kv, st = verify(params, pool2.kv, pool2.states,
                         jnp.asarray(pool2.table),
                         jnp.asarray(pool2.lengths), jnp.asarray(wslots),
                         jnp.asarray(woffs), jnp.asarray(toks))
    vlg = np.asarray(vlg)
    for j in range(k):
        np.testing.assert_array_equal(vlg[:, j].argmax(-1),
                                      seq_logits[j].argmax(-1))
        np.testing.assert_allclose(vlg[:, j], seq_logits[j], atol=2e-4)
    for a, b in zip(seq_leaves, jax.tree.leaves(kv)):
        np.testing.assert_allclose(a, np.asarray(b), atol=1e-5)


def test_verify_rejects_recurrent_models():
    from repro.serve.fleet.model_exec import build_verify_step
    cfg = get_reduced("rwkv6-1.6b")
    model = build_model(cfg)
    with pytest.raises(ValueError, match="attention-only"):
        build_verify_step(model, 4)


# ----------------------------------------------------------------------------
# chaos: health-aware pairing falls back to plain decode
# ----------------------------------------------------------------------------

def test_spec_fallback_when_draft_peer_offline():
    """Preempting the draft partner mid-run forces plain-decode fallback
    ticks; every request still completes with at-most-once emission."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    wl = generate_workload("steady", 12, cfg.padded_vocab, seed=5,
                           max_prompt=12, max_new=6)
    fc = FleetConfig(**_FC)
    chaos = ChaosConfig(FaultConfig(n_peers=2, seed=5,
                                    preemptions=((1, 6, 120.0),)))
    rep = FleetRouter(model, [params, params], config=fc,
                      policy="speculative", spec=SpecConfig(k=4),
                      chaos=chaos, defense=FleetDefense()).run(wl)
    assert rep.preemptions >= 1
    assert rep.spec_fallback_ticks >= 1
    assert rep.spec_rounds >= 1           # speculation resumed after drains
    assert rep.completed == 12
    assert rep.lost_tokens == 0 and rep.duplicated_tokens == 0


def test_spec_dedicated_draft_peer_excluded_from_serving():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _requests(cfg, [5, 9, 12, 7])
    fc = FleetConfig(**_FC)
    router = FleetRouter(model, [params, params, params], config=fc,
                         policy="speculative",
                         spec=SpecConfig(k=2, draft_peer=1))
    rep = router.run(_ListWorkload(list(reqs)))
    assert rep.completed == len(reqs)
    drafter = router.engines[1]
    assert not isinstance(drafter, SpecEngine)
    assert not drafter.records             # never served a request
    assert all(isinstance(router.engines[i], SpecEngine) for i in (0, 2))


def test_spec_requires_two_peers_for_ring():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    with pytest.raises(ValueError, match="2 peers"):
        FleetRouter(model, [params], policy="speculative")


# ----------------------------------------------------------------------------
# the point of it all: simulated speedup in the service-bound regime
# ----------------------------------------------------------------------------

def test_spec_simulated_speedup():
    """k=4 full-accept speculation beats plain decode by >1.5x simulated
    tokens/sec in the service-bound regime (the benchmarks/serving.py
    acceptance cell, pinned here at test scale)."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    wl = generate_workload("steady", 16, cfg.padded_vocab, seed=7,
                           max_prompt=8, max_new=16)
    # compress arrivals + fix output lengths: decode-dominated saturation
    reqs = [Request(r.rid, r.arrival_ms * 0.02, r.prompt, 16)
            for r in wl.requests]
    fc = FleetConfig(max_slots=4, block_size=4, num_blocks=64,
                     max_blocks_per_slot=8)
    plain = FleetRouter(model, [params, params], config=fc).run(
        _ListWorkload(list(reqs), scenario="steady", seed=7))
    spec = FleetRouter(model, [params, params], config=fc,
                       policy="speculative", spec=SpecConfig(k=4)).run(
        _ListWorkload(list(reqs), scenario="steady", seed=7))
    assert spec.stream_digest == plain.stream_digest
    speedup = spec.sim_tokens_per_s / plain.sim_tokens_per_s
    assert speedup > 1.5, speedup
