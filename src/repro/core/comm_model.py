"""Section-3 analytical communication model.

All quantities are BITS PER ITERATION PER DEVICE over the *expensive* links
(inter-server in the paper; inter-pod here — intra-group communication is not
counted, exactly as Figure 1 only counts inter-server bytes).

  all_reduce (ring/tree):      C_AR   = 2 * b_model
  codist, checkpoints every T: C_ckpt = (n-1) * b_model / T
  codist, predictions every T: C_pred = (n-1) * b_pred * B / T

where b_model = bits of one parameter vector, b_pred = bits of the predictions
for ONE sample, B = per-device batch size (the paper's accounting) — for LM
workloads one "sample" is a sequence, so b_pred = seq_len * vocab * bits.

The paper's headline: ResNet50 (b_model = 8e8 bits, b_pred = 3.2e4 bits,
B = 256) => predictions every 5 iterations communicates ~1000x fewer bits than
all_reduce. ``test_comm_model.py`` asserts these exact numbers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import CodistConfig, ModelConfig


@dataclass(frozen=True)
class CommCost:
    bits_per_iter_per_device: float
    scheme: str

    def ratio_vs(self, other: "CommCost") -> float:
        """How many times fewer bits this scheme communicates vs `other`."""
        if self.bits_per_iter_per_device == 0:
            return float("inf")
        return other.bits_per_iter_per_device / self.bits_per_iter_per_device


def allreduce_bits(b_model: float) -> CommCost:
    """Optimized ring/tree all_reduce: each device sends+receives ~2x the model."""
    return CommCost(2.0 * b_model, "all_reduce")


def codist_checkpoint_bits(b_model: float, n: int, period: int) -> CommCost:
    return CommCost((n - 1) * b_model / period, f"codist_ckpt_T{period}")


def codist_prediction_bits(b_pred: float, batch: int, n: int, period: int) -> CommCost:
    return CommCost((n - 1) * b_pred * batch / period, f"codist_pred_T{period}")


def bits_per_exchange_event(scheme: str, n: int, b_model: float = 0.0,
                            b_pred: float = 0.0, batch: int = 1) -> float:
    """Bits crossing the slow links for ONE exchange event.

    This is the event-based view the async runtime meters: one event is one
    peer's exchange step, in which it receives the (n-1) other replicas'
    payloads — predictions (``b_pred`` bits per sample, ``batch`` samples)
    or parameters (``b_model``); all_reduce's event is the per-step gradient
    ring (~2x the model per device). The per-iteration quantities above are
    this divided by the exchange period, and
    ``tests/test_comm_model.py`` asserts the mailbox-metered bytes of an
    ``AsyncScheduler`` run agree with this formula exactly.
    """
    if scheme in ("all_reduce", "allreduce"):
        return 2.0 * b_model
    if scheme in ("predictions", "prediction"):
        return (n - 1) * b_pred * batch
    if scheme in ("checkpoints", "checkpoint"):
        return (n - 1) * b_model
    raise ValueError(f"unknown scheme {scheme!r}")


# ----------------------------------------------------------------------------
# model-aware helpers
# ----------------------------------------------------------------------------

def model_bits(cfg: ModelConfig, param_bits: int = 32) -> float:
    return cfg.param_count() * param_bits


def param_bits_of(params) -> float:
    """b_model measured from a LIVE parameter pytree (actual dtypes), so
    consumers that move whole replicas — the runtime's checkpoint-mode
    mailbox and the serving fleet's weight refresh — bill bytes through one
    ledger and stay directly comparable."""
    import jax

    return float(sum(x.size * x.dtype.itemsize * 8
                     for x in jax.tree_util.tree_leaves(params)))


def prediction_bits_classifier(num_classes: int, logit_bits: int = 32) -> float:
    """b_pred for a classifier: one logit vector per sample."""
    return num_classes * logit_bits


def prediction_bits_lm(cfg: ModelConfig, seq_len: int, logit_bits: int = 32,
                       compression: str = "none", topk: int = 64,
                       subsample: int = 0) -> float:
    """b_pred for an LM 'sample' (= one sequence of logits), with the
    beyond-paper compression options accounted for."""
    v = cfg.padded_vocab
    tokens = subsample if (compression == "subsample" and subsample) else seq_len
    if compression == "topk":
        # topk values (logit_bits) + topk int32 indices per token
        per_token = topk * (logit_bits + 32)
    elif compression == "bf16":
        per_token = v * 16
    else:
        per_token = v * logit_bits
    return tokens * per_token


def codist_cost(cfg: ModelConfig, codist: CodistConfig, per_device_batch: int,
                seq_len: Optional[int] = None, param_bits: int = 32,
                logit_bits: int = 32) -> CommCost:
    """Bits/iter/device over cross-group links for a CodistConfig."""
    n, T = codist.n_models, codist.period
    if codist.mode == "checkpoints":
        return codist_checkpoint_bits(model_bits(cfg, param_bits), n, T)
    if seq_len is None:
        b_pred = prediction_bits_classifier(cfg.vocab_size, logit_bits)
    else:
        b_pred = prediction_bits_lm(cfg, seq_len, logit_bits,
                                    codist.compression, codist.topk,
                                    codist.subsample)
    return codist_prediction_bits(b_pred, per_device_batch, n, T)


def paper_resnet50_numbers() -> dict:
    """The exact Section-3 worked example, used as a regression anchor."""
    b_model = 8e8          # "ResNet50 ... will have b_model = 8e8 bits"
    b_pred = 3.2e4         # 1000 classes * 32 bits
    B = 256                # per-model batch size in Fig. 1
    ar = allreduce_bits(b_model)
    out = {"all_reduce": ar.bits_per_iter_per_device}
    for T in (1, 5, 10, 100):
        c = codist_prediction_bits(b_pred, B, n=2, period=T)
        out[f"pred_T{T}"] = c.bits_per_iter_per_device
        out[f"pred_T{T}_ratio"] = c.ratio_vs(ar)
    for T in (625, 1250, 2500, 5000):
        c = codist_checkpoint_bits(b_model, n=2, period=T)
        out[f"ckpt_T{T}"] = c.bits_per_iter_per_device
        out[f"ckpt_T{T}_ratio"] = c.ratio_vs(ar)
    return out
