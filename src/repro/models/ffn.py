"""Dense feed-forward blocks: SwiGLU (llama-family) and plain 2-layer MLP."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, activation, dense_init


def init_ffn(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None,
             dtype=jnp.float32) -> Dict[str, jax.Array]:
    kg = KeyGen(key)
    d = cfg.d_model
    dff = d_ff or cfg.d_ff
    if cfg.act in ("silu", "geglu"):  # SwiGLU / GeGLU: gate, up, down
        return {
            "w_gate": dense_init(kg(), d, (dff,), dtype),
            "w_up": dense_init(kg(), d, (dff,), dtype),
            "w_down": dense_init(kg(), dff, (d,), dtype,
                                 scale=1.0 / max(1, cfg.num_layers) ** 0.5),
        }
    return {
        "w_up": dense_init(kg(), d, (dff,), dtype),
        "w_down": dense_init(kg(), dff, (d,), dtype,
                             scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def ffn_forward(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = activation(cfg.act if cfg.act not in ("relu",) else "gelu")
    if "w_gate" in p:
        h = act(jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    else:
        h = act(jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype)))
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))
