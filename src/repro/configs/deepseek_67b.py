"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.configs.base import ModelConfig, reduced as _reduced

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    qkv_bias=False,
    act="silu",
    source="DeepSeek LLM 67B [arXiv:2401.02954]",
)


def reduced():
    return _reduced(CONFIG)
