#!/usr/bin/env python3
"""CI benchmark regression gate: diff a fresh benchmark run against the
committed baseline.

    python tools/bench_compare.py --baseline BENCH_throughput.json \\
        --new bench_ci.json [--tolerance 0.25]

Both files are ``benchmarks.run --json`` documents. Rows are matched by
their ``name`` (``<benchmark>/<variant>``); only benchmarks present in
the new run are gated, so a baseline regenerated from the full suite
still gates a CI run of ``--only throughput`` — but within a benchmark
the new run DID execute, every baseline row must reappear (a variant that
stops being emitted, or is renamed, would otherwise vacate its gates
silently). Per matched row:

* **throughput** — ``us_per_call`` may grow by at most ``--tolerance``
  (default 0.25 = 25%); rows timed at 0 on either side (skipped /
  unmeasured, e.g. shardmap without enough devices) are not timing-gated,
  and neither are rows whose BASELINE time is under ``--min-us``
  (microsecond-scale interpret-mode kernel timings swing several-fold
  run-to-run even on one machine — they are informational, not gateable);
* **comm_bytes** — the ``comm_bytes=N`` field inside ``derived`` must
  match EXACTLY: communication volume is deterministic accounting, and a
  silent change is a correctness bug, not noise.

Exit codes: 0 clean, 1 regression(s) (a readable table says which), 2
usage error (missing/empty files, no comparable rows). To bless a new
baseline after an intentional change, regenerate it and commit:

    PYTHONPATH=src python -m benchmarks.run \\
        --only throughput,fault,sweep_smoke,serving,serving_chaos \\
        --quick --json BENCH_throughput.json

(see docs/experiments.md for when a re-bless is legitimate). This script
is stdlib-only on purpose — it must run before any project deps install.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Optional

COMM_RE = re.compile(r"comm_bytes=([0-9]+(?:\.[0-9]+)?)")


def load_rows(path: str) -> Dict[str, Dict]:
    """name -> {"us": float, "comm": float|None} from a --json document."""
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for r in doc.get("rows", []):
        m = COMM_RE.search(str(r.get("derived", "")))
        rows[r["name"]] = {
            "us": float(r.get("us_per_call", 0.0)),
            "comm": float(m.group(1)) if m else None,
        }
    return rows


def compare(base: Dict[str, Dict], new: Dict[str, Dict],
            tolerance: float, min_us: float = 0.0) -> List[Dict]:
    """One result record per matched row, plus a REGRESSED record for
    every baseline row of an executed benchmark that vanished from the
    new run (matching on the ``<benchmark>/`` name prefix)."""
    out = []
    ran_prefixes = {n.split("/", 1)[0] for n in new}
    for name in sorted(set(base) - set(new)):
        if name.split("/", 1)[0] in ran_prefixes:
            out.append({"name": name, "base_us": base[name]["us"],
                        "new_us": 0.0, "base_comm": base[name]["comm"],
                        "new_comm": None, "ratio": None,
                        "status": "REGRESSED",
                        "why": "row missing from the new run"})
    for name in sorted(set(base) & set(new)):
        b, n = base[name], new[name]
        rec = {"name": name, "base_us": b["us"], "new_us": n["us"],
               "base_comm": b["comm"], "new_comm": n["comm"],
               "ratio": None, "status": "OK", "why": ""}
        if (b["comm"] is None) != (n["comm"] is None):
            # a row gaining or LOSING its comm accounting is a semantic
            # change, not noise — e.g. a crashed sweep cell emitting '-'
            # must not sail through as "nothing to compare"
            rec["status"] = "REGRESSED"
            side = "new" if n["comm"] is None else "baseline"
            rec["why"] = f"comm_bytes missing on the {side} side"
        elif b["comm"] is not None and b["comm"] != n["comm"]:
            rec["status"] = "REGRESSED"
            rec["why"] = (f"comm_bytes {b['comm']:.0f} -> {n['comm']:.0f} "
                          "(must match exactly)")
        if b["us"] > 0 and n["us"] > 0:
            rec["ratio"] = n["us"] / b["us"]
            if rec["status"] == "OK" and b["us"] < min_us:
                rec["status"] = "SKIP"
                rec["why"] = f"baseline under --min-us {min_us:.0f}"
            elif rec["status"] == "OK" and rec["ratio"] > 1.0 + tolerance:
                rec["status"] = "REGRESSED"
                rec["why"] = (f"{rec['ratio']:.2f}x slower "
                              f"(tolerance {1.0 + tolerance:.2f}x)")
        elif rec["status"] == "OK":
            rec["status"] = "SKIP"
            rec["why"] = "unmeasured timing on one side"
        out.append(rec)
    return sorted(out, key=lambda r: r["name"])


def render(records: List[Dict]) -> str:
    headers = ("row", "base us", "new us", "ratio", "comm", "status")
    lines = []
    for r in records:
        comm = ("-" if r["base_comm"] is None
                else ("=" if r["base_comm"] == r["new_comm"] else "DIFF"))
        lines.append((r["name"], f"{r['base_us']:.1f}", f"{r['new_us']:.1f}",
                      "-" if r["ratio"] is None else f"{r['ratio']:.2f}x",
                      comm,
                      r["status"] + (f"  {r['why']}" if r["why"] else "")))
    widths = [max(len(h), *(len(l[i]) for l in lines)) if lines else len(h)
              for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    return "\n".join([fmt.format(*headers),
                      fmt.format(*("-" * w for w in widths))]
                     + [fmt.format(*l) for l in lines])


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate CI benchmark results against the committed "
                    "baseline (see module docstring).")
    ap.add_argument("--baseline", default="BENCH_throughput.json")
    ap.add_argument("--new", default="bench_ci.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional us_per_call growth "
                         "(default 0.25 = 25%%)")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="skip the timing gate for rows whose baseline "
                         "us_per_call is below this (noise floor)")
    args = ap.parse_args(argv)

    try:
        base = load_rows(args.baseline)
        new = load_rows(args.new)
    except (OSError, json.JSONDecodeError, KeyError, ValueError) as e:
        print(f"bench_compare: cannot load inputs: {e}", file=sys.stderr)
        return 2
    records = compare(base, new, args.tolerance, args.min_us)
    if not records:
        print("bench_compare: no comparable rows between "
              f"{args.baseline} ({len(base)} rows) and "
              f"{args.new} ({len(new)} rows)", file=sys.stderr)
        return 2

    print(render(records))
    regressed = [r for r in records if r["status"] == "REGRESSED"]
    missing = sorted(set(new) - set(base))
    if missing:
        print(f"\nnote: {len(missing)} new row(s) not in the baseline "
              f"(not gated): {', '.join(missing[:8])}"
              + ("..." if len(missing) > 8 else ""))
    if regressed:
        print(f"\nFAIL: {len(regressed)}/{len(records)} row(s) regressed "
              f"(tolerance {args.tolerance:.0%} on timing, exact on "
              "comm_bytes).")
        print("If the change is intentional, bless a new baseline:\n"
              "    PYTHONPATH=src python -m benchmarks.run "
              "--only throughput,fault,sweep_smoke,serving,serving_chaos "
              "--quick --json BENCH_throughput.json")
        return 1
    print(f"\nOK: {len(records)} row(s) within tolerance "
          f"({args.tolerance:.0%} timing, exact comm_bytes).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
