"""Step functions: all_reduce baseline, codistillation (prediction /
checkpoint / pipelined), and eval — all pure and pjit-compatible.

All schedules (LR, weight decay, label smoothing, alpha) are evaluated
*inside* the step from ``state.step`` so one compiled step serves the whole
run. Variants with/without the distillation term are separate compiled
functions selected by the host loop via ``StepPlan`` (Section 3's "only
periodically communicate predictions, and omit the distillation term
otherwise").

The stacked-model representation makes the optimizer trivially per-model:
SGD/Adam are elementwise pytree transforms, so applying them to stacked
params IS n independent optimizer updates.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CodistConfig, TrainConfig
from repro.core import codistillation as cd
from repro.core import schedules as sched
from repro.optim import make_optimizer
from repro.train.state import CodistState, TrainState

PyTree = Any


# ----------------------------------------------------------------------------
# schedule bundles
# ----------------------------------------------------------------------------

def make_schedules(tc: TrainConfig, codist: Optional[CodistConfig] = None):
    lr_fn = sched.make_lr_fn(tc.lr_schedule, tc.lr, tc.total_steps,
                             tc.warmup_steps, tc.step_milestones, tc.step_decay)
    if tc.weight_decay_schedule:
        values = tuple(tc.weight_decay_schedule)
        miles = tc.step_milestones[: len(values) - 1]
        wd_fn = lambda s: sched.scheduled_weight_decay(s, tc.total_steps,
                                                       values, miles)
    else:
        wd_fn = lambda s: sched.constant_weight_decay(s, tc.weight_decay)
    if tc.label_smoothing_decay:
        ls_fn = lambda s: sched.decayed_label_smoothing(s, tc.total_steps,
                                                        tc.label_smoothing)
    else:
        ls_fn = lambda s: jnp.asarray(tc.label_smoothing, jnp.float32)
    if codist is not None:
        alpha_fn = lambda s: sched.alpha_schedule(
            s, codist.alpha0, codist.alpha_growth, codist.steps_per_epoch,
            codist.burn_in_steps)
    else:
        alpha_fn = lambda s: jnp.zeros((), jnp.float32)
    return lr_fn, wd_fn, ls_fn, alpha_fn


def _task_forward(model, params: PyTree, batch: Dict, remat: bool):
    """Unified forward over LM / enc-dec / conv models."""
    if hasattr(model.cfg, "kind"):  # ConvConfig
        return model.forward(params, batch)
    return model.forward(params, batch, remat=remat)


def _grads_with_metrics(loss_fn, params: PyTree, batch: Dict, k: int,
                        accum_dtype=jnp.float32):
    """Gradients of ``loss_fn(params, batch) -> (loss, metrics)``.

    k>1 enables microbatched gradient accumulation: every batch leaf carries a
    leading (k, B/k, ...) axis and a lax.scan accumulates fp32 grads — the
    production memory lever for the biggest configs (per-layer activations
    saved for backward scale with B/k, not B).
    """
    if k <= 1:
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    m_shape = jax.eval_shape(
        lambda p, b: loss_fn(p, b)[1], params,
        jax.tree.map(lambda x: x[0], batch))
    m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shape)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)

    def body(carry, mb):
        g_acc, m_acc = carry
        (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, gg: a + gg.astype(accum_dtype) / k,
                             g_acc, g)
        m_acc = jax.tree.map(lambda a, mm: a + mm / k, m_acc, m)
        return (g_acc, m_acc), None

    (grads, metrics), _ = jax.lax.scan(body, (g0, m0), batch)
    return grads, metrics


# ----------------------------------------------------------------------------
# all_reduce baseline (standard data-parallel; gradient sync crosses pods)
# ----------------------------------------------------------------------------

def make_allreduce_step(model, tc: TrainConfig,
                        trainable: Optional[PyTree] = None) -> Callable:
    lr_fn, wd_fn, ls_fn, _ = make_schedules(tc)
    _, opt_update = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                   b1=tc.adam_b1, b2=tc.adam_b2,
                                   dtype=tc.opt_dtype)

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        def loss_fn(params, b):
            logits, aux = _task_forward(model, params, b, tc.remat)
            task = cd.cross_entropy(logits, b["labels"],
                                    ls_fn(state.step), b.get("mask"),
                                    fused=tc.fused_losses)
            metrics = {"loss": task + aux, "task_loss": task, "aux_loss": aux,
                       "accuracy": cd.accuracy(logits, b["labels"],
                                               b.get("mask"))}
            return task + aux, metrics

        grads, metrics = _grads_with_metrics(loss_fn, state.params, batch,
                                             tc.microbatch,
                                             jnp.dtype(tc.accum_dtype))
        params, opt = opt_update(state.params, grads, state.opt,
                                 lr_fn(state.step), wd_fn(state.step),
                                 trainable)
        metrics.update(lr=lr_fn(state.step), wd=wd_fn(state.step))
        return TrainState(params, opt, state.step + 1), metrics

    return step


# ----------------------------------------------------------------------------
# codistillation steps
# ----------------------------------------------------------------------------

def _stacked_forward(model, stacked_params: PyTree, batch_all: Dict,
                     remat: bool):
    """vmap over the model axis: batch_all arrays carry a leading n axis."""
    def one(params, batch):
        return _task_forward(model, params, batch, remat)
    return jax.vmap(one)(stacked_params, batch_all)


def make_codist_step(model, codist: CodistConfig, tc: TrainConfig,
                     distill: bool, trainable: Optional[PyTree] = None
                     ) -> Callable:
    """Prediction-exchange codistillation step (Algorithm 1, coordinated
    sampling). ``distill=False`` compiles the off-step variant that omits the
    distillation term (and hence the cross-pod logits collective entirely)."""
    lr_fn, wd_fn, ls_fn, alpha_fn = make_schedules(tc, codist)
    _, opt_update = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                   b1=tc.adam_b1, b2=tc.adam_b2,
                                   dtype=tc.opt_dtype)

    def step(state: CodistState, batch_all: Dict) -> Tuple[CodistState, Dict]:
        def loss_fn(stacked, b):
            logits_all, aux_all = _stacked_forward(model, stacked, b,
                                                   tc.remat)
            if distill:
                total, metrics = cd.codist_loss(
                    codist, logits_all, b["labels"],
                    alpha_fn(state.step), ls_fn(state.step),
                    b.get("mask"), fused=tc.fused_losses)
            else:
                task = jax.vmap(
                    lambda lg, lb, m: cd.cross_entropy(lg, lb,
                                                       ls_fn(state.step), m,
                                                       fused=tc.fused_losses)
                )(logits_all, b["labels"],
                  b.get("mask", jnp.ones(b["labels"].shape, jnp.float32)))
                total = jnp.mean(task)
                metrics = {"loss": total, "task_loss": total,
                           "distill_loss": jnp.zeros(()),
                           "task_loss_per_model": task,
                           "distill_loss_per_model": jnp.zeros_like(task),
                           "alpha": jnp.zeros(())}
            total = total + jnp.mean(aux_all)
            metrics["aux_loss"] = jnp.mean(aux_all)
            metrics["accuracy"] = jnp.mean(jax.vmap(cd.accuracy)(
                logits_all, b["labels"]))
            return total, metrics

        # microbatch axis sits AFTER the stacked model axis: (n, k, B/k, ...)
        mb_batch = batch_all
        if tc.microbatch > 1:
            mb_batch = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batch_all)
        grads, metrics = _grads_with_metrics(loss_fn, state.params, mb_batch,
                                             tc.microbatch,
                                             jnp.dtype(tc.accum_dtype))
        params, opt = opt_update(state.params, grads, state.opt,
                                 lr_fn(state.step), wd_fn(state.step),
                                 trainable)
        metrics.update(lr=lr_fn(state.step), wd=wd_fn(state.step))
        return CodistState(params, opt, state.step + 1, state.stale,
                           state.peer), metrics

    return step


def make_codist_checkpoint_step(model, codist: CodistConfig, tc: TrainConfig,
                                trainable: Optional[PyTree] = None
                                ) -> Callable:
    """Checkpoint-exchange codistillation (Anil et al.'s variant).

    Every step: each model i draws its OWN batch x_i and distills against the
    stale replicas' predictions on x_i — n-1 extra (gradient-free) forward
    passes. Every T steps the host loop refreshes ``state.stale`` via
    ``refresh_stale`` (the cross-pod parameter all-gather).
    """
    lr_fn, wd_fn, ls_fn, alpha_fn = make_schedules(tc, codist)
    _, opt_update = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                   b1=tc.adam_b1, b2=tc.adam_b2,
                                   dtype=tc.opt_dtype)
    n = codist.n_models

    def step(state: CodistState, batch_all: Dict) -> Tuple[CodistState, Dict]:
        # peer_pairwise[i, j] = stale_j(x_i); computed once, no gradient
        def stale_on_batch(batch_i):
            return jax.vmap(
                lambda sp: _task_forward(model, sp, batch_i, tc.remat)[0]
            )(state.stale)
        peer_pairwise = jax.lax.stop_gradient(
            jax.vmap(stale_on_batch)(batch_all))          # (n_batch=i, n_model=j, ...)

        def loss_fn(stacked):
            logits_all, aux_all = _stacked_forward(model, stacked, batch_all,
                                                   tc.remat)
            total, metrics = cd.codist_loss(
                codist, logits_all, batch_all["labels"], alpha_fn(state.step),
                ls_fn(state.step), batch_all.get("mask"),
                peer_pairwise=peer_pairwise, fused=tc.fused_losses)
            total = total + jnp.mean(aux_all)
            metrics["aux_loss"] = jnp.mean(aux_all)
            return total, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        params, opt = opt_update(state.params, grads, state.opt,
                                 lr_fn(state.step), wd_fn(state.step),
                                 trainable)
        metrics.update(lr=lr_fn(state.step), wd=wd_fn(state.step))
        return CodistState(params, opt, state.step + 1, state.stale,
                           state.peer), metrics

    return step


@jax.jit
def refresh_stale(state: CodistState) -> CodistState:
    """The checkpoint exchange: stale <- current params (cross-pod all-gather
    in the sharded setting: params are pod-sharded, stale is pod-replicated)."""
    return state._replace(stale=jax.tree.map(jnp.array, state.params))


# ----------------------------------------------------------------------------
# pipelined prediction exchange (beyond-paper: removes the sync point)
# ----------------------------------------------------------------------------

def make_codist_pipelined_step(model, codist: CodistConfig, tc: TrainConfig
                               ) -> Callable:
    """Distills against the PREVIOUS exchange's peer logits, replaying the
    previous (coordinated) batch for the distill term. Combined with
    ``compression='subsample'`` the replay forward is cheap, and the logits
    collective of step k-1 can overlap with step k's compute — the sync point
    the paper flags for prediction exchange disappears.

    state.peer = {"batch": prev batch_all, "logits": prev logits_all,
                  "valid": bool}
    """
    lr_fn, wd_fn, ls_fn, alpha_fn = make_schedules(tc, codist)
    _, opt_update = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                   b1=tc.adam_b1, b2=tc.adam_b2,
                                   dtype=tc.opt_dtype)

    def step(state: CodistState, batch_all: Dict) -> Tuple[CodistState, Dict]:
        peer = state.peer

        def loss_fn(stacked):
            logits_all, aux_all = _stacked_forward(model, stacked, batch_all,
                                                   tc.remat)
            task = jax.vmap(
                lambda lg, lb, m: cd.cross_entropy(lg, lb, ls_fn(state.step),
                                                   m, fused=tc.fused_losses)
            )(logits_all, batch_all["labels"],
              batch_all.get("mask", jnp.ones(batch_all["labels"].shape,
                                             jnp.float32)))
            # replay forward on the previous batch for the distillation term
            replay_logits, _ = _stacked_forward(model, stacked, peer["batch"],
                                                tc.remat)
            _, dmetrics = cd.codist_loss(
                codist, replay_logits, peer["batch"]["labels"],
                alpha_fn(state.step), 0.0, peer["batch"].get("mask"),
                peer_logits_all=peer["logits"], fused=tc.fused_losses)
            dist = dmetrics["distill_loss_per_model"]
            alpha = alpha_fn(state.step) * peer["valid"].astype(jnp.float32)
            total = jnp.mean(task + alpha * dist) + jnp.mean(aux_all)
            return total, {"loss": total, "task_loss": jnp.mean(task),
                           "distill_loss": jnp.mean(dist), "alpha": alpha,
                           "aux_loss": jnp.mean(aux_all),
                           "logits_all": logits_all}

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params)
        logits_all = metrics.pop("logits_all")
        params, opt = opt_update(state.params, grads, state.opt,
                                 lr_fn(state.step), wd_fn(state.step))
        new_peer = {"batch": batch_all,
                    "logits": jax.lax.stop_gradient(logits_all),
                    "valid": jnp.ones((), jnp.bool_)}
        return CodistState(params, opt, state.step + 1, state.stale,
                           new_peer), metrics

    return step


def init_peer_state(batch_all: Dict, logits_shape: Tuple[int, ...]) -> Dict:
    return {"batch": jax.tree.map(jnp.zeros_like, batch_all),
            "logits": jnp.zeros(logits_shape, jnp.float32),
            "valid": jnp.zeros((), jnp.bool_)}


# ----------------------------------------------------------------------------
# eval
# ----------------------------------------------------------------------------

def make_eval_step(model, tc: Optional[TrainConfig] = None) -> Callable:
    fused = tc.fused_losses if tc is not None else None

    def eval_step(params: PyTree, batch: Dict) -> Dict:
        logits, _ = _task_forward(model, params, batch, False)
        return {
            "eval_loss": cd.cross_entropy(logits, batch["labels"],
                                          0.0, batch.get("mask"),
                                          fused=fused),
            "eval_accuracy": cd.accuracy(logits, batch["labels"],
                                         batch.get("mask")),
        }
    return eval_step


def make_codist_eval_step(model, tc: Optional[TrainConfig] = None) -> Callable:
    fused = tc.fused_losses if tc is not None else None

    def eval_step(stacked_params: PyTree, batch_all: Dict) -> Dict:
        logits_all, _ = _stacked_forward(model, stacked_params, batch_all, False)
        loss = jax.vmap(lambda lg, lb: cd.cross_entropy(lg, lb, fused=fused))(
            logits_all, batch_all["labels"])
        acc = jax.vmap(cd.accuracy)(logits_all, batch_all["labels"])
        return {"eval_loss": jnp.mean(loss), "eval_loss_per_model": loss,
                "eval_accuracy": jnp.mean(acc), "eval_accuracy_per_model": acc}
    return eval_step
