"""Fault tolerance: codistillation vs the all-reduce barrier under faults.

The practical argument for codistillation's weak synchronization (Anil et
al., arXiv:1804.03235; the straggler analysis of arXiv:1604.00981) is that
slow, preempted, or failed replicas do not gate the healthy ones. The
virtual cluster (``repro.runtime``) makes that measurable: the SAME seeded
fault schedule drives the barrier-free async codistillation runtime and the
``simulate_allreduce`` barrier baseline, so the simulated wall-clock
degradation is an apples-to-apples comparison.

Scenario (the ISSUE-3 acceptance case): one peer runs 4x slower for ~20% of
its steps. Expectations:
  * all-reduce's wall-clock degrades by roughly the straggler's lost time
    (every step waits for the slowest replica);
  * codistillation's time-to-first-model barely moves — the healthy peer
    never waits, it just sees (bounded) staler targets;
  * the healthy peer's final task loss stays within 5% of the no-fault run.

Rows land in BENCH_throughput.json via ``benchmarks.run --only fault``;
per-peer trajectories are persisted as JSONL under results/fault_tolerance/.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import CodistConfig, TrainConfig
from repro.data import make_lm_batch
from repro.runtime import AsyncScheduler, FaultConfig, simulate_allreduce

from benchmarks.common import lm_setup, timed


def run(quick: bool = False) -> List[Dict]:
    model, task = lm_setup()
    steps = 40 if quick else 100
    b, s = 8, 32
    tc = TrainConfig(lr=3e-3, total_steps=steps, warmup_steps=5,
                     optimizer="adamw", lr_schedule="cosine", seed=0)
    codist = CodistConfig(n_models=2, period=1)

    def batches(step):
        return make_lm_batch(task, b, s, step, None, seed=0)

    clean = FaultConfig(n_peers=2, seed=0)
    # ISSUE-3 acceptance scenario: peer 1 is 4x slower for ~20% of its steps
    straggler = FaultConfig(n_peers=2, seed=0, straggler_peers=(1,),
                            straggler_factor=4.0, straggler_frac=0.2)

    rows: List[Dict] = []
    reports = {}
    for tag, faults in (("clean", clean), ("straggler", straggler)):
        rep, us = timed(
            lambda f=faults: AsyncScheduler(
                model, tc, codist, batches, f, staleness_bound=2,
                log_every=steps - 1).run(),
            warmup=0, iters=1)
        reports[("codist", tag)] = rep
        rep.save_histories(f"results/fault_tolerance/codist_{tag}")
        rows.append({"name": f"fault/codist_{tag}_time_to_first",
                     "us_per_call": us, "derived": round(rep.time_to_first, 3)})
        rows.append({"name": f"fault/codist_{tag}_sim_time",
                     "derived": round(rep.sim_time, 3)})
        rows.append({"name": f"fault/codist_{tag}_loss",
                     "derived": round(min(rep.final_task_loss.values()), 4)})

        ar, us = timed(
            lambda f=faults: simulate_allreduce(model, tc, batches, f,
                                                log_every=steps - 1),
            warmup=0, iters=1)
        reports[("allreduce", tag)] = ar
        ar.save_histories(f"results/fault_tolerance/allreduce_{tag}")
        rows.append({"name": f"fault/allreduce_{tag}_sim_time",
                     "us_per_call": us, "derived": round(ar.sim_time, 3)})

    # ---- the acceptance comparison -----------------------------------------
    cd0 = reports[("codist", "clean")]
    cd1 = reports[("codist", "straggler")]
    ar0 = reports[("allreduce", "clean")]
    ar1 = reports[("allreduce", "straggler")]
    deg_cd = (cd1.time_to_first - cd0.time_to_first) / cd0.time_to_first
    deg_ar = (ar1.sim_time - ar0.sim_time) / ar0.sim_time
    loss0 = min(cd0.final_task_loss.values())
    loss1 = min(cd1.final_task_loss.values())
    loss_gap = abs(loss1 - loss0) / loss0
    rows.append({"name": "fault/codist_degradation_frac",
                 "derived": round(deg_cd, 4)})
    rows.append({"name": "fault/allreduce_degradation_frac",
                 "derived": round(deg_ar, 4)})
    rows.append({"name": "fault/codist_degrades_strictly_less",
                 "derived": int(deg_cd < deg_ar)})
    rows.append({"name": "fault/loss_gap_frac_vs_nofault",
                 "derived": round(loss_gap, 4)})
    rows.append({"name": "fault/loss_within_5pct",
                 "derived": int(loss_gap <= 0.05)})
    rows.append({"name": "fault/straggler_staleness_mean",
                 "derived": round(cd1.staleness["staleness_mean"], 4)})
    rows.append({"name": "fault/straggler_payloads_dropped",
                 "derived": cd1.staleness["payloads_dropped"]})
    rows.append({"name": "fault/comm_bytes_per_event",
                 "derived": round(cd1.comm_bytes / max(1, cd1.comm_events))})
    return rows
